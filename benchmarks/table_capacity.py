"""Capacity table: Theorem 1/4 LP bounds vs simulated saturation throughput,
plus pairing-model (constraint (3)) sensitivity.  Not a paper figure per se —
it validates the quantitative anchors of §V and Theorem 4.
"""
from __future__ import annotations

import time

from repro.core import (PolicyConfig, capacity_upper_bound,
                        paper_grid_problem, single_node_capacity)
from repro.sim import simulate

T = 3000


def _sat_rate(p, cfg, lam_over):
    """Drive the system above capacity; measure saturated useful rate."""
    res = simulate(p, cfg, lam_over, T=T, seed=13)
    return float(res.useful_rate(T // 2))


def run(emit) -> dict:
    out = {}
    for C in (2.0, 3.0):
        p = paper_grid_problem(C=C)
        t0 = time.time()
        lp = capacity_upper_bound(p)
        lp_ms = (time.time() - t0) * 1e3
        sat = _sat_rate(p, PolicyConfig(name="pi3bar"), lam_over=lp.lam_star + 3)
        emit(f"capacity/C{C:g}/LP,{lp_ms*1e3:.1f},lambda_star={lp.lam_star:.3f}")
        emit(f"capacity/C{C:g}/sim_saturation,,useful_rate={sat:.3f}")
        # simulated saturation approaches (but cannot exceed) the LP bound
        assert sat <= lp.lam_star + 0.15
        assert sat >= 0.85 * lp.lam_star
        out[(C, "lp")] = lp.lam_star
        out[(C, "sat")] = sat

    # single-node pinning (Theorem 1) is strictly worse here
    p = paper_grid_problem(C=2.0)
    for i in range(4):
        s = single_node_capacity(p, i).lam_star
        emit(f"capacity/C2/single_node{i},,lambda_star={s:.3f}")

    # multi-stream (multiclass) extension: identical streams share the
    # computation capacity; disjoint-node streams add up (paper §VI)
    from repro.core import multi_stream_capacity
    ms2 = multi_stream_capacity([p, p])
    emit(f"capacity/C2/two_identical_streams,,lambda_total={ms2.lam_star:.3f}")
    assert abs(ms2.lam_star - 8.0) < 1e-6

    # pairing sensitivity: fifo vs analytic bound (7)
    for pairing in ("fifo", "bound"):
        sat = _sat_rate(p, PolicyConfig(name="pi3bar", pairing=pairing),
                        lam_over=11.0)
        emit(f"capacity/C2/pairing_{pairing},,useful_rate={sat:.3f}")
        out[("pairing", pairing)] = sat
    return out


if __name__ == "__main__":
    run(print)
