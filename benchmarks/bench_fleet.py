"""Fleet sweep benchmark: scenarios vs their LP capacity bounds.

Runs a (scenario x policy x rate x seed) grid through the sharded fleet
engine and emits a JSON capacity/efficiency table.  Regulated policies
(pi3_reg etc.) are scored against the rho0-adjusted bound
lam_star/(1+eps_B) — the Theorem-3/5 guarantee — so regulated and
unregulated rows are comparable.  The smoke preset packs >= 64 simulations
into <= 3 compiled programs (one per *semantic* policy group: pi3 and
pi3_reg share a program, eps_B is traced data), includes a regulated
policy under Gilbert–Elliott Markov fading, and checks physical sanity:
measured useful rate never exceeds the LP upper bound, pi3 sustains
>= 0.8 and pi3_reg >= 0.9 of their bounds on the paper's 4x4 grid.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python benchmarks/bench_fleet.py --preset smoke [--out fleet.json]
"""
from __future__ import annotations

import argparse
import json
import time

PRESETS = {
    "smoke": dict(
        scenario_policies={
            "paper_grid": ("pi3", "pi3bar", "pi3_reg"),
            "random_geometric": ("pi3", "pi3bar"),
            "expander": ("pi3", "pi3bar"),
            "fat_tree": ("pi3", "pi3bar"),
            "ge_grid": ("pi3_reg",),
        },
        rate_fracs=(0.3, 0.6, 0.8, 0.95),
        seeds=(0, 1),
        T=4000, chunk=500,
        eps_b=0.05,
    ),
    "full": dict(
        scenario_policies={
            "paper_grid": ("pi1", "pi2", "pi3", "pi3bar", "pi2_reg",
                           "pi3_reg"),
            "random_geometric": ("pi3", "pi3bar"),
            "ring": ("pi3", "pi3bar"),
            "tree": ("pi3", "pi3bar"),
            "expander": ("pi3", "pi3bar"),
            "fat_tree": ("pi3", "pi3bar"),
            "wireless_grid": ("pi3",),
            "fading_geometric": ("pi3",),
            "flaky_expander": ("pi3",),
            "failing_grid": ("pi3",),
            "ge_grid": ("pi3_reg", "pi3bar"),
            "ge_geometric": ("pi3_reg",),
            "bursty_grid": ("pi3_reg", "pi3bar"),
        },
        rate_fracs=(0.2, 0.4, 0.6, 0.8, 0.9, 0.95),
        seeds=(0, 1, 2),
        T=20000, chunk=1000,
        eps_b=0.05,
    ),
}

# Windowed rates can transiently exceed the long-run bound by backlog drain;
# 2% covers that noise without masking a real capacity violation.
LP_TOL = 1.02


def run(emit, preset: str = "smoke") -> dict:
    from repro.fleet import capacity_report

    spec = PRESETS[preset]
    t0 = time.time()
    table = capacity_report(**spec)
    wall = time.time() - t0
    table["preset"] = preset
    table["wall_s"] = wall

    emit(f"fleet/{preset}/sweep,{wall*1e6/max(table['n_sims'],1):.0f},"
         f"n_sims={table['n_sims']} n_programs={table['n_programs']}")
    for scen, entry in table["scenarios"].items():
        lam_star = entry["lam_star"]
        for pol, row in entry["policies"].items():
            emit(f"fleet/{preset}/{scen}/{pol},,lam_star={lam_star:.3f} "
                 f"bound={row['bound']:.3f} rho0={row['rho0']:.3f} "
                 f"best={row['best_useful_rate']:.3f} "
                 f"eff={row['efficiency']:.3f} "
                 f"max_stable_offered={row['max_stable_offered']:.3f}")
            assert row["best_useful_rate"] <= lam_star * LP_TOL, (
                f"{scen}/{pol}: measured {row['best_useful_rate']:.3f} "
                f"exceeds LP bound {lam_star:.3f}")

    grid = table["scenarios"].get("paper_grid")
    if grid is not None and "pi3" in grid["policies"]:
        eff = grid["policies"]["pi3"]["efficiency"]
        emit(f"fleet/{preset}/paper_grid/pi3_efficiency,,eff={eff:.3f}")
        assert eff >= 0.8, f"pi3 efficiency {eff:.3f} < 0.8 on paper grid"
    if grid is not None and "pi3_reg" in grid["policies"]:
        # Acceptance: the regulated policy reaches >= 0.9 of its
        # rho0-adjusted bound lam_star/(1+eps_B) on the paper grid.
        row = grid["policies"]["pi3_reg"]
        emit(f"fleet/{preset}/paper_grid/pi3_reg_efficiency,,"
             f"eff={row['efficiency']:.3f} bound={row['bound']:.3f}")
        assert row["efficiency"] >= 0.9, (
            f"pi3_reg efficiency {row['efficiency']:.3f} < 0.9 vs "
            f"rho0-adjusted bound {row['bound']:.3f}")

    if preset == "smoke":
        assert "pi3_reg" in table["scenarios"]["ge_grid"]["policies"], (
            "smoke must sweep a regulated policy under Gilbert–Elliott "
            "fading")
        assert table["n_sims"] >= 64
        assert table["n_programs"] <= 3
    return table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    ap.add_argument("--out", default=None, help="write the JSON table here")
    args = ap.parse_args()
    table = run(print, preset=args.preset)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
