"""Fleet sweep benchmark: scenarios vs their LP capacity bounds.

Runs a (scenario x policy x rate x seed) grid through the sharded fleet
engine and emits a JSON capacity/efficiency table.  Regulated policies
(pi3_reg etc.) are scored against the *exact* regulated LP bound
`capacity_upper_bound(problem, rho0=1+eps_B)` — rows carry both
`bound_exact` and the closed-form `bound_approx = lam_star/(1+eps_B)`
(DESIGN.md §6).  The smoke preset packs >= 64 simulations into <= 3
compiled programs (one per *semantic* policy group: pi3 and pi3_reg share
a program, eps_B is traced data), includes regulated policies under
Gilbert–Elliott Markov link fading AND Markov comp-node failures
(`ge_comp_grid`), and checks physical sanity: measured useful rate never
exceeds the LP upper bound, pi3 sustains >= 0.8 and pi3_reg >= 0.9 of
their exact bounds on the paper's 4x4 grid.

The emitted table also records engine throughput (`us_per_sim`,
`sims_per_sec`), the XLA memory analysis of the largest chunk-step
program (`memory.peak_bytes` etc.), a `backends` section timing the
same sweep under both slot-decision backends — the XLA oracle and the
fused Pallas slot kernels (`FleetJob(backend="pallas")`, DESIGN.md §7) —
with a bit-exact parity gate, and a `frontier` section measuring the
empirical max sustainable rate per target via `find_lambda_max`
(early-stopped adaptive bisection, DESIGN.md §8): measured
`lam_max / bound_exact` must land in FRONTIER_RATIO_BAND and the early
stop must save >= FRONTIER_MIN_SAVED_FRAC of the simulated slots.
`scripts/check_bench.py` gates committed baselines
(`BENCH_baseline.json`) against regressions.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python benchmarks/bench_fleet.py --preset smoke [--out fleet.json] \
          [--stream-out FLEET_stream.jsonl]
"""
from __future__ import annotations

import argparse
import json
import time

PRESETS = {
    "smoke": dict(
        scenario_policies={
            "paper_grid": ("pi3", "pi3bar", "pi3_reg"),
            "random_geometric": ("pi3", "pi3bar"),
            "expander": ("pi3", "pi3bar"),
            "fat_tree": ("pi3", "pi3bar"),
            "ge_grid": ("pi3_reg",),
            "ge_comp_grid": ("pi3_reg",),
        },
        rate_fracs=(0.3, 0.6, 0.8, 0.95),
        seeds=(0, 1),
        T=4000, chunk=500,
        eps_b=0.05,
    ),
    "full": dict(
        scenario_policies={
            "paper_grid": ("pi1", "pi2", "pi3", "pi3bar", "pi2_reg",
                           "pi3_reg"),
            "random_geometric": ("pi3", "pi3bar"),
            "ring": ("pi3", "pi3bar"),
            "tree": ("pi3", "pi3bar"),
            "expander": ("pi3", "pi3bar"),
            "fat_tree": ("pi3", "pi3bar"),
            "wireless_grid": ("pi3",),
            "fading_geometric": ("pi3",),
            "flaky_expander": ("pi3",),
            "failing_grid": ("pi3",),
            "ge_grid": ("pi3_reg", "pi3bar"),
            "ge_geometric": ("pi3_reg",),
            "bursty_grid": ("pi3_reg", "pi3bar"),
            "ge_comp_grid": ("pi3_reg", "pi3bar"),
            "ge_full_grid": ("pi3_reg",),
        },
        rate_fracs=(0.2, 0.4, 0.6, 0.8, 0.9, 0.95),
        seeds=(0, 1, 2),
        T=20000, chunk=1000,
        eps_b=0.05,
    ),
}

# Windowed rates can transiently exceed the long-run bound by backlog drain;
# 2% covers that noise without masking a real capacity violation.
LP_TOL = 1.02

#: (scenario, policy) -> minimum efficiency vs the exact regulated LP bound
#: (DESIGN.md §6).  Single source of truth: asserted here on every bench run
#: and imported by scripts/check_bench.py for the CI baseline gate.  Rows a
#: preset does not sweep are skipped.
EFFICIENCY_GATES = {
    ("paper_grid", "pi3"): 0.8,
    ("paper_grid", "pi3_reg"): 0.9,
    ("ge_grid", "pi3_reg"): 0.9,
    ("ge_comp_grid", "pi3_reg"): 0.9,
}


#: Backend-comparison sweep (DESIGN.md §7): the same jobs through the XLA
#: oracle and the fused Pallas slot kernels (interpret mode on CPU), timed
#: side by side and gated on bit-exact metric parity by check_bench.
BACKEND_COMPARE = dict(scenario="paper_grid", policy="pi3_reg", eps_b=0.05,
                       n_jobs=8, lam0=4.0, dlam=0.25, T=512, chunk=128)


#: Frontier smoke (DESIGN.md §8): adaptive lam_max searches, early-stopped.
#: T must comfortably cover the backpressure gradient fill-up (the verdict
#: burn-in is 2 chunks here) or stable rates read as still-growing.
FRONTIER_SMOKE = dict(
    targets=(("paper_grid", "pi3"), ("paper_grid", "pi3_reg")),
    eps_b=0.05, seeds=(0, 1), T=4096, chunk=256, rel_tol=0.025)

#: measured lam_max / bound_exact band for the paper grid (acceptance:
#: the empirical frontier localizes the exact regulated LP bound from
#: below).  Imported by scripts/check_bench.py for the CI baseline gate.
FRONTIER_RATIO_BAND = (0.90, 1.0)

#: minimum fraction of simulated slots the early stop must save across the
#: whole frontier smoke (per-sim freeze savings, summed over all probes).
FRONTIER_MIN_SAVED_FRAC = 0.30


#: Resilience overhead smoke (DESIGN.md §12): the same sweep plain and with
#: chunk-boundary checkpointing on (snapshot-before-donate, background disk
#: writes), timed side by side and gated on bit-exact metric parity here
#: plus the overhead ceiling in scripts/check_bench.py.
RESILIENCE_COMPARE = dict(scenario="paper_grid", policy="pi3_reg",
                          eps_b=0.05, n_jobs=8, lam0=4.0, dlam=0.25,
                          T=2048, chunk=256)

#: checkpoint-on us_per_sim must stay within 1 + this of the plain run.
#: Imported by scripts/check_bench.py for the CI gate.
RESILIENCE_MAX_OVERHEAD = 0.05


def frontier_section(emit) -> dict:
    """Run the FRONTIER_SMOKE searches and gate their ratios/savings.

    Each target runs `find_lambda_max` — exact-LP-seeded bracket, integer
    bisection on the rel_tol grid, per-probe early stop — and must land
    its measured lam_max inside FRONTIER_RATIO_BAND of the exact bound
    while the freeze saves at least FRONTIER_MIN_SAVED_FRAC of the
    simulated slots in aggregate (DESIGN.md §8)."""
    from repro.fleet import find_lambda_max

    c = dict(FRONTIER_SMOKE)
    targets = c.pop("targets")
    out: dict = {"targets": {}, "T": c["T"], "rel_tol": c["rel_tol"],
                 "seeds": list(c["seeds"])}
    saved = full = 0
    for scen, pol in targets:
        t0 = time.time()
        r = find_lambda_max(scen, pol, eps_b=c["eps_b"], seeds=c["seeds"],
                            T=c["T"], chunk=c["chunk"], rel_tol=c["rel_tol"])
        wall = time.time() - t0
        row = {
            "lam_max": r.lam_max, "bound_exact": r.bound_exact,
            "ratio": r.ratio, "n_calls": r.n_calls, "n_iters": r.n_iters,
            "total_slots": r.total_slots, "full_slots": r.full_slots,
            "slots_saved": r.slots_saved,
            "slots_saved_frac": r.slots_saved_frac,
            "launch_slots_saved": r.launch_slots_saved,
            "n_step_compiles": r.n_step_compiles, "wall_s": wall,
        }
        out["targets"][f"{scen}/{pol}"] = row
        saved += r.slots_saved
        full += r.full_slots
        emit(f"fleet/frontier/{scen}/{pol},,lam_max={r.lam_max:.3f} "
             f"bound_exact={r.bound_exact:.3f} ratio={r.ratio:.3f} "
             f"calls={r.n_calls} saved_frac={r.slots_saved_frac:.3f} "
             f"compiles={r.n_step_compiles}")
        lo, hi = FRONTIER_RATIO_BAND
        assert lo <= r.ratio <= hi + 1e-9, (
            f"{scen}/{pol}: lam_max/bound {r.ratio:.3f} outside "
            f"[{lo}, {hi}]")
        assert r.n_step_compiles == 1, (
            f"{scen}/{pol}: bisection compiled {r.n_step_compiles} "
            "chunk-step programs (must reuse one)")
    out["slots_saved"] = saved
    out["full_slots"] = full
    out["slots_saved_frac"] = saved / full if full else 0.0
    emit(f"fleet/frontier/slots_saved,,{saved}/{full} "
         f"frac={out['slots_saved_frac']:.3f} "
         f"gate>={FRONTIER_MIN_SAVED_FRAC}")
    assert out["slots_saved_frac"] >= FRONTIER_MIN_SAVED_FRAC, (
        f"early stopping saved only {out['slots_saved_frac']:.1%} of "
        f"simulated slots (< {FRONTIER_MIN_SAVED_FRAC:.0%})")
    return out


def backend_compare(emit) -> dict:
    """Run the BACKEND_COMPARE sweep under both slot-decision backends.

    Each backend gets a warm-up run (compilation; the engine's memoized
    launches make the second run compile-free) and a timed run.  Returns
    {"xla": {...}, "pallas": {...}, "parity_max_abs_diff": 0.0} for the
    bench table's `backends` section."""
    import numpy as np
    from repro.fleet import FleetJob, run_fleet

    c = BACKEND_COMPARE
    out: dict = {}
    useful = {}
    for backend in ("xla", "pallas"):
        jobs = [FleetJob(scenario=c["scenario"], policy=c["policy"],
                         lam=c["lam0"] + c["dlam"] * s, eps_b=c["eps_b"],
                         seed=s, backend=backend)
                for s in range(c["n_jobs"])]
        run_fleet(jobs, T=c["T"], chunk=c["chunk"])          # warm-up
        t0 = time.time()
        res = run_fleet(jobs, T=c["T"], chunk=c["chunk"])
        wall = time.time() - t0
        useful[backend] = res.column("useful_rate")
        out[backend] = {
            "us_per_sim": wall * 1e6 / len(jobs),
            "wall_s": wall,
            "n_sims": len(jobs),
            "T": res.T,
        }
        emit(f"fleet/backends/{backend},{out[backend]['us_per_sim']:.0f},"
             f"n_sims={len(jobs)} T={res.T}")
    diff = float(np.max(np.abs(useful["xla"] - useful["pallas"])))
    out["parity_max_abs_diff"] = diff
    emit(f"fleet/backends/parity,,max_abs_diff={diff}")
    assert diff == 0.0, (
        f"pallas backend diverged from xla by {diff} (DESIGN.md §7)")
    return out


def resilience_section(emit, ckpt_dir: str = "CKPT_bench") -> dict:
    """Time the RESILIENCE_COMPARE sweep plain vs checkpoint-on.

    Checkpointing rides the chunk boundaries (DESIGN.md §12): the carry
    is read to host synchronously before the next donating launch, disk
    writes go to a background thread.  Metrics must be bit-identical
    (snapshotting is a pure read of the carry); the per-sim overhead is
    reported for check_bench's RESILIENCE_MAX_OVERHEAD gate.  The gate
    is tight (5%), well inside this box's scheduler-noise band, so the
    estimator is paired: each rep times plain and checkpoint-on
    back-to-back (a load burst inflates both), and the overhead is the
    *minimum* per-rep ratio across three reps — one burst-free rep is
    enough for a clean reading."""
    from repro.fleet import FleetJob, run_fleet
    from repro.runtime.resilience import ResilienceConfig

    c = RESILIENCE_COMPARE
    jobs = [FleetJob(scenario=c["scenario"], policy=c["policy"],
                     lam=c["lam0"] + c["dlam"] * s, eps_b=c["eps_b"],
                     seed=s)
            for s in range(c["n_jobs"])]
    kw = dict(T=c["T"], chunk=c["chunk"])
    rc = ResilienceConfig(checkpoint_dir=ckpt_dir, blocking=False,
                          resume=False)
    run_fleet(jobs, **kw)                                    # warm-up
    base = ckpt = None
    walls = {"plain": [], "ckpt": []}
    for _ in range(3):
        t0 = time.time()
        base = run_fleet(jobs, **kw)
        walls["plain"].append(time.time() - t0)
        t0 = time.time()
        ckpt = run_fleet(jobs, **kw, resilience=rc)
        walls["ckpt"].append(time.time() - t0)
    for m0, m1 in zip(base.metrics, ckpt.metrics):
        assert m0 == m1, ("checkpointing perturbed the run "
                          "(observer effect)", m0, m1)
    plain_us = min(walls["plain"]) * 1e6 / len(jobs)
    ckpt_us = min(walls["ckpt"]) * 1e6 / len(jobs)
    overhead = min(c / p for p, c in zip(walls["plain"], walls["ckpt"])) - 1.0
    out = {
        "us_per_sim_plain": plain_us,
        "us_per_sim_ckpt": ckpt_us,
        "overhead_frac": overhead,
        "n_snapshots": c["T"] // c["chunk"],
        "n_sims": len(jobs), "T": c["T"],
        "checkpoint_dir": ckpt_dir,
    }
    emit(f"fleet/resilience/overhead,,plain={plain_us:.0f}us "
         f"ckpt={ckpt_us:.0f}us frac={out['overhead_frac']:+.3f} "
         f"gate<={RESILIENCE_MAX_OVERHEAD}")
    return out


def run(emit, preset: str = "smoke", stream_out: str | None = None,
        ckpt_dir: str = "CKPT_bench") -> dict:
    from repro.fleet import capacity_report

    spec = PRESETS[preset]
    t0 = time.time()
    table = capacity_report(**spec, memory_stats=True,
                            stream_path=stream_out)
    wall = time.time() - t0
    table["preset"] = preset
    table["wall_s"] = wall
    table["us_per_sim"] = wall * 1e6 / max(table["n_sims"], 1)
    table["sims_per_sec"] = table["n_sims"] / max(wall, 1e-9)

    emit(f"fleet/{preset}/sweep,{table['us_per_sim']:.0f},"
         f"n_sims={table['n_sims']} n_programs={table['n_programs']} "
         f"sims_per_sec={table['sims_per_sec']:.2f}")
    if "memory" in table:
        emit(f"fleet/{preset}/chunk_step_memory,,"
             f"peak_bytes={table['memory']['peak_bytes']:.0f} "
             f"temp_bytes={table['memory']['temp_bytes']:.0f}")
    for scen, entry in table["scenarios"].items():
        lam_star = entry["lam_star"]
        for pol, row in entry["policies"].items():
            emit(f"fleet/{preset}/{scen}/{pol},,lam_star={lam_star:.3f} "
                 f"bound_exact={row['bound_exact']:.3f} "
                 f"bound_approx={row['bound_approx']:.3f} "
                 f"rho0={row['rho0']:.3f} "
                 f"best={row['best_useful_rate']:.3f} "
                 f"eff={row['efficiency']:.3f} "
                 f"max_stable_offered={row['max_stable_offered']:.3f}")
            assert row["best_useful_rate"] <= lam_star * LP_TOL, (
                f"{scen}/{pol}: measured {row['best_useful_rate']:.3f} "
                f"exceeds LP bound {lam_star:.3f}")
            # The approximation is a valid lower bound on the exact LP,
            # within rho0 of it (DESIGN.md §6) — a broken cache key or a
            # mismatched rho0 would show up here.
            assert row["bound_approx"] <= row["bound_exact"] * (1 + 1e-9), row
            assert row["bound_exact"] <= \
                row["bound_approx"] * row["rho0"] * (1 + 1e-9), row

    # Acceptance: gated rows reach their efficiency floor vs the *exact*
    # regulated LP bound lam_star(rho0).
    for (scen, pol), floor in EFFICIENCY_GATES.items():
        row = table["scenarios"].get(scen, {}).get("policies", {}).get(pol)
        if row is None:
            continue
        emit(f"fleet/{preset}/{scen}/{pol}_efficiency,,"
             f"eff={row['efficiency']:.3f} gate={floor} "
             f"bound_exact={row['bound_exact']:.3f}")
        assert row["efficiency"] >= floor, (
            f"{scen}/{pol} efficiency {row['efficiency']:.3f} < {floor} vs "
            f"exact regulated bound {row['bound_exact']:.3f}")

    if preset == "smoke":
        assert "pi3_reg" in table["scenarios"]["ge_grid"]["policies"], (
            "smoke must sweep a regulated policy under Gilbert–Elliott "
            "fading")
        assert "ge_comp_grid" in table["scenarios"], (
            "smoke must sweep a Markov comp-node-failure scenario")
        assert table["n_sims"] >= 64
        assert table["n_programs"] <= 3

    # Side-by-side slot-decision backends (xla oracle vs fused Pallas
    # kernels), gated on bit-exact parity (DESIGN.md §7).
    table["backends"] = backend_compare(emit)

    # Adaptive lam_max frontier (DESIGN.md §8): measured frontier must
    # bracket the exact LP bound, early stop must pay for itself.
    table["frontier"] = frontier_section(emit)

    # Preemption-safety overhead (DESIGN.md §12): checkpoint-on must be
    # bit-identical and nearly free (gated by check_bench).
    table["resilience"] = resilience_section(emit, ckpt_dir=ckpt_dir)
    return table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    ap.add_argument("--out", default=None, help="write the JSON table here")
    ap.add_argument("--stream-out", default=None,
                    help="write per-chunk telemetry records (JSONL, "
                    "repro.obs.schema) here while the sweep runs")
    ap.add_argument("--ckpt-dir", default="CKPT_bench",
                    help="checkpoint dir for the resilience overhead "
                    "section (uploaded in the CI bench artifact)")
    args = ap.parse_args()
    table = run(print, preset=args.preset, stream_out=args.stream_out,
                ckpt_dir=args.ckpt_dir)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
        print(f"wrote {args.out}")
    if args.stream_out:
        print(f"wrote {args.stream_out} "
              f"({table.get('stream_records', 0)} records)")


if __name__ == "__main__":
    main()
