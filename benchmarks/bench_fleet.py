"""Fleet sweep benchmark: scenarios vs their LP capacity bounds.

Runs a (scenario x policy x rate x seed) grid through the sharded fleet
engine and emits a JSON capacity/efficiency table.  Regulated policies
(pi3_reg etc.) are scored against the *exact* regulated LP bound
`capacity_upper_bound(problem, rho0=1+eps_B)` — rows carry both
`bound_exact` and the closed-form `bound_approx = lam_star/(1+eps_B)`
(DESIGN.md §6).  The smoke preset packs >= 64 simulations into <= 3
compiled programs (one per *semantic* policy group: pi3 and pi3_reg share
a program, eps_B is traced data), includes regulated policies under
Gilbert–Elliott Markov link fading AND Markov comp-node failures
(`ge_comp_grid`), and checks physical sanity: measured useful rate never
exceeds the LP upper bound, pi3 sustains >= 0.8 and pi3_reg >= 0.9 of
their exact bounds on the paper's 4x4 grid.

The emitted table also records engine throughput (`us_per_sim`,
`sims_per_sec`) and the XLA memory analysis of the largest chunk-step
program (`memory.peak_bytes` etc.) — `scripts/check_bench.py` gates
committed baselines (`BENCH_baseline.json`) against regressions.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python benchmarks/bench_fleet.py --preset smoke [--out fleet.json]
"""
from __future__ import annotations

import argparse
import json
import time

PRESETS = {
    "smoke": dict(
        scenario_policies={
            "paper_grid": ("pi3", "pi3bar", "pi3_reg"),
            "random_geometric": ("pi3", "pi3bar"),
            "expander": ("pi3", "pi3bar"),
            "fat_tree": ("pi3", "pi3bar"),
            "ge_grid": ("pi3_reg",),
            "ge_comp_grid": ("pi3_reg",),
        },
        rate_fracs=(0.3, 0.6, 0.8, 0.95),
        seeds=(0, 1),
        T=4000, chunk=500,
        eps_b=0.05,
    ),
    "full": dict(
        scenario_policies={
            "paper_grid": ("pi1", "pi2", "pi3", "pi3bar", "pi2_reg",
                           "pi3_reg"),
            "random_geometric": ("pi3", "pi3bar"),
            "ring": ("pi3", "pi3bar"),
            "tree": ("pi3", "pi3bar"),
            "expander": ("pi3", "pi3bar"),
            "fat_tree": ("pi3", "pi3bar"),
            "wireless_grid": ("pi3",),
            "fading_geometric": ("pi3",),
            "flaky_expander": ("pi3",),
            "failing_grid": ("pi3",),
            "ge_grid": ("pi3_reg", "pi3bar"),
            "ge_geometric": ("pi3_reg",),
            "bursty_grid": ("pi3_reg", "pi3bar"),
            "ge_comp_grid": ("pi3_reg", "pi3bar"),
            "ge_full_grid": ("pi3_reg",),
        },
        rate_fracs=(0.2, 0.4, 0.6, 0.8, 0.9, 0.95),
        seeds=(0, 1, 2),
        T=20000, chunk=1000,
        eps_b=0.05,
    ),
}

# Windowed rates can transiently exceed the long-run bound by backlog drain;
# 2% covers that noise without masking a real capacity violation.
LP_TOL = 1.02

#: (scenario, policy) -> minimum efficiency vs the exact regulated LP bound
#: (DESIGN.md §6).  Single source of truth: asserted here on every bench run
#: and imported by scripts/check_bench.py for the CI baseline gate.  Rows a
#: preset does not sweep are skipped.
EFFICIENCY_GATES = {
    ("paper_grid", "pi3"): 0.8,
    ("paper_grid", "pi3_reg"): 0.9,
    ("ge_grid", "pi3_reg"): 0.9,
    ("ge_comp_grid", "pi3_reg"): 0.9,
}


def run(emit, preset: str = "smoke") -> dict:
    from repro.fleet import capacity_report

    spec = PRESETS[preset]
    t0 = time.time()
    table = capacity_report(**spec, memory_stats=True)
    wall = time.time() - t0
    table["preset"] = preset
    table["wall_s"] = wall
    table["us_per_sim"] = wall * 1e6 / max(table["n_sims"], 1)
    table["sims_per_sec"] = table["n_sims"] / max(wall, 1e-9)

    emit(f"fleet/{preset}/sweep,{table['us_per_sim']:.0f},"
         f"n_sims={table['n_sims']} n_programs={table['n_programs']} "
         f"sims_per_sec={table['sims_per_sec']:.2f}")
    if "memory" in table:
        emit(f"fleet/{preset}/chunk_step_memory,,"
             f"peak_bytes={table['memory']['peak_bytes']:.0f} "
             f"temp_bytes={table['memory']['temp_bytes']:.0f}")
    for scen, entry in table["scenarios"].items():
        lam_star = entry["lam_star"]
        for pol, row in entry["policies"].items():
            emit(f"fleet/{preset}/{scen}/{pol},,lam_star={lam_star:.3f} "
                 f"bound_exact={row['bound_exact']:.3f} "
                 f"bound_approx={row['bound_approx']:.3f} "
                 f"rho0={row['rho0']:.3f} "
                 f"best={row['best_useful_rate']:.3f} "
                 f"eff={row['efficiency']:.3f} "
                 f"max_stable_offered={row['max_stable_offered']:.3f}")
            assert row["best_useful_rate"] <= lam_star * LP_TOL, (
                f"{scen}/{pol}: measured {row['best_useful_rate']:.3f} "
                f"exceeds LP bound {lam_star:.3f}")
            # The approximation is a valid lower bound on the exact LP,
            # within rho0 of it (DESIGN.md §6) — a broken cache key or a
            # mismatched rho0 would show up here.
            assert row["bound_approx"] <= row["bound_exact"] * (1 + 1e-9), row
            assert row["bound_exact"] <= \
                row["bound_approx"] * row["rho0"] * (1 + 1e-9), row

    # Acceptance: gated rows reach their efficiency floor vs the *exact*
    # regulated LP bound lam_star(rho0).
    for (scen, pol), floor in EFFICIENCY_GATES.items():
        row = table["scenarios"].get(scen, {}).get("policies", {}).get(pol)
        if row is None:
            continue
        emit(f"fleet/{preset}/{scen}/{pol}_efficiency,,"
             f"eff={row['efficiency']:.3f} gate={floor} "
             f"bound_exact={row['bound_exact']:.3f}")
        assert row["efficiency"] >= floor, (
            f"{scen}/{pol} efficiency {row['efficiency']:.3f} < {floor} vs "
            f"exact regulated bound {row['bound_exact']:.3f}")

    if preset == "smoke":
        assert "pi3_reg" in table["scenarios"]["ge_grid"]["policies"], (
            "smoke must sweep a regulated policy under Gilbert–Elliott "
            "fading")
        assert "ge_comp_grid" in table["scenarios"], (
            "smoke must sweep a Markov comp-node-failure scenario")
        assert table["n_sims"] >= 64
        assert table["n_programs"] <= 3
    return table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    ap.add_argument("--out", default=None, help="write the JSON table here")
    args = ap.parse_args()
    table = run(print, preset=args.preset)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
