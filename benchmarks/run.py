"""Benchmark harness — one module per paper table/figure plus framework
benchmarks.  Prints ``name,us_per_call,derived`` CSV lines.

  fig5b           paper Fig. 5(b): queue length vs rate, pi3 vs pi3bar
  fig5c           paper Fig. 5(c): running averages at C=2, lam=6
  table_capacity  Theorem 1/4 LP vs simulated saturation + pairing models
  bench_router    backpressure MoE router vs aux-loss vs plain
  bench_serving   backpressure serving scheduler vs RR/JSQ
  bench_kernels   Pallas kernels (interpret) vs jnp references

Usage: PYTHONPATH=src python -m benchmarks.run [suite ...]
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import fig5b, fig5c, table_capacity, bench_router
    suites = {
        "fig5b": fig5b.run,
        "fig5c": fig5c.run,
        "table_capacity": table_capacity.run,
        "bench_router": bench_router.run,
    }
    try:
        from . import bench_serving
        suites["bench_serving"] = bench_serving.run
    except ImportError:
        pass
    try:
        from . import bench_kernels
        suites["bench_kernels"] = bench_kernels.run
    except ImportError:
        pass
    try:
        from . import bench_fleet
        suites["bench_fleet"] = bench_fleet.run
    except ImportError:
        pass

    chosen = sys.argv[1:] or list(suites)
    failures = []
    print("name,us_per_call,derived")
    for name in chosen:
        t0 = time.time()
        try:
            suites[name](print)
            print(f"# suite {name} ok in {time.time()-t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"# suite {name} FAILED")
    if failures:
        raise SystemExit(f"failed suites: {failures}")


if __name__ == "__main__":
    main()
