"""Fig. 5(b): average total queue length vs query rate, pi3 (solid) vs
pi3bar (dashed), for C=2 and C=3 on the 4x4 grid.

Reproduces the paper's two claims:
  * both policies share the same capacity knee (the regulator costs ~nothing),
  * the knee sits at lam*=8 for C=2 (computation-bound) and just below the
    LP bound 10 for C=3 (communication-bound; paper reads ~9.8).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PolicyConfig, capacity_upper_bound, paper_grid_problem
from repro.sim import sweep_rates

T = 2500
LAMS = {2.0: [4.0, 5.0, 6.0, 6.5, 7.0, 7.5, 8.0, 8.5, 9.0],
        3.0: [5.0, 6.0, 7.0, 8.0, 8.5, 9.0, 9.5, 10.0, 10.5]}


def run(emit) -> dict:
    out = {}
    for C in (2.0, 3.0):
        p = paper_grid_problem(C=C)
        lam_star = capacity_upper_bound(p).lam_star
        emit(f"# fig5b C={C}: LP lambda* = {lam_star:.3f}")
        for name in ("pi3", "pi3bar"):
            t0 = time.time()
            res = sweep_rates(p, PolicyConfig(name=name, eps_b=0.01),
                              LAMS[C], T=T, seed=7)
            dt = time.time() - t0
            avg_q = np.asarray(res.total_queue.mean(axis=1))
            rate = np.asarray(res.delivered_useful[:, -1] -
                              res.delivered_useful[:, T // 2]) / (T - T // 2)
            us = dt / (len(LAMS[C]) * T) * 1e6
            for lam, q, r in zip(LAMS[C], avg_q, rate):
                emit(f"fig5b/C{C:g}/{name}/lam{lam:g},{us:.2f},"
                     f"avg_queue={q:.1f};useful_rate={r:.3f}")
            out[(C, name)] = (np.array(LAMS[C]), avg_q, rate)
        # capacity knee check: queue explodes past lambda*
        for name in ("pi3", "pi3bar"):
            lams, q, r = out[(C, name)]
            below = q[lams <= lam_star - 1.0]
            above = q[lams >= lam_star + 0.4]
            if len(above) and len(below):
                assert above.min() > 1.5 * below.max(), (C, name)
    return out


if __name__ == "__main__":
    run(print)
