"""Kernel benchmarks: interpret-mode Pallas vs jnp reference (correctness +
CPU timing; real speed lives on TPU — the derived column reports max error).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention_op, attention_ref
from repro.kernels.bp_route.ops import bp_route_op, bp_route_ref
from repro.kernels.bp_topk.ops import bp_topk_op, bp_topk_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run(emit) -> dict:
    key = jax.random.key(0)
    out = {}

    # flash attention — gemma3-like tile (GQA 2:1, window)
    q = jax.random.normal(key, (1, 8, 512, 128), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 512, 128))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, 512, 128))
    us_k = _time(lambda *a: flash_attention_op(*a, causal=True, window=256), q, k, v)
    us_r = _time(lambda *a: attention_ref(*a, causal=True, window=256), q, k, v)
    err = float(jnp.max(jnp.abs(
        flash_attention_op(q, k, v, causal=True, window=256)
        - attention_ref(q, k, v, causal=True, window=256))))
    emit(f"kernels/flash_attention/interp,{us_k:.0f},max_err={err:.2e};ref_us={us_r:.0f}")
    assert err < 1e-4
    out["flash"] = err

    # bp_route — fleet-scale control plane: 4096 links x 96 classes
    Q = jax.random.uniform(jax.random.fold_in(key, 3), (512, 96)) * 100
    edges = jax.random.randint(jax.random.fold_in(key, 4), (4096, 2), 0, 512)
    edges = edges.at[:, 1].set((edges[:, 1] + 1 + edges[:, 0]) % 512)
    cap = jnp.ones((4096,)) * 5.0
    us_k = _time(bp_route_op, Q, edges, cap)
    cls, rate, dirn = bp_route_op(Q, edges, cap)
    rcls, rrate, rdirn = bp_route_ref(Q[edges[:, 0]], Q[edges[:, 1]], cap)
    ok = bool(jnp.all(cls == rcls) & jnp.all(dirn == rdirn))
    emit(f"kernels/bp_route/interp,{us_k:.0f},exact_match={ok}")
    assert ok
    out["bp_route"] = ok

    # bp_topk — moonshot gating: 4096 tokens x 64 experts top-6
    scores = jax.random.normal(jax.random.fold_in(key, 5), (4096, 64))
    H = jax.random.uniform(jax.random.fold_in(key, 6), (64,)) * 0.3
    us_k = _time(lambda s, h: bp_topk_op(s, h, 6), scores, H)
    idx, w = bp_topk_op(scores, H, 6)
    ridx, rw = bp_topk_ref(scores, H, 6)
    ok = bool(jnp.all(idx == ridx))
    werr = float(jnp.max(jnp.abs(w - rw)))
    emit(f"kernels/bp_topk/interp,{us_k:.0f},exact_idx={ok};w_err={werr:.2e}")
    assert ok and werr < 1e-5
    out["bp_topk"] = werr
    return out


if __name__ == "__main__":
    run(print)
