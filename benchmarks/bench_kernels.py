"""Kernel benchmarks: interpret-mode Pallas vs jnp reference (correctness +
CPU timing; real speed lives on TPU — the derived column reports max error).

Emits a JSON table (``--out BENCH_kernels.json``) of per-kernel µs that
`scripts/check_bench.py` diffs against the committed
``BENCH_kernels_baseline.json`` with the same >25% regression rule as the
fleet bench (timing skippable via CHECK_BENCH_SKIP_TIMING=1; the
exact-match assertions always run).

The ``bp_slot`` sections time the *fused* slot-decision kernels
(DESIGN.md §7) against their materializing oracles at the fleet smoke pad
dims (E=45, NC=4) and a scaled point (E=512, NC=16).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention_op, attention_ref
from repro.kernels.bp_route.ops import bp_route_op, bp_route_ref
from repro.kernels.bp_slot.kernel import comp_balance_decide
from repro.kernels.bp_slot.ops import slot_route_op, slot_route_op_ref
from repro.kernels.bp_slot.ref import comp_balance_ref
from repro.kernels.bp_topk.ops import bp_topk_op, bp_topk_ref


def _time(fn, *args, reps=5):
    """Min-of-reps µs timing with an adaptive inner loop: sub-ms kernels
    are batched until one rep spans >= ~5 ms, so the regression gate in
    scripts/check_bench.py sees dispatch-noise-free numbers."""
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    once = time.perf_counter() - t0
    inner = max(1, int(5e-3 / max(once, 1e-9)))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) / inner)
    return best * 1e6


def _bench_bp_slot(key, emit, table, tag: str, E: int, NC: int, N: int):
    """Fused slot-decision kernels vs oracles at one (E, NC, N) point."""
    # routing decision
    Q = jax.random.uniform(jax.random.fold_in(key, 10), (N, 3, NC)) * 100
    edges = jax.random.randint(jax.random.fold_in(key, 11), (E, 2), 0, N)
    edges = edges.at[:, 1].set((edges[:, 1] + 1 + edges[:, 0]) % N)
    cap = jnp.ones((E,)) * 5.0
    us_k = _time(slot_route_op, Q, edges, cap)
    us_r = _time(jax.jit(slot_route_op_ref), Q, edges, cap)
    out = slot_route_op(Q, edges, cap)
    ref = slot_route_op_ref(Q, edges, cap)
    ok = all(bool(jnp.all(a == b)) for a, b in zip(out, ref))
    emit(f"kernels/bp_slot/route_{tag},{us_k:.0f},"
         f"exact_match={ok};ref_us={us_r:.0f}")
    assert ok
    table[f"bp_slot_route_{tag}"] = {"us": us_k, "ref_us": us_r,
                                     "E": E, "NC": NC}

    # fused comp/balance decision
    r = lambda i: jax.random.uniform(jax.random.fold_in(key, 20 + i),
                                     (NC,)) * 10
    panels = (r(0), r(1), r(2), r(3), r(4),
              jnp.ones((NC,)), r(5), r(6), r(7) + 5, r(8) + 5, r(9))
    x_net = r(10)
    eps = jnp.float32(0.05)
    args = (eps,) + panels + (x_net,)
    fused = jax.jit(lambda *a: comp_balance_decide(*a))
    oracle = jax.jit(lambda *a: comp_balance_ref(
        *a, pairing="fifo", thresholded=False, threshold=0.0))
    us_k = _time(fused, *args)
    us_r = _time(oracle, *args)
    Z, n = fused(*args)
    rZ, rn = oracle(*args)
    ok = bool(jnp.all(Z == rZ)) and int(n) == int(rn)
    emit(f"kernels/bp_slot/balance_{tag},{us_k:.0f},"
         f"exact_match={ok};ref_us={us_r:.0f}")
    assert ok
    table[f"bp_slot_balance_{tag}"] = {"us": us_k, "ref_us": us_r, "NC": NC}


def run(emit) -> dict:
    key = jax.random.key(0)
    kernels: dict = {}
    table = {"kernels": kernels}

    # flash attention — gemma3-like tile (GQA 2:1, window)
    q = jax.random.normal(key, (1, 8, 512, 128), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 512, 128))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, 512, 128))
    us_k = _time(lambda *a: flash_attention_op(*a, causal=True, window=256), q, k, v)
    us_r = _time(lambda *a: attention_ref(*a, causal=True, window=256), q, k, v)
    err = float(jnp.max(jnp.abs(
        flash_attention_op(q, k, v, causal=True, window=256)
        - attention_ref(q, k, v, causal=True, window=256))))
    emit(f"kernels/flash_attention/interp,{us_k:.0f},max_err={err:.2e};ref_us={us_r:.0f}")
    assert err < 1e-4
    kernels["flash_attention"] = {"us": us_k, "ref_us": us_r, "max_err": err}

    # bp_route — fleet-scale control plane: 4096 links x 96 classes
    Q = jax.random.uniform(jax.random.fold_in(key, 3), (512, 96)) * 100
    edges = jax.random.randint(jax.random.fold_in(key, 4), (4096, 2), 0, 512)
    edges = edges.at[:, 1].set((edges[:, 1] + 1 + edges[:, 0]) % 512)
    cap = jnp.ones((4096,)) * 5.0
    us_k = _time(bp_route_op, Q, edges, cap)
    cls, rate, dirn = bp_route_op(Q, edges, cap)
    rcls, rrate, rdirn = bp_route_ref(Q[edges[:, 0]], Q[edges[:, 1]], cap)
    ok = bool(jnp.all(cls == rcls) & jnp.all(dirn == rdirn))
    emit(f"kernels/bp_route/interp,{us_k:.0f},exact_match={ok}")
    assert ok
    kernels["bp_route"] = {"us": us_k}

    # bp_topk — moonshot gating: 4096 tokens x 64 experts top-6
    scores = jax.random.normal(jax.random.fold_in(key, 5), (4096, 64))
    H = jax.random.uniform(jax.random.fold_in(key, 6), (64,)) * 0.3
    us_k = _time(lambda s, h: bp_topk_op(s, h, 6), scores, H)
    idx, w = bp_topk_op(scores, H, 6)
    ridx, rw = bp_topk_ref(scores, H, 6)
    ok = bool(jnp.all(idx == ridx))
    werr = float(jnp.max(jnp.abs(w - rw)))
    emit(f"kernels/bp_topk/interp,{us_k:.0f},exact_idx={ok};w_err={werr:.2e}")
    assert ok and werr < 1e-5
    kernels["bp_topk"] = {"us": us_k, "w_err": werr}

    # bp_slot — the fused slot-step decision at fleet pad dims and scaled
    _bench_bp_slot(key, emit, kernels, "fleet", E=45, NC=4, N=16)
    _bench_bp_slot(key, emit, kernels, "scaled", E=512, NC=16, N=128)
    return table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write the JSON table here")
    args = ap.parse_args()
    table = run(print)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
