"""Capacity atlas benchmark: the measured-vs-LP frontier, registry-wide.

Runs `fleet.atlas.sweep_lambda_max` over every scenario family in the
registry grid (paper_grid, random_geometric, ring, tree, expander,
fat_tree, wireless_grid, plus the GE-faded/comp-outage variants) at
ATLAS_SWEEP's (family x topo_seed) width: >= 100 (scenario x seed)
bisection lanes advanced by one padded chunk-step launch per policy
group (DESIGN.md §10).  Each cell bisects its own exact regulated LP
bound (`capacity_upper_bound(problem, rho0=1+eps_B)`) on the
rel_tol-quantized grid with `fold_seed`-decoupled probe streams — the
per-cell results are bit-identical to what sequential
`find_lambda_max` calls would return at the same PadDims
(tests/test_atlas.py asserts this on a mini-atlas).

The emitted table (`atlas_table`) carries per-family ratio medians of
lam_max / bound_exact, UNDECIDED-at-bracket-top counts (horizon-limited
localization, distinguished from proven-UNSTABLE evidence since the
frontier's `undecided` surfacing), and the fleet-level launch
accounting.  In-bench assertions enforce the acceptance gates —
ATLAS_BAND_FAMILIES medians inside ATLAS_RATIO_BAND, at most
ATLAS_MAX_PROGRAMS compiled programs with exactly one step compile
each, the ATLAS_MAX_LAUNCHES budget, and a >= ATLAS_MIN_SPEEDUP
launch-count reduction vs the sequential path — and
`scripts/check_bench.py --mode atlas` re-checks them against the
committed `BENCH_atlas.json` baseline.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python benchmarks/bench_atlas.py [--out BENCH_atlas.json] \
          [--stream-out ATLAS_stream.jsonl]
"""
from __future__ import annotations

import argparse
import json
import time

#: The atlas grid + search configuration.  T/chunk are calibrated so the
#: streaming verdict can latch well before the horizon (earliest decision
#: 6 windows = slot 3072; chunk < 256 leaves the burn-in inside the
#: gradient fill transient and misreads stable rates as UNSTABLE, and
#: T = 2048 leaves ring/tree cells UNDECIDED often enough to collapse
#: their brackets), rel_tol quantizes every probe to 5% of each cell's
#: own exact bound, and seeds=(0,) keeps one lane per cell — 9 families
#: x 12 topo_seeds = 108 bisection lanes.
ATLAS_SWEEP = dict(
    families=("paper_grid", "random_geometric", "ring", "tree", "expander",
              "fat_tree", "wireless_grid", "ge_grid", "ge_comp_grid"),
    topo_seeds=tuple(range(12)),
    policy="pi3", eps_b=0.05, seeds=(0,),
    T=4096, chunk=512, rel_tol=0.05, max_calls=12)

#: lam_max / bound_exact band for the *unfaded* families' per-family
#: ratio median (acceptance: the atlas localizes the exact LP bound from
#: below at this horizon).  Faded/outage families (GE link fading, comp
#: failures) are swept and reported but not banded — their effective
#: capacity sits below the static LP by the fading duty cycle — and so
#: is wireless_grid, whose interference constraint lives outside the
#: Theorem-4 LP entirely (measured ratio ~0.0-0.25: the atlas puts a
#: number on exactly that modeling gap).  Imported by
#: scripts/check_bench.py for the CI baseline gate.
ATLAS_RATIO_BAND = (0.90, 1.0)
ATLAS_BAND_FAMILIES = ("paper_grid", "random_geometric", "ring", "tree",
                       "expander", "fat_tree")

#: compiled-program ceiling: the whole atlas must fit in <= 4 policy
#: groups (here: 2 — wireless_grid forks the interference program family,
#: everything else shares one), each compiled exactly once.
ATLAS_MAX_PROGRAMS = 4

#: minimum (scenario x seed) bisection lanes the sweep must advance.
ATLAS_MIN_LANES = 100

#: chunk-step launch budget for the whole atlas, and the minimum
#: batching win vs per-cell sequential searches (seq_launches counts the
#: launches the per-cell `find_lambda_max` path would have issued).
ATLAS_MAX_LAUNCHES = 250
ATLAS_MIN_SPEEDUP = 5.0


def run(emit, stream_out: str | None = None) -> dict:
    """Run the atlas sweep, assert the gates, return the JSON table."""
    from repro.fleet import atlas_table, registry_cells, sweep_lambda_max

    c = dict(ATLAS_SWEEP)
    cells = registry_cells(c.pop("families"), c.pop("topo_seeds"),
                           policy=c.pop("policy"), eps_b=c.pop("eps_b"))
    t0 = time.time()
    res = sweep_lambda_max(cells, **c, stream_path=stream_out)
    wall = time.time() - t0

    table = atlas_table(res)
    table["wall_s"] = wall
    if res.stream_records:
        table["stream_records"] = len(res.stream_records)
    table["us_per_lane_slot"] = (1e6 * wall / res.total_slots
                                 if res.total_slots else 0.0)
    emit(f"fleet/atlas/sweep,{table['us_per_lane_slot']:.1f},"
         f"cells={res.n_cells} lanes={res.n_lanes} "
         f"programs={res.n_programs} launches={res.n_launches} "
         f"seq_launches={res.seq_launches} "
         f"speedup=x{res.launch_speedup:.1f} wall_s={wall:.1f}")

    lo, hi = ATLAS_RATIO_BAND
    for fam, row in table["families"].items():
        emit(f"fleet/atlas/{fam},,ratio_median={row['ratio_median']:.3f} "
             f"[{row['ratio_min']:.3f}, {row['ratio_max']:.3f}] "
             f"undecided_hi={row['n_undecided_hi']}/{row['n_cells']} "
             f"calls_mean={row['n_calls_mean']:.1f}")
        for cell in row["cells"]:
            assert cell["ratio"] <= 1.0 + 1e-9, (
                f"{fam}/ts{cell['topo_seed']}: measured lam_max "
                f"{cell['lam_max']:.3f} exceeds the exact LP bound "
                f"{cell['bound_exact']:.3f}")
    for fam in ATLAS_BAND_FAMILIES:
        med = table["families"][fam]["ratio_median"]
        assert lo <= med <= hi + 1e-9, (
            f"{fam}: ratio median {med:.3f} outside [{lo}, {hi}]")

    assert res.n_lanes >= ATLAS_MIN_LANES, (
        f"only {res.n_lanes} bisection lanes (need >= {ATLAS_MIN_LANES})")
    assert res.n_programs <= ATLAS_MAX_PROGRAMS, (
        f"{res.n_programs} compiled programs (ceiling {ATLAS_MAX_PROGRAMS})")
    assert res.n_step_compiles == res.n_programs, (
        f"{res.n_step_compiles} step compiles across {res.n_programs} "
        "policy groups (the bisection rewrites must not retrace)")
    assert res.n_launches <= ATLAS_MAX_LAUNCHES, (
        f"{res.n_launches} chunk launches (budget {ATLAS_MAX_LAUNCHES})")
    assert res.launch_speedup >= ATLAS_MIN_SPEEDUP, (
        f"launch speedup x{res.launch_speedup:.1f} < x{ATLAS_MIN_SPEEDUP}")
    return {"atlas": table}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write the JSON table here")
    ap.add_argument("--stream-out", default=None,
                    help="write per-launch telemetry records (JSONL, "
                    "repro.obs.schema) here while the sweep runs")
    args = ap.parse_args()
    table = run(print, stream_out=args.stream_out)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
        print(f"wrote {args.out}")
    if args.stream_out:
        print(f"wrote {args.stream_out} "
              f"({table['atlas'].get('stream_records', 0)} records)")


if __name__ == "__main__":
    main()
