"""Capacity atlas benchmark: the measured-vs-LP frontier at 10^3 scale.

Runs `fleet.atlas.sweep_lambda_max` over every scenario family in the
registry grid (paper_grid, random_geometric, ring, tree, expander,
fat_tree, wireless_grid, plus the GE-faded/comp-outage variants) at
ATLAS_SWEEP's (family x topo_seed) width — >= ATLAS_MIN_CELLS cells,
each replicated across ATLAS_SWEEP["seeds"] arrival seeds — with the
DESIGN.md §13 scaling levers on:

* **shape buckets** (`n_buckets`): cells are partitioned by (E, N, NC)
  quantiles and each (policy group x bucket) pair gets its own padded
  launch schedule and its own compiled program, so ring cells stop
  paying expander pad dims;
* **adaptive horizons** (`max_requeues`): any cell whose bracket top
  stays UNDECIDED — or whose bracket fully collapses, the signature of
  the low-rate gradient-fill transient reading as proven-UNSTABLE — is
  re-queued over its original bracket at a doubled horizon (one 2xT
  rung here; tests/test_atlas.py exercises the full 2xT-then-4xT
  ladder) — the bench asserts zero silently-collapsed brackets (a
  collapsed cell must have exhausted its re-queue budget, never
  skipped it);
* **seed bands**: per-family q10-q90 bands over the lam_max /
  bound_exact ratios, gated on width (a fat band means seed noise is
  setting the median).

Each cell bisects its own exact regulated LP bound
(`capacity_upper_bound(problem, rho0=1+eps_B)`) on the rel_tol-
quantized grid with `fold_seed`-decoupled probe streams — per-cell
results are bit-identical to what sequential `find_lambda_max` calls
would return at the same PadDims (tests/test_atlas.py asserts this per
bucket on a mini-atlas).  The LP side is deduplicated through the
fingerprint-keyed bounded cache (`report.exact_lam_star`): the bench
asserts solve count <= n_cells — deterministic families cost one solve
across all their topo_seeds.

In-bench assertions enforce the acceptance gates — ATLAS_BAND_FAMILIES
medians inside ATLAS_RATIO_BAND with band widths <=
ATLAS_MAX_BAND_WIDTH, at most ATLAS_MAX_PROGRAMS compiled programs
with exactly one step compile each, >= ATLAS_MIN_BUCKETS buckets whose
per-bucket launch ledger sums to the total within
ATLAS_MAX_BUCKET_LAUNCHES each, the ATLAS_MAX_LAUNCHES budget, and a
>= ATLAS_MIN_SPEEDUP launch-count reduction vs the sequential path —
and `scripts/check_bench.py --mode atlas` re-checks them against the
committed `BENCH_atlas.json` baseline.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python benchmarks/bench_atlas.py [--out BENCH_atlas.json] \
          [--stream-out ATLAS_stream.jsonl] [--preset full|ci]
"""
from __future__ import annotations

import argparse
import json
import time

#: The atlas grid + search configuration.  T=4096/chunk=512 keeps the
#: verdict discipline the original atlas calibrated (burn-in past the
#: gradient-fill transient — chunk < 512 misreads stable rates as
#: UNSTABLE; T < 4096 leaves ring/tree brackets collapsed at the base
#: horizon), and the single re-queue rung re-runs UNDECIDED-at-top
#: cells at 2xT = 8192 (DESIGN.md §13; ~70% of registry cells are
#: horizon-limited at the base horizon, so a second rung would re-run
#: most of the atlas at 4xT for little verdict gain — the 2-rung
#: ladder is exercised by tests/test_atlas.py instead).  rel_tol
#: quantizes every probe to 10% of each cell's own exact bound (the
#: band gates are stated on that grid); seeds=(0, 1, 2) replicates
#: every cell across arrival seeds for the band math.  9 families x 56
#: topo_seeds = 504 cells, 1512 bisection lanes, split into 3 shape
#: buckets (E<=14 / E=24 / E>24 at the registry's shape distribution).
ATLAS_SWEEP = dict(
    families=("paper_grid", "random_geometric", "ring", "tree", "expander",
              "fat_tree", "wireless_grid", "ge_grid", "ge_comp_grid"),
    topo_seeds=tuple(range(56)),
    policy="pi3", eps_b=0.05, seeds=(0, 1, 2),
    T=4096, chunk=512, rel_tol=0.1, max_calls=8,
    n_buckets=3, max_requeues=1)

#: presets: "full" is the committed-baseline scale above (~35 min on a
#: single core — regenerate BENCH_atlas.json with it out-of-band); "ci"
#: subsamples topo_seeds/seeds at the *same* horizon, chunk, bucketing
#: and re-queue discipline so every scaling lever still runs inside the
#: CI job budget.  The verdict calibration (T=4096/chunk=512) must not
#: differ between presets — a cheaper horizon would change the verdicts
#: themselves, not just the sample size.
ATLAS_PRESETS = {
    "full": ATLAS_SWEEP,
    "ci": dict(ATLAS_SWEEP, topo_seeds=tuple(range(12)), seeds=(0, 1)),
}

#: lam_max / bound_exact band for the *unfaded* families' per-family
#: ratio median (acceptance: the atlas localizes the exact LP bound from
#: below at this horizon).  Faded/outage families (GE link fading, comp
#: failures) are swept and reported but not banded — their effective
#: capacity sits below the static LP by the fading duty cycle — and so
#: is wireless_grid, whose interference constraint lives outside the
#: Theorem-4 LP entirely (measured ratio ~0.0-0.25: the atlas puts a
#: number on exactly that modeling gap).  Imported by
#: scripts/check_bench.py for the CI baseline gate.
ATLAS_RATIO_BAND = (0.90, 1.0)
ATLAS_BAND_FAMILIES = ("paper_grid", "random_geometric", "ring", "tree",
                       "expander", "fat_tree")

#: per-family q10-q90 band width ceiling on the banded families: seed
#: replication must tighten the surface, not smear it (DESIGN.md §13).
#: Two rel_tol grid steps — one step is the healthy spread, two flags a
#: decile of cells reading a whole extra step low.
ATLAS_MAX_BAND_WIDTH = 0.2

#: scale floors: (scenario x topo_seed) cells, (cell x seed) bisection
#: lanes, and the number of non-empty shape buckets.
ATLAS_MIN_CELLS = 500
ATLAS_MIN_LANES = 1500
ATLAS_MIN_BUCKETS = 2

#: compiled-program ceiling: one program per (policy group x bucket),
#: each compiled exactly once.  Here: 2 policy groups (wireless_grid
#: forks the interference program family) x 3 buckets = 6; the ceiling
#: leaves headroom for a bucket-count bump without a baseline edit.
ATLAS_MAX_PROGRAMS = 8

#: chunk-step launch budgets — total and per bucket (the re-queue
#: rung extends the busiest bucket, not the whole fleet) — and the
#: minimum batching win vs per-cell sequential searches (seq_launches
#: counts the launches the per-cell `find_lambda_max` path would have
#: issued).
ATLAS_MAX_LAUNCHES = 450
ATLAS_MAX_BUCKET_LAUNCHES = 200
ATLAS_MIN_SPEEDUP = 10.0

#: per-preset scale gates (the shared discipline gates — band widths,
#: program ceiling, compile-per-program, ledger-sums-to-total — are
#: preset-independent above).  Tables carry their preset in a "preset"
#: field so scripts/check_bench.py gates each table at its own scale.
ATLAS_GATES = {
    "full": dict(min_cells=ATLAS_MIN_CELLS, min_lanes=ATLAS_MIN_LANES,
                 max_launches=ATLAS_MAX_LAUNCHES,
                 max_bucket_launches=ATLAS_MAX_BUCKET_LAUNCHES,
                 min_speedup=ATLAS_MIN_SPEEDUP),
    "ci": dict(min_cells=100, min_lanes=200,
               max_launches=ATLAS_MAX_LAUNCHES,
               max_bucket_launches=ATLAS_MAX_BUCKET_LAUNCHES,
               min_speedup=5.0),
}


def run(emit, stream_out: str | None = None, preset: str = "full") -> dict:
    """Run the atlas sweep, assert the gates, return the JSON table."""
    from repro.fleet import (atlas_table, exact_lam_star, registry_cells,
                             sweep_lambda_max)

    c = dict(ATLAS_PRESETS[preset])
    gates = ATLAS_GATES[preset]
    max_requeues = c["max_requeues"]
    cells = registry_cells(c.pop("families"), c.pop("topo_seeds"),
                           policy=c.pop("policy"), eps_b=c.pop("eps_b"))
    exact_lam_star.cache_clear()
    t0 = time.time()
    res = sweep_lambda_max(cells, **c, stream_path=stream_out)
    wall = time.time() - t0

    # LP hygiene (DESIGN.md §13): the fingerprint-keyed cache dedupes
    # topo_seeds of deterministic families — one solve per *distinct*
    # padded problem, never more than one per cell.
    lp = exact_lam_star.cache_info()
    assert lp.misses <= res.n_cells, (
        f"{lp.misses} LP solves for {res.n_cells} cells "
        "(fingerprint dedup broken)")

    table = atlas_table(res)
    table["preset"] = preset
    table["wall_s"] = wall
    table["lp_solves"] = lp.misses
    if res.stream_records:
        table["stream_records"] = len(res.stream_records)
    table["us_per_lane_slot"] = (1e6 * wall / res.total_slots
                                 if res.total_slots else 0.0)
    emit(f"fleet/atlas/sweep,{table['us_per_lane_slot']:.1f},"
         f"cells={res.n_cells} lanes={res.n_lanes} "
         f"buckets={res.n_buckets} programs={res.n_programs} "
         f"launches={res.n_launches} requeues={res.n_requeues} "
         f"lp_solves={lp.misses} seq_launches={res.seq_launches} "
         f"speedup=x{res.launch_speedup:.1f} wall_s={wall:.1f}")
    for b in sorted(res.bucket_launches):
        d = res.bucket_dims[b]
        emit(f"fleet/atlas/bucket{b},,dims=({d.n_nodes},{d.n_edges},"
             f"{d.n_comp}) cells={res.bucket_cells.get(b, 0)} "
             f"launches={res.bucket_launches[b]}")

    lo, hi = ATLAS_RATIO_BAND
    for fam, row in table["families"].items():
        band = row["band"]
        emit(f"fleet/atlas/{fam},,ratio_median={row['ratio_median']:.3f} "
             f"band=[{band['q10']:.3f}, {band['q90']:.3f}] "
             f"(w={band['width']:.3f}) "
             f"undecided_hi={row['n_undecided_hi']}/{row['n_cells']} "
             f"requeued={row['n_requeued']} "
             f"calls_mean={row['n_calls_mean']:.1f}")
        for cell in row["cells"]:
            assert cell["ratio"] <= 1.0 + 1e-9, (
                f"{fam}/ts{cell['topo_seed']}: measured lam_max "
                f"{cell['lam_max']:.3f} exceeds the exact LP bound "
                f"{cell['bound_exact']:.3f}")
            # zero silently-collapsed brackets: ANY collapsed cell —
            # UNDECIDED-at-top or proven-UNSTABLE-at-bottom (the
            # low-rate gradient-fill artifact reads as the latter) —
            # must have burned its whole re-queue ladder first.
            if cell["lam_max"] == 0.0:
                assert cell["n_requeues"] == max_requeues, (
                    f"{fam}/ts{cell['topo_seed']}: collapsed bracket with "
                    f"only {cell['n_requeues']} re-queues (budget "
                    f"{max_requeues}) — silent collapse")
    for fam in ATLAS_BAND_FAMILIES:
        row = table["families"][fam]
        med, width = row["ratio_median"], row["band"]["width"]
        assert lo <= med <= hi + 1e-9, (
            f"{fam}: ratio median {med:.3f} outside [{lo}, {hi}]")
        assert width <= ATLAS_MAX_BAND_WIDTH + 1e-9, (
            f"{fam}: band width {width:.3f} > {ATLAS_MAX_BAND_WIDTH}")

    assert res.n_cells >= gates["min_cells"], (
        f"only {res.n_cells} cells (need >= {gates['min_cells']})")
    assert res.n_lanes >= gates["min_lanes"], (
        f"only {res.n_lanes} bisection lanes "
        f"(need >= {gates['min_lanes']})")
    assert res.n_buckets >= ATLAS_MIN_BUCKETS, (
        f"{res.n_buckets} shape buckets (need >= {ATLAS_MIN_BUCKETS})")
    assert res.n_programs <= ATLAS_MAX_PROGRAMS, (
        f"{res.n_programs} compiled programs (ceiling {ATLAS_MAX_PROGRAMS})")
    assert res.n_step_compiles == res.n_programs, (
        f"{res.n_step_compiles} step compiles across {res.n_programs} "
        "(policy group x bucket) programs (the bisection rewrites must "
        "not retrace)")
    assert sum(res.bucket_launches.values()) == res.n_launches, (
        res.bucket_launches, res.n_launches)
    for b, n in sorted(res.bucket_launches.items()):
        assert n <= gates["max_bucket_launches"], (
            f"bucket {b}: {n} launches "
            f"(budget {gates['max_bucket_launches']})")
    assert res.n_launches <= gates["max_launches"], (
        f"{res.n_launches} chunk launches "
        f"(budget {gates['max_launches']})")
    assert res.launch_speedup >= gates["min_speedup"], (
        f"launch speedup x{res.launch_speedup:.1f} "
        f"< x{gates['min_speedup']}")
    return {"atlas": table}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write the JSON table here")
    ap.add_argument("--stream-out", default=None,
                    help="write per-launch telemetry records (JSONL, "
                    "repro.obs.schema) here while the sweep runs")
    ap.add_argument("--preset", default="full",
                    choices=sorted(ATLAS_PRESETS),
                    help="'full' regenerates the committed baseline scale; "
                    "'ci' subsamples topo_seeds/seeds at the same horizon "
                    "so the gate fits the CI job budget")
    args = ap.parse_args()
    table = run(print, stream_out=args.stream_out, preset=args.preset)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
        print(f"wrote {args.out}")
    if args.stream_out:
        print(f"wrote {args.stream_out} "
              f"({table['atlas'].get('stream_records', 0)} records)")


if __name__ == "__main__":
    main()
