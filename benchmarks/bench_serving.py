"""Serving-dispatch benchmark: backpressure (paper eq. 9) vs round-robin vs
join-shortest-queue, under a straggling replica and heterogeneous capacity
— the regimes where backlog-aware dispatch matters.
"""
from __future__ import annotations

import time

from repro.serving import simulate


def run(emit) -> dict:
    out = {}
    for scenario, kw in (("uniform", {}),
                         ("straggler", {"straggler": 2}),
                         ("hetero", {"hetero": True})):
        for policy in ("rr", "jsq", "bp"):
            t0 = time.time()
            r = simulate(policy, ticks=3000, load=0.9, seed=5, **kw)
            us = (time.time() - t0) / 3000 * 1e6
            emit(f"serving/{scenario}/{policy},{us:.1f},"
                 f"completed={r['completed']};p50={r['p50']:.0f};"
                 f"p99={r['p99']:.0f};mean={r['mean']:.1f};"
                 f"backlog={r['residual_backlog']:.0f}")
            out[(scenario, policy)] = r
        # backpressure must dominate RR on tail latency when skewed
        if scenario != "uniform":
            assert out[(scenario, "bp")]["p99"] <= out[(scenario, "rr")]["p99"]
    return out


if __name__ == "__main__":
    run(print)
