"""Serving benchmark: trace-driven admission control vs the exact LP bound.

Runs the serving subsystem (DESIGN.md §9) — markov_onoff bursty query
traces through the admission gate into the backpressure network — on the
paper's 4x4 grid under pi3_reg and scores delivered QPS against the
*exact* regulated LP bound `policy_bound_exact` (DESIGN.md §6):

* at 0.95 x bound offered load the gate must stay open (no shedding,
  `delivered_qps / bound >= SERVING_MIN_RATIO`) with bounded p99 sojourn;
* at SERVING_OVERLOAD_FRAC x bound the gate must duty-cycle: shed at
  least SERVING_OVERLOAD_MIN_SHED of the offered mass while the admitted
  rate stays at or below capacity — graceful degradation, not collapse.

A `parity` section replays a small sweep under both slot-decision
backends (XLA oracle vs the fused Pallas slot kernels, DESIGN.md §7) and
requires bit-exact agreement on every serving metric — the admission +
load-balance decision path must not fork per backend.

Per-chunk stream records (windowed QPS / shed / p99 / verdict medians)
are emitted as JSONL via --stream-out.  `scripts/check_bench.py --mode
serving` gates committed baselines (`BENCH_baseline.json`, key
`"serving"`) against regressions.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python benchmarks/bench_serving.py --out BENCH_serving.json \
          --stream-out SERVING_stream.jsonl
"""
from __future__ import annotations

import argparse
import json
import time

#: The gated smoke sweep: bursty (markov_onoff) queries on the paper grid
#: under the regulated pi3 policy, one nominal-load row and one overload
#: row, scored against the exact regulated LP bound.  T spans 8 chunks =
#: 8 admission windows (burn-in is the first 2).
SERVING_SMOKE = dict(scenario="paper_grid", policy="pi3_reg",
                     trace="bursty", rate_fracs=(0.95, 1.3),
                     seeds=(0, 1), T=4096, chunk=512, eps_b=0.05)

#: Nominal-load row (rate_frac 0.95) acceptance gates.  Single source of
#: truth: asserted on every bench run and imported by
#: scripts/check_bench.py for the CI baseline gate.
SERVING_MIN_RATIO = 0.9      # delivered_qps / bound_exact floor
SERVING_MAX_SHED = 0.02      # shed fraction ceiling (gate must stay open)
SERVING_P99_MAX = 512.0      # p99 sojourn ceiling, slots (observed ~280)

#: Overload row (rate_frac SERVING_OVERLOAD_FRAC) gates: the gate must
#: actually shed, and the admitted rate must not exceed capacity by more
#: than slack (windowed admission can transiently overshoot the bound).
SERVING_OVERLOAD_FRAC = 1.3
SERVING_OVERLOAD_MIN_SHED = 0.10
SERVING_OVERLOAD_RATE_SLACK = 1.05

#: Backend-parity sweep: the same serving jobs under the XLA oracle and
#: the fused Pallas slot kernels (interpret mode on CPU) must agree
#: bit-exactly on every finalize leaf.
SERVING_PARITY = dict(scenario="paper_grid", policy="pi3_reg",
                      trace="bursty", rate_frac=0.95, n_jobs=4,
                      T=1024, chunk=256, eps_b=0.05)


def parity_section(emit) -> dict:
    """Replay SERVING_PARITY under both backends; gate bit-exact parity.

    Each backend gets a warm-up run (compilation) and a timed run; the
    parity diff is the max |xla - pallas| over every metric leaf of every
    job — the DESIGN.md §7 contract extended to the serving decision path
    (trace draw + admission gate + bp_slot + latency stamps)."""
    import numpy as np
    from repro.fleet.report import policy_bound_exact
    from repro.serving import ServingJob, run_serving

    c = SERVING_PARITY
    bound = policy_bound_exact(c["scenario"], c["policy"], c["eps_b"], 0)
    out: dict = {}
    metrics = {}
    for backend in ("xla", "pallas"):
        jobs = [ServingJob(scenario=c["scenario"], policy=c["policy"],
                           trace=c["trace"], lam=c["rate_frac"] * bound,
                           seed=s, eps_b=c["eps_b"], backend=backend,
                           interpret=True)
                for s in range(c["n_jobs"])]
        run_serving(jobs, T=c["T"], chunk=c["chunk"])        # warm-up
        t0 = time.time()
        res = run_serving(jobs, T=c["T"], chunk=c["chunk"])
        wall = time.time() - t0
        metrics[backend] = res.metrics
        out[backend] = {"us_per_sim": wall * 1e6 / len(jobs),
                        "wall_s": wall, "n_sims": len(jobs), "T": res.T}
        emit(f"serving/parity/{backend},{out[backend]['us_per_sim']:.0f},"
             f"n_sims={len(jobs)} T={res.T}")
    diff = 0.0
    for mx, mp in zip(metrics["xla"], metrics["pallas"]):
        for k in mx:
            d = float(np.max(np.abs(np.asarray(mx[k]) - np.asarray(mp[k]))))
            diff = max(diff, d)
    out["parity_max_abs_diff"] = diff
    emit(f"serving/parity/diff,,max_abs_diff={diff}")
    assert diff == 0.0, (
        f"pallas serving path diverged from xla by {diff} (DESIGN.md §7/§9)")
    return out


def run(emit) -> dict:
    """Run the gated serving smoke + parity; returns the bench table."""
    from repro.serving import serving_report, write_stream_jsonl

    t0 = time.time()
    rep = serving_report(**SERVING_SMOKE, stream=True)
    wall = time.time() - t0
    result = rep.pop("result")
    table: dict = {"serving": rep}
    rep["stream_records"] = len(result.stream_records)
    table["us_per_sim"] = wall * 1e6 / max(rep["n_sims"], 1)
    table["wall_s"] = wall
    run.stream_records = result.stream_records   # for main()'s JSONL writer
    run.write_stream_jsonl = write_stream_jsonl

    bound = rep["bound_exact"]
    for frac, row in rep["rows"].items():
        emit(f"serving/smoke/{frac},,offered={row['offered']:.3f} "
             f"qps={row['delivered_qps']:.3f} "
             f"ratio={row['delivered_over_bound']:.3f} "
             f"shed={row['shed_frac']:.3f} p99={row['p99_sojourn']:.0f} "
             f"flips={row['gate_flips']:.0f} "
             f"open={row['gate_open_frac']:.3f}")

    nom = rep["rows"]["0.95"]
    assert nom["delivered_over_bound"] >= SERVING_MIN_RATIO, (
        f"0.95-load delivered/bound {nom['delivered_over_bound']:.3f} < "
        f"{SERVING_MIN_RATIO} (bound_exact={bound:.3f})")
    assert nom["shed_frac_max"] <= SERVING_MAX_SHED, (
        f"0.95-load shed_frac {nom['shed_frac_max']:.3f} > "
        f"{SERVING_MAX_SHED}: the gate shed under nominal load")
    assert nom["p99_sojourn_max"] <= SERVING_P99_MAX, (
        f"0.95-load p99 sojourn {nom['p99_sojourn_max']:.0f} slots > "
        f"{SERVING_P99_MAX}")

    over = rep["rows"][f"{SERVING_OVERLOAD_FRAC:g}"]
    assert over["shed_frac"] >= SERVING_OVERLOAD_MIN_SHED, (
        f"overload shed_frac {over['shed_frac']:.3f} < "
        f"{SERVING_OVERLOAD_MIN_SHED}: the gate failed to shed at "
        f"{SERVING_OVERLOAD_FRAC}x the bound")
    assert over["admitted_rate"] <= bound * SERVING_OVERLOAD_RATE_SLACK, (
        f"overload admitted_rate {over['admitted_rate']:.3f} > bound "
        f"{bound:.3f} x {SERVING_OVERLOAD_RATE_SLACK}")
    emit(f"serving/smoke/gates,,ratio>={SERVING_MIN_RATIO} "
         f"shed<={SERVING_MAX_SHED} p99<={SERVING_P99_MAX:.0f} "
         f"overload_shed>={SERVING_OVERLOAD_MIN_SHED}: pass")

    rep["parity"] = parity_section(emit)
    emit(f"serving/sweep,{table['us_per_sim']:.0f},"
         f"n_sims={rep['n_sims']} wall_s={wall:.1f} "
         f"stream_records={rep['stream_records']}")
    return table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write the JSON table here")
    ap.add_argument("--stream-out", default=None,
                    help="write per-chunk stream records as JSONL here")
    args = ap.parse_args()
    table = run(print)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
        print(f"wrote {args.out}")
    if args.stream_out:
        n = run.write_stream_jsonl(run.stream_records, args.stream_out)
        print(f"wrote {args.stream_out} ({n} records)")


if __name__ == "__main__":
    main()
