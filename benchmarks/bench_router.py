"""MoE load-balance benchmark: backpressure router (paper eq. 9/10 mapped to
experts) vs aux-loss vs plain top-k under skewed gate distributions.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.router import (RouterConfig, init_router_state, route,
                               load_violation)

E, T, K, STEPS = 64, 1024, 6, 40   # moonshot-like: 64 experts top-6


def run(emit) -> dict:
    key = jax.random.key(0)
    base = jax.random.normal(key, (T, E)) * 0.5
    skew = jnp.zeros((E,)).at[:4].add(3.0)     # 4 hot experts
    out = {}
    for mode, beta in (("plain", 0.0), ("aux", 0.0), ("backpressure", 2.0)):
        cfg = RouterConfig(n_experts=E, k=K, mode=mode, beta=beta)
        state = init_router_state(E)
        step = jax.jit(lambda s, l: route(cfg, s, l))
        loads = []
        t0 = time.time()
        for i in range(STEPS):
            logits = base + skew[None, :] + \
                0.1 * jax.random.normal(jax.random.fold_in(key, i), (T, E))
            r = step(state, logits)
            state = r.new_state
            loads.append(r.load)
        dt = (time.time() - t0) / STEPS * 1e6
        v = float(load_violation(jnp.stack(loads[-10:]).mean(0)))
        emit(f"router/{mode},{dt:.1f},load_violation={v:.3f}")
        out[mode] = v
    assert out["backpressure"] < out["plain"]
    return out


if __name__ == "__main__":
    run(print)
