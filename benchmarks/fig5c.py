"""Fig. 5(c): running averages of query rate, per-embedding allocations and
computations for one pi3 run at C=2, lambda=6 (an achievable rate).

The paper's claim: the long-run average computation rate matches the average
query demand, and load balancing splits queries across the 4 embeddings.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PolicyConfig, paper_grid_problem
from repro.sim import simulate

T = 4000
LAM = 6.0


def run(emit) -> dict:
    p = paper_grid_problem(C=2.0)
    t0 = time.time()
    res = simulate(p, PolicyConfig(name="pi3", eps_b=0.01), LAM, T=T, seed=11)
    us = (time.time() - t0) / T * 1e6

    comp = np.asarray(res.computed)
    nstar = np.asarray(res.n_star)
    t_axis = np.arange(1, T + 1)
    run_comp = np.cumsum(comp) / t_axis
    emit(f"# fig5c C=2 lam={LAM}: running averages (paper: comp -> lam)")
    for t in (500, 1000, 2000, 4000):
        emit(f"fig5c/run_avg_computations/t{t},{us:.2f},value={run_comp[t-1]:.3f}")
    shares = np.bincount(nstar, minlength=4) / T
    for i, s in enumerate(shares):
        emit(f"fig5c/embedding_share/node{i},{us:.2f},share={s:.3f}")
    # final computation rate must match demand (paper's convergence claim)
    assert abs(run_comp[-1] - LAM) < 0.4, run_comp[-1]
    return {"run_comp": run_comp, "shares": shares}


if __name__ == "__main__":
    run(print)
