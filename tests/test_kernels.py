"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
asserting allclose against the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention_op, attention_ref
from repro.kernels.bp_route.ops import bp_route_op, bp_route_ref
from repro.kernels.bp_topk.ops import bp_topk_op, bp_topk_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, H, KH, S, T, D, causal, window, dtype)
    (2, 4, 2, 128, 128, 64, True, None, jnp.float32),
    (1, 4, 4, 256, 256, 32, True, 64, jnp.float32),
    (2, 2, 1, 128, 256, 64, False, None, jnp.float32),
    (1, 8, 2, 128, 128, 128, True, None, jnp.bfloat16),
    (1, 2, 2, 64, 64, 16, True, 16, jnp.float32),
    (1, 1, 1, 512, 512, 64, True, 128, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_allclose(case):
    B, H, KH, S, T, D, causal, window, dtype = case
    key = jax.random.key(42)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, KH, T, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, KH, T, D), dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_invariance():
    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 2, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    outs = [np.asarray(flash_attention_op(q, k, v, block_q=bq, block_k=bk))
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(s_blocks=st.integers(1, 4), d=st.sampled_from([32, 64]),
       causal=st.booleans(), seed=st.integers(0, 100))
def test_flash_attention_property(s_blocks, d, causal, seed):
    S = 64 * s_blocks
    key = jax.random.key(seed)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, S, d))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 2, S, d))
    out = flash_attention_op(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# bp_route
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,N", [(24, 12, 16), (300, 48, 64), (7, 3, 5),
                                   (1024, 96, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bp_route_allclose(E, C, N, dtype):
    key = jax.random.key(1)
    Q = (jax.random.uniform(key, (N, C)) * 100).astype(dtype)
    edges = jax.random.randint(jax.random.fold_in(key, 1), (E, 2), 0, N)
    # avoid self loops
    edges = edges.at[:, 1].set((edges[:, 1] + 1 + edges[:, 0]) % N)
    cap = jax.random.uniform(jax.random.fold_in(key, 2), (E,)) * 10
    cls, rate, dirn = bp_route_op(Q, edges, cap)
    rcls, rrate, rdirn = bp_route_ref(Q[edges[:, 0]], Q[edges[:, 1]], cap)
    np.testing.assert_array_equal(np.asarray(cls), np.asarray(rcls))
    np.testing.assert_allclose(np.asarray(rate), np.asarray(rrate), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(dirn), np.asarray(rdirn))


def test_bp_route_zero_diff_no_rate():
    Q = jnp.ones((4, 6)) * 3.0
    edges = jnp.array([[0, 1], [2, 3]])
    cap = jnp.array([5.0, 5.0])
    _, rate, _ = bp_route_op(Q, edges, cap)
    np.testing.assert_allclose(np.asarray(rate), 0.0)


@settings(max_examples=10, deadline=None)
@given(e=st.integers(1, 60), c=st.integers(1, 30), seed=st.integers(0, 999))
def test_bp_route_property(e, c, seed):
    key = jax.random.key(seed)
    qm = jax.random.uniform(jax.random.fold_in(key, 1), (e, c)) * 50
    ql = jax.random.uniform(jax.random.fold_in(key, 2), (e, c)) * 50
    cap = jnp.ones((e,)) * 2.5
    from repro.kernels.bp_route.kernel import bp_route_decide
    cls, rate, dirn = bp_route_decide(qm, ql, cap, block_e=16)
    rcls, rrate, rdirn = bp_route_ref(qm, ql, cap)
    np.testing.assert_array_equal(np.asarray(cls), np.asarray(rcls))
    np.testing.assert_array_equal(np.asarray(dirn), np.asarray(rdirn))
    # the chosen class really is the max |differential backlog|
    diff = np.abs(np.asarray(qm) - np.asarray(ql))
    np.testing.assert_allclose(diff[np.arange(e), np.asarray(cls)],
                               diff.max(axis=1), rtol=1e-6)


# ---------------------------------------------------------------------------
# bp_topk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,E,k", [(64, 8, 2), (256, 64, 6), (100, 32, 8),
                                   (512, 16, 1)])
def test_bp_topk_allclose(T, E, k):
    key = jax.random.key(2)
    scores = jax.random.normal(key, (T, E))
    H = jax.random.uniform(jax.random.fold_in(key, 1), (E,)) * 0.5
    idx, w = bp_topk_op(scores, H, k, block_t=64)
    ridx, rw = bp_topk_ref(scores, H, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw),
                               rtol=1e-5, atol=1e-6)


def test_bp_topk_weights_normalized_and_bias_steers():
    T, E, k = 128, 16, 4
    scores = jax.random.normal(jax.random.key(3), (T, E))
    zero_bias = jnp.zeros((E,))
    idx0, w0 = bp_topk_op(scores, zero_bias, k)
    np.testing.assert_allclose(np.asarray(w0.sum(axis=1)), 1.0, atol=1e-5)
    # huge bias on expert 0 bans it from selection
    ban = jnp.zeros((E,)).at[0].set(1e6)
    idx1, _ = bp_topk_op(scores, ban, k)
    assert not np.any(np.asarray(idx1) == 0)


@settings(max_examples=8, deadline=None)
@given(t=st.integers(1, 80), e=st.sampled_from([8, 16, 64]),
       k=st.integers(1, 6), seed=st.integers(0, 99))
def test_bp_topk_property(t, e, k, seed):
    k = min(k, e)
    scores = jax.random.normal(jax.random.key(seed), (t, e))
    H = jax.random.uniform(jax.random.key(seed + 1), (e,))
    idx, w = bp_topk_op(scores, H, k, block_t=32)
    ridx, rw = bp_topk_ref(scores, H, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# integration: kernel-backed MoE routing and banded window attention
# ---------------------------------------------------------------------------

def test_kernel_backed_moe_routing_parity():
    """bp_topk kernel in the real MoE router path == einsum path."""
    from repro.configs import get_config, reduced
    from repro.core.router import RouterState
    from repro.models.common import Init, split_tree
    from repro.models.moe import _route, init_moe

    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    x = jax.random.normal(jax.random.key(0), (2, 16, cfg.d_model))
    p, _ = split_tree(init_moe(cfg, Init(key=jax.random.key(1))))
    rs = RouterState(H=jnp.arange(cfg.n_experts, dtype=jnp.float32),
                     steps=jnp.zeros((), jnp.int32))
    a = _route(cfg, p, x, rs, use_kernel=False)
    b = _route(cfg, p, x, rs, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("S,window", [(37, 8), (64, 16), (128, 32), (16, 16)])
def test_banded_window_attention_allclose(S, window):
    from repro.models.attention import sdpa, sdpa_banded, _mask
    key = jax.random.key(7)
    q = jax.random.normal(jax.random.fold_in(key, 1), (2, S, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (2, S, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 3), (2, S, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (2, S))
    ref = sdpa(q, k, v, _mask(pos, pos, causal=True, window=window))
    out = sdpa_banded(q, k, v, pos, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(nblocks=st.integers(1, 5), window=st.sampled_from([8, 16]),
       seed=st.integers(0, 99))
def test_chunked_attention_property(nblocks, window, seed):
    from repro.models.attention import sdpa, sdpa_chunked, _mask
    S = 16 * nblocks + 3
    key = jax.random.key(seed)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, S, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
    ref = sdpa(q, k, v, _mask(pos, pos, causal=True, window=window))
    out = sdpa_chunked(q, k, v, pos, pos, causal=True, window=window,
                       chunk_q=16, chunk_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
