"""Frontier subsystem tests (DESIGN.md §8): the streaming stability verdict
as a pure unit, early-stop bit-equality against full runs, the golden
`find_lambda_max` bracket on the paper grid, bisection compile accounting,
and the (topo_seed, rate_index, call_index) seed-fold regression."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # property tests widen coverage when hypothesis exists;
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # the deterministic grid always runs
    HAVE_HYPOTHESIS = False

from repro.core.queues import (DriftStats, VERDICT_NAMES, VERDICT_STABLE,
                               VERDICT_UNDECIDED, VERDICT_UNSTABLE,
                               drift_verdict_update)
from repro.fleet import (FleetJob, VerdictConfig, find_lambda_max, fold_seed,
                         get_scenario, policy_bound_exact, run_fleet,
                         stream_simulate)
from repro.sim.workload import poisson_arrivals

# Verdict parameters shared by the unit tests: window 50, anchor at 100,
# three agreeing boundaries latch a verdict.
_VP = dict(window=50, burn_in=100, k_stable=3, k_unstable=3,
           drift_tol=0.02, gap_tol=0.05)


@functools.partial(jax.jit, static_argnames=tuple(_VP))
def _run_trace(qs, useful, lam, **vp):
    """Feed a synthetic (backlog, cumulative-useful) trace through the
    pure per-slot verdict update and return the final DriftStats."""

    def body(d, x):
        t, q, u = x
        return drift_verdict_update(d, t, q, u, lam, **vp), None

    T = qs.shape[0]
    xs = (jnp.arange(T, dtype=jnp.int32), qs.astype(jnp.float32),
          useful.astype(jnp.float32))
    d, _ = jax.lax.scan(body, DriftStats.zero(), xs)
    return d


def _mm1_trace(drift: float, lam: float, T: int, seed: int):
    """M/M/1-like synthetic totals: a backlog random walk with the given
    per-slot drift (reflected at 0) and the matching cumulative useful
    deliveries — undelivered work is what accumulates as backlog, so the
    delivered rate is lam - max(drift, 0)."""
    rng = np.random.default_rng(seed)
    steps = drift + rng.normal(0.0, np.sqrt(max(lam, 1.0)), size=T)
    q = np.zeros(T, np.float32)
    level = 10.0                                 # small initial fill
    for t in range(T):
        level = max(level + steps[t], 0.0)
        q[t] = level
    rate = lam - max(drift, 0.0)
    useful = np.cumsum(np.full(T, rate, np.float32)
                       + rng.normal(0.0, 0.1, size=T).astype(np.float32))
    return jnp.asarray(q), jnp.asarray(useful)


class TestVerdictUnit:
    def _verdict(self, drift, lam, seed, T=1200):
        qs, useful = _mm1_trace(drift, lam, T, seed)
        return _run_trace(qs, useful, jnp.float32(lam), **_VP)

    @pytest.mark.parametrize("drift,lam,seed", [
        (-0.5, 4.0, 0), (-0.1, 2.0, 1), (-1.0, 8.0, 2), (-0.2, 6.0, 3)])
    def test_negative_drift_eventually_stable(self, drift, lam, seed):
        d = self._verdict(drift, lam, seed)
        assert int(d.verdict) == VERDICT_STABLE, VERDICT_NAMES[int(d.verdict)]
        assert int(d.decided_at) >= _VP["burn_in"] + 2 * _VP["window"]

    @pytest.mark.parametrize("drift,lam,seed", [
        (1.0, 4.0, 0), (0.8, 2.0, 1), (2.0, 8.0, 2), (1.5, 6.0, 3)])
    def test_positive_drift_eventually_unstable(self, drift, lam, seed):
        d = self._verdict(drift, lam, seed)
        assert int(d.verdict) == VERDICT_UNSTABLE, \
            VERDICT_NAMES[int(d.verdict)]

    if HAVE_HYPOTHESIS:
        @settings(max_examples=20, deadline=None)
        @given(drift=st.floats(-2.0, 2.0).filter(lambda x: abs(x) >= 0.5),
               lam=st.floats(1.0, 10.0), seed=st.integers(0, 2 ** 16))
        def test_property_drift_sign_decides(self, drift, lam, seed):
            """Any M/M/1-like trace with clearly negative drift latches
            STABLE, clearly positive drift latches UNSTABLE."""
            d = self._verdict(drift, lam, seed)
            want = VERDICT_STABLE if drift < 0 else VERDICT_UNSTABLE
            assert int(d.verdict) == want, (
                f"drift={drift} lam={lam} -> {VERDICT_NAMES[int(d.verdict)]}")

    def test_undecided_near_boundary_never_flips_after_latching(self):
        """Regression: a verdict latched at decided_at must never change,
        even when later windows carry opposite evidence (the scenario of a
        near-boundary sim whose batch keeps running)."""
        lam, T = 4.0, 2000
        qs_stable, useful_stable = _mm1_trace(-0.5, lam, T, seed=7)
        qs_unst, useful_unst = _mm1_trace(1.5, lam, T, seed=7)
        # stable first half, violently unstable second half
        qs = jnp.concatenate([qs_stable[:T // 2],
                              qs_stable[T // 2 - 1] + qs_unst[:T // 2]])
        useful = jnp.concatenate([
            useful_stable[:T // 2],
            useful_stable[T // 2 - 1] + useful_unst[:T // 2]])
        d = _run_trace(qs, useful, jnp.float32(lam), **_VP)
        # latched STABLE during the first half and stayed latched
        assert int(d.verdict) == VERDICT_STABLE
        assert int(d.decided_at) <= T // 2
        # and with the halves swapped, UNSTABLE latches and survives calm
        qs2 = jnp.concatenate([qs_unst[:T // 2],
                               qs_unst[T // 2 - 1] + qs_stable[:T // 2]])
        useful2 = jnp.concatenate([
            useful_unst[:T // 2],
            useful_unst[T // 2 - 1] + useful_stable[:T // 2]])
        d2 = _run_trace(qs2, useful2, jnp.float32(lam), **_VP)
        assert int(d2.verdict) == VERDICT_UNSTABLE
        assert int(d2.decided_at) <= T // 2

    def test_borderline_trace_stays_undecided(self):
        """A trace living between the stable and unstable bars (drift just
        above tolerance, gap just below) must not latch either way."""
        lam, T = 4.0, 1500
        rng = np.random.default_rng(0)
        # drift ~ 3x drift_tol*scale but gap ~ 0: growing backlog with
        # full delivery — fails both the stable and the unstable test
        q = np.cumsum(np.full(T, 3 * _VP["drift_tol"] * lam)
                      + rng.normal(0, 0.01, T)).astype(np.float32)
        useful = np.cumsum(np.full(T, lam, np.float32))
        d = _run_trace(jnp.asarray(q), jnp.asarray(useful),
                       jnp.float32(lam), **_VP)
        assert int(d.verdict) == VERDICT_UNDECIDED
        assert int(d.decided_at) == 0


# ---------------------------------------------------------------------------
# Early-stop correctness: freezing is bit-exact, bisection is launch-only
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestEarlyStopCorrectness:
    def test_frozen_metrics_bit_equal_full_run_at_decided_slot(self):
        """A sim frozen at decided_at inside an early-stopped batch must
        report exactly the metrics of the same sim run (without early
        stopping) for a horizon of decided_at slots: the freeze mask is a
        bit-exact pin, not an approximation."""
        scenario, lam, seed, chunk = "paper_grid", 10.0, 3, 128
        job = FleetJob(scenario=scenario, policy="pi3", lam=lam, eps_b=0.05,
                       seed=seed)
        res = run_fleet([job], T=2048, chunk=chunk, early_stop=True)
        m = res.metrics[0]
        s = int(m["decided_at_slot"])
        assert m["verdict"] != float(VERDICT_UNDECIDED), m
        assert 0 < s < 2048 and s % chunk == 0
        assert m["slots_saved"] == 2048 - s
        # reference: the plain streaming path, horizon exactly s, no freeze
        ref = stream_simulate(get_scenario(scenario).build(0),
                              job.policy_config(), lam, T=s, chunk=chunk,
                              seed=seed)
        for k in ("delivered", "delivered_useful", "delivered_dummy",
                  "max_queue", "mean_queue"):
            assert m[k] == float(ref[k]), (k, m[k], float(ref[k]))

    def test_undecided_sims_match_plain_run_exactly(self):
        """Sims that never decide ride the early-stopped batch to the full
        horizon and must equal a plain run bitwise (where(False, old, new)
        is `new`)."""
        job = FleetJob(scenario="paper_grid", policy="pi3bar", lam=7.9,
                       seed=0)
        a = run_fleet([job], T=1024, chunk=128, early_stop=True)
        b = run_fleet([job], T=1024, chunk=128, early_stop=False)
        if a.verdicts()[0] == "UNDECIDED":
            for k in ("useful_rate", "delivered", "mean_queue", "max_queue"):
                assert a.metrics[0][k] == b.metrics[0][k], k
        # decided or not, the state-level counters never diverge before
        # the decision slot; delivered totals of the plain run are >= the
        # frozen run's (frozen sims stop accumulating)
        assert b.metrics[0]["delivered"] >= a.metrics[0]["delivered"]

    def test_bisection_reuses_cached_compiled_program(self):
        """TestNoRecompilation, frontier edition: after the first launch,
        every bisection step must be launch-only — one compiled chunk-step
        program across all probes (memoized runner + group launch)."""
        r = find_lambda_max("paper_grid", "pi3", eps_b=0.051937,
                            seeds=(0,), T=768, chunk=128, rel_tol=0.1,
                            max_calls=10)
        assert r.n_calls >= 3                  # bracket + >= 1 bisection
        assert r.n_step_compiles == 1, (
            f"bisection retraced: {r.n_step_compiles} chunk-step programs")


# ---------------------------------------------------------------------------
# Golden frontier: paper grid, pi3, exact-bound bracket + invariance
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestGoldenFrontier:
    KW = dict(eps_b=0.05, seeds=(0, 1), T=4096, chunk=256, rel_tol=0.025)

    def test_paper_grid_brackets_exact_bound(self):
        bound = policy_bound_exact("paper_grid", "pi3", 0.05)
        r = find_lambda_max("paper_grid", "pi3", **self.KW)
        assert r.bound_exact == pytest.approx(bound)
        assert r.lam_max <= bound * (1 + 1e-9)
        assert r.lam_max >= 0.9 * bound, (
            f"lam_max {r.lam_max:.3f} < 0.9 * bound {bound:.3f}")
        assert r.hi - r.lo == pytest.approx(self.KW["rel_tol"] * bound)
        assert r.slots_saved_frac > 0.0 and r.launch_slots_saved > 0

    def test_invariant_to_initial_bracket(self):
        """First probes of a grid index always draw the same folded seeds
        (call_index 0), so two searches from different brackets land on
        the same quantized lam_max exactly."""
        r1 = find_lambda_max("paper_grid", "pi3", **self.KW,
                             bracket=(0.5, 1.1))
        r2 = find_lambda_max("paper_grid", "pi3", **self.KW,
                             bracket=(0.6, 1.05))
        assert r1.lam_max == r2.lam_max
        assert r1.ratio == r2.ratio


# ---------------------------------------------------------------------------
# Seed decoupling: the (topo_seed, rate_index, call_index) fold
# ---------------------------------------------------------------------------

class TestSeedDecoupling:
    def test_fold_seed_decouples_every_axis(self):
        base = fold_seed(0, 3, 0, 0)
        assert base == fold_seed(0, 3, 0, 0)      # deterministic
        assert base != fold_seed(0, 4, 0, 0)      # rate_index
        assert base != fold_seed(0, 3, 1, 0)      # call_index (re-probe)
        assert base != fold_seed(1, 3, 0, 0)      # topo_seed
        assert base != fold_seed(0, 3, 0, 1)      # per-probe seed
        seen = {fold_seed(t, k, c, s) for t in range(3) for k in range(12)
                for c in range(2) for s in range(4)}
        assert len(seen) == 3 * 12 * 2 * 4        # no collisions on the grid
        assert all(0 <= s < 2 ** 31 for s in seen)

    def test_bisection_steps_never_share_arrival_streams(self):
        """Regression for the latent seed-coupling hazard: two bisection
        probes at different rates must not draw the same uniforms — with
        the raw job seed they would (PRNGKey(seed) ignores lam), coupling
        the noise at every probed rate."""
        T = 256
        # the hazard: two probes reusing the raw job seed start from the
        # *same* PRNGKey, so every derived stream coincides slot-for-slot
        uh = poisson_arrivals(jax.random.PRNGKey(0), 5.0, T)
        vh = poisson_arrivals(jax.random.PRNGKey(0), 5.0, T)
        assert np.array_equal(np.asarray(uh), np.asarray(vh))
        # the fix: rate_index enters the fold, streams decouple
        s_lo = fold_seed(0, rate_index=20, call_index=0, seed=0)
        s_hi = fold_seed(0, rate_index=32, call_index=0, seed=0)
        u = poisson_arrivals(jax.random.PRNGKey(s_lo), 5.0, T)
        v = poisson_arrivals(jax.random.PRNGKey(s_hi), 5.0, T)
        assert not np.array_equal(np.asarray(u), np.asarray(v))
        # and a re-probe of the same rate draws fresh noise
        s_again = fold_seed(0, rate_index=20, call_index=1, seed=0)
        w = poisson_arrivals(jax.random.PRNGKey(s_again), 5.0, T)
        assert not np.array_equal(np.asarray(u), np.asarray(w))


# ---------------------------------------------------------------------------
# Verdict metrics through the engine (no early stop: reporting only)
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestFleetVerdictMetrics:
    def test_rows_gain_verdict_fields(self):
        jobs = [FleetJob(scenario="paper_grid", policy="pi3", lam=lam,
                         eps_b=0.05, seed=0) for lam in (2.0, 14.0)]
        res = run_fleet(jobs, T=2048, chunk=256)      # early_stop off
        for m in res.metrics:
            assert {"verdict", "decided_at_slot", "slots_saved"} <= set(m)
            assert m["slots_saved"] == 0.0            # no freezing
        v = res.verdicts()
        assert v[0] in ("STABLE", "UNDECIDED")
        assert v[1] == "UNSTABLE"                     # far above capacity
        assert res.slots_saved == 0 and res.launch_slots_saved == 0

    def test_verdict_config_forks_runner_not_behavior(self):
        """A custom VerdictConfig reaches the runner (stricter evidence
        delays the decision) without touching the simulated dynamics."""
        job = FleetJob(scenario="paper_grid", policy="pi3", lam=2.0,
                       eps_b=0.05, seed=0)
        fast = run_fleet([job], T=2048, chunk=256, early_stop=True)
        slow = run_fleet([job], T=2048, chunk=256, early_stop=True,
                         verdict=VerdictConfig(k_stable=6, k_unstable=6))
        assert slow.metrics[0]["decided_at_slot"] >= \
            fast.metrics[0]["decided_at_slot"]
        # dynamics identical up to the earlier freeze: delivered monotone
        assert slow.metrics[0]["delivered"] >= fast.metrics[0]["delivered"]

    def test_capacity_report_points_carry_verdicts(self):
        from repro.fleet import capacity_report
        table = capacity_report({"paper_grid": ("pi3bar",)},
                                rate_fracs=(0.4,), seeds=(0,), T=512,
                                chunk=128, eps_b=0.05)
        pt = table["scenarios"]["paper_grid"]["policies"]["pi3bar"]["points"][0]
        assert pt["verdict"] in VERDICT_NAMES
        assert 0 < pt["decided_at_slot"] <= 512
