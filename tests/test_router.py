"""Backpressure MoE router: balance properties and H-queue dynamics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.router import (RouterConfig, init_router_state, route,
                               load_violation)


def _skewed_logits(key, T, E, hot=0, strength=3.0):
    logits = jax.random.normal(key, (T, E)) * 0.5
    return logits.at[:, hot].add(strength)


def test_plain_router_collapses_backpressure_balances():
    key = jax.random.key(0)
    E, T, k = 16, 512, 2
    state_bp = init_router_state(E)
    state_pl = init_router_state(E)
    cfg_bp = RouterConfig(n_experts=E, k=k, mode="backpressure", beta=2.0)
    cfg_pl = RouterConfig(n_experts=E, k=k, mode="plain")
    loads_bp, loads_pl = [], []
    for s in range(30):
        logits = _skewed_logits(jax.random.fold_in(key, s), T, E)
        out_bp = route(cfg_bp, state_bp, logits)
        out_pl = route(cfg_pl, state_pl, logits)
        state_bp, state_pl = out_bp.new_state, out_pl.new_state
        loads_bp.append(out_bp.load)
        loads_pl.append(out_pl.load)
    v_bp = float(load_violation(jnp.stack(loads_bp[-10:]).mean(0)))
    v_pl = float(load_violation(jnp.stack(loads_pl[-10:]).mean(0)))
    assert v_pl > 3.0          # plain top-k slams the hot expert
    assert v_bp < 1.6          # backpressure bias spreads the load
    assert v_bp < v_pl / 2


def test_h_queue_update_rule():
    # H_e <- [H_e + assigned_e - capacity]^+  (paper eq. for H_n).
    E, T, k = 4, 8, 1
    cfg = RouterConfig(n_experts=E, k=k, mode="backpressure", beta=0.0)
    state = init_router_state(E)
    logits = jnp.full((T, E), -10.0).at[:, 2].set(10.0)   # all to expert 2
    out = route(cfg, state, logits)
    cap = T * k / E
    expected = np.zeros(E)
    expected[2] = T - cap
    np.testing.assert_allclose(np.asarray(out.new_state.H), expected, atol=1e-5)


def test_combine_weights_normalized_and_from_gates():
    key = jax.random.key(1)
    cfg = RouterConfig(n_experts=8, k=3, mode="backpressure", beta=1.0)
    out = route(cfg, init_router_state(8), jax.random.normal(key, (32, 8)))
    s = np.asarray(out.combine_w.sum(axis=1))
    np.testing.assert_allclose(s, 1.0, atol=1e-5)
    assert np.all(np.asarray(out.combine_w) >= 0)


def test_aux_mode_has_differentiable_loss():
    cfg = RouterConfig(n_experts=8, k=2, mode="aux", aux_coef=0.01)

    def loss(logits):
        return route(cfg, init_router_state(8), logits).aux_loss

    g = jax.grad(loss)(jnp.ones((16, 8)) * 0.1)
    assert np.isfinite(np.asarray(g)).all()


def test_bias_affects_selection_not_weights():
    # With a huge H on the favourite expert, selection avoids it, and
    # combine weights are still the renormalized raw gates of the selected.
    E, k = 4, 1
    cfg = RouterConfig(n_experts=E, k=k, mode="backpressure", beta=100.0)
    H = jnp.array([0.0, 0.0, 1e6, 0.0])
    state = init_router_state(E)._replace(H=H)
    logits = jnp.tile(jnp.array([[0.0, 1.0, 5.0, 0.5]]), (10, 1))
    out = route(cfg, state, logits)
    assert not np.any(np.asarray(out.expert_idx) == 2)
    np.testing.assert_allclose(np.asarray(out.combine_w), 1.0, atol=1e-6)
