"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs — required for every assigned arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import get_model, split_tree


def _batch_for(cfg, B, S, key):
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        return {"patch_embeds": jax.random.normal(key, (B, cfg.n_patches,
                                                        cfg.d_model)),
                "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_step(arch):
    cfg = reduced(get_config(arch))
    api = get_model(cfg)
    params, axes = split_tree(api.init(key=jax.random.key(0)))
    # axes tree aligned with params tree (axes tuples are subtrees, so use
    # prefix flattening; ndim must match the annotation length)
    axes_leaves = jax.tree_util.tree_structure(params).flatten_up_to(axes)
    for p, a in zip(jax.tree.leaves(params), axes_leaves):
        assert p.ndim == len(a), (p.shape, a)
    batch = _batch_for(cfg, 2, 32, jax.random.key(1))
    ms = api.init_state()

    def loss_fn(p):
        loss, (H, m) = api.loss(p, batch, activ_dtype=jnp.float32,
                                router_H=ms.router_H)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    # one SGD step changes the loss -> graph is connected
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    gnorm = sum(float(jnp.sum(g * g)) for g in flat)
    assert gnorm > 0.0
    p2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2, _ = api.loss(p2, batch, activ_dtype=jnp.float32,
                        router_H=ms.router_H)
    assert np.isfinite(float(loss2)) and float(loss2) != float(loss)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch):
    cfg = reduced(get_config(arch))
    api = get_model(cfg)
    params, _ = split_tree(api.init(key=jax.random.key(0)))
    ms = api.init_state()
    caches = api.init_decode(2, 16, jnp.float32)
    tok = jnp.array([1, 2], jnp.int32)
    for _ in range(3):
        logits, caches = api.decode_step(params, caches, {"tokens": tok},
                                         activ_dtype=jnp.float32,
                                         router_H=ms.router_H)
        assert logits.shape == (2, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_full_configs_match_spec():
    spec = {
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "seamless-m4t-large-v2": (48, 1024, 16, 16, 8192, 256206),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (L, d, H, KV, ff, V) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, d, H, KV, ff, V), arch
    # MoE details
    assert get_config("moonshot-v1-16b-a3b").n_experts == 64
    assert get_config("moonshot-v1-16b-a3b").top_k == 6
    assert get_config("granite-moe-1b-a400m").n_experts == 32
    assert get_config("granite-moe-1b-a400m").top_k == 8
    assert get_config("zamba2-2.7b").ssm_state == 64
    assert get_config("gemma3-27b").local_global == 5


def test_cells_listing():
    from repro.configs import cells
    cs = cells()
    # 10 archs x 3 shapes + 2 sub-quadratic archs x long_500k
    assert len(cs) == 32
    assert ("zamba2-2.7b", "long_500k") in cs
    assert ("xlstm-350m", "long_500k") in cs
    assert ("gemma3-27b", "long_500k") not in cs
    assert len(cells(include_skipped=True)) == 40
