"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
fault tolerance, LLM continuous-batching serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, TokenStream
from repro.optim import (AdamW, compress_int8_ef, compress_topk_ef,
                         global_norm, init_ef, warmup_cosine)
from repro.runtime.fault import (StragglerConfig, StragglerDetector,
                                 plan_recovery)
from repro.launch.serve import Engine


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

class TestData:
    def test_deterministic_and_sharded(self):
        cfg = DataConfig(vocab=512, seq_len=32, global_batch=8, seed=3)
        a = TokenStream(cfg).batch(7)["tokens"]
        b = TokenStream(cfg).batch(7)["tokens"]
        np.testing.assert_array_equal(a, b)
        # host shards partition the global batch deterministically
        h0 = TokenStream(cfg, host_id=0, n_hosts=2).batch(7)["tokens"]
        h1 = TokenStream(cfg, host_id=1, n_hosts=2).batch(7)["tokens"]
        assert h0.shape == (4, 33) and h1.shape == (4, 33)
        assert not np.array_equal(h0, h1)

    def test_stream_is_learnable(self):
        # bigram structure => entropy below unigram entropy is reachable;
        # cheap proxy: adjacent-token mutual information is nonzero.
        cfg = DataConfig(vocab=128, seq_len=256, global_batch=4, seed=0)
        toks = TokenStream(cfg).batch(0)["tokens"]
        x, y = toks[:, :-1].ravel() % 16, toks[:, 1:].ravel() % 16
        joint = np.histogram2d(x, y, bins=16)[0] / x.size
        px, py = joint.sum(1), joint.sum(0)
        mi = np.nansum(joint * np.log(joint / (px[:, None] * py[None, :]
                                               + 1e-12) + 1e-12))
        assert mi > 0.05

    def test_tokens_in_range(self):
        cfg = DataConfig(vocab=64, seq_len=16, global_batch=2)
        t = TokenStream(cfg).batch(0)["tokens"]
        assert t.min() >= 0 and t.max() < 64


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

class TestOptim:
    def test_adamw_reduces_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        p = {"w": jnp.array([3.0, -2.0])}
        s = opt.init(p)
        for _ in range(200):
            g = {"w": 2 * p["w"]}
            p, s = opt.update(g, s, p)
        assert float(jnp.abs(p["w"]).max()) < 0.05

    def test_clip_norm(self):
        opt = AdamW(lr=0.0, clip_norm=1.0)
        g = {"w": jnp.ones(4) * 100}
        # after clipping, the internal grads have norm 1 -> moments bounded
        p = {"w": jnp.zeros(4)}
        s = opt.init(p)
        _, s2 = opt.update(g, s, p)
        assert float(global_norm(s2.m)) <= 0.101

    def test_schedule_shape(self):
        sched = warmup_cosine(1.0, warmup=10, total=100)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 999))
    def test_int8_ef_error_feedback_contracts(self, seed):
        """EF invariant: dequantized + residual == original (exactly)."""
        key = jax.random.key(seed)
        g = {"a": jax.random.normal(key, (64,)) * 3.0}
        ef = init_ef(g)
        dq, ef2 = compress_int8_ef(g, ef)
        np.testing.assert_allclose(np.asarray(dq["a"] + ef2.err["a"]),
                                   np.asarray(g["a"]), rtol=1e-5, atol=1e-5)
        # quantization error bounded by scale
        scale = float(jnp.abs(g["a"]).max()) / 127.0
        assert float(jnp.abs(ef2.err["a"]).max()) <= scale * 0.51 + 1e-6

    def test_topk_ef_keeps_largest(self):
        g = {"a": jnp.asarray(np.r_[np.zeros(90), np.arange(1, 11.0)])}
        ef = init_ef(g)
        kept, ef2 = compress_topk_ef(g, ef, frac=0.1)
        assert int((kept["a"] != 0).sum()) == 10
        np.testing.assert_allclose(np.asarray(kept["a"] + ef2.err["a"]),
                                   np.asarray(g["a"]), atol=1e-6)

    def test_ef_accumulates_small_signals(self):
        """A gradient too small to survive quantization alone must get
        through via the accumulated residual."""
        g = {"a": jnp.r_[jnp.ones(1) * 1.0, jnp.ones(1) * 1e-3]}
        ef = init_ef(g)
        total = jnp.zeros(2)
        n = 200
        for _ in range(n):
            dq, ef = compress_int8_ef(g, ef)
            total = total + dq["a"]
        # mean transmitted value of the small coordinate ~ its true value
        # (quantization step is 1/127 ~ 0.0079, so 1e-3 only gets through
        # via the accumulated residual every ~8 steps)
        assert float(total[1] / n) == pytest.approx(1e-3, rel=0.25)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def _state(self, v=0.0):
        return {"p": {"w": jnp.full((4, 4), v), "b": jnp.arange(3.0)},
                "step": jnp.asarray(int(v), jnp.int32)}

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        ck.save(5, self._state(5.0))
        out = ck.restore(self._state(0.0))
        np.testing.assert_allclose(np.asarray(out["p"]["w"]), 5.0)
        assert int(out["step"]) == 5

    def test_keep_k_gc(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, self._state(float(s)))
        assert ck.all_steps() == [3, 4]

    def test_latest_and_explicit_step(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=5)
        ck.save(1, self._state(1.0))
        ck.save(9, self._state(9.0))
        assert ck.latest_step() == 9
        out = ck.restore(self._state(), step=1)
        np.testing.assert_allclose(np.asarray(out["p"]["w"]), 1.0)

    def test_async_save(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(3, self._state(3.0), blocking=False)
        ck.wait()
        assert ck.latest_step() == 3

    def test_structure_mismatch_raises(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(1, self._state())
        with pytest.raises(AssertionError):
            ck.restore({"only": jnp.zeros(1)})

    def test_restore_with_shardings(self, tmp_path):
        # resharding path: restore onto the (1-device) mesh explicitly
        from jax.sharding import NamedSharding, PartitionSpec as P
        ck = Checkpointer(tmp_path)
        ck.save(2, self._state(2.0))
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), self._state())
        out = ck.restore(self._state(), shardings=sh)
        assert out["p"]["w"].sharding.mesh.shape["data"] == 1


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

class TestFault:
    def test_straggler_detection(self):
        cfg = StragglerConfig(window=8, factor=1.5, patience=2,
                              heartbeat_timeout_s=10)
        det = StragglerDetector(["h0", "h1", "h2", "h3"], cfg)
        for t in range(12):
            for h in ("h0", "h1", "h2"):
                det.record(h, 1.0, now=float(t))
            det.record("h3", 3.0, now=float(t))
            slow = det.stragglers()
        assert slow == ["h3"]

    def test_dead_host_heartbeat(self):
        cfg = StragglerConfig(heartbeat_timeout_s=5)
        det = StragglerDetector(["h0", "h1"], cfg)
        det.record("h0", 1.0, now=100.0)
        det.record("h1", 1.0, now=92.0)
        assert det.dead(now=100.0) == ["h1"]

    def test_recovery_plan_remesh(self):
        plan = plan_recovery(n_hosts=64, devices_per_host=8,
                             dead=["h7"], stragglers=[], model_parallel=16)
        assert plan.action == "remesh"
        assert plan.new_mesh_shape == ((63 * 8) // 16, 16)

    def test_recovery_plan_rebalance_then_none(self):
        p1 = plan_recovery(8, 8, [], ["h2"], 4)
        assert p1.action == "rebalance" and p1.evict == ("h2",)
        p0 = plan_recovery(8, 8, [], [], 4)
        assert p0.action == "none"


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

class TestServing:
    # The network-serving scheduler tests (trace/admission/latency) live in
    # tests/test_serving.py against repro.serving; this class keeps the LLM
    # continuous-batching engine (repro.launch.serve) covered.
    def test_engine_completes_and_outputs_agree(self):
        """Engine mechanics: all requests finish with the requested length,
        and two engines agree on the decode logits (exact token trajectories
        are chaotic under CPU thread-order float jitter on a random-init
        model, so we compare logits with tolerance instead)."""
        from repro.configs import get_config, reduced
        from repro.models import get_model, split_tree
        cfg = reduced(get_config("qwen2-0.5b"))
        api = get_model(cfg)
        params, _ = split_tree(api.init(key=jax.random.key(0)))
        engines = [Engine(cfg, params, slots=2, max_len=64) for _ in range(2)]
        logits = []
        for eng in engines:
            eng.submit([5, 6, 7], max_new=5)
            eng.submit([9, 10], max_new=5)
            eng._admit()
            lg, _ = eng._step(eng.params, eng.caches,
                              jnp.asarray(eng._last_tok), eng.router_H)
            logits.append(np.asarray(lg))
        np.testing.assert_allclose(logits[0], logits[1], rtol=1e-4, atol=1e-5)
        fin = engines[0].run_until_done()
        assert len(fin) == 2
        assert all(len(r.out) == 5 for r in fin.values())
