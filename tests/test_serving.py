"""Serving subsystem tests (DESIGN.md §9): trace generators, streaming
latency accumulators, admission hysteresis, chunked-vs-closed bit
equality, fairness across query classes, and the outage shed/recover loop
wired to the fault-tolerance planner (markers: fleet_smoke for engine
runs, pallas for backend parity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import paper_grid_problem
from repro.core.latency import (LatencyStats, latency_mean,
                                latency_quantiles, latency_update)
from repro.core.queues import DriftStats
from repro.fleet import PadDims, pad_problem, policy_bound_exact
from repro.fleet.scenarios import event_code, get_scenario
from repro.runtime.fault import (StragglerConfig, StragglerDetector,
                                 plan_recovery)
from repro.serving import (AdmissionConfig, AdmissionState, QueryClass,
                           ServingJob, TraceSpec, TraceState,
                           admission_admit, admission_update, draw_arrivals,
                           get_trace, jsonl_line, list_traces,
                           make_serving_runner, run_serving, serving_report,
                           write_stream_jsonl)
from repro.serving.trace import envelope


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

class TestTrace:
    def test_registry(self):
        assert {"steady", "bursty", "diurnal_mix",
                "bursty_mix"} <= set(list_traces())
        with pytest.raises(KeyError, match="unknown trace"):
            get_trace("nope")

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="sum to 1"):
            TraceSpec("bad", (QueryClass("a", "poisson", 0.5),))
        with pytest.raises(ValueError, match="unknown arrival"):
            QueryClass("a", "zipf")
        with pytest.raises(ValueError, match="at least one"):
            TraceSpec("empty", ())
        with pytest.raises(ValueError, match="diurnal_depth"):
            TraceSpec("deep", (QueryClass("a"),), diurnal_period=10,
                      diurnal_depth=1.5)

    def test_envelope_mean_one(self):
        spec = get_trace("diurnal_mix")
        t = jnp.arange(spec.diurnal_period)
        env = jax.vmap(lambda ti: envelope(spec, ti))(t)
        assert float(env.mean()) == pytest.approx(1.0, abs=1e-3)
        assert float(env.max()) == pytest.approx(1 + spec.diurnal_depth,
                                                 abs=1e-3)
        # no period -> constant 1
        assert float(envelope(get_trace("steady"), jnp.int32(7))) == 1.0

    def _scan_trace(self, spec, lam, T, seed=0):
        from repro.fleet.scenarios import ModState
        p = paper_grid_problem()
        pp = pad_problem(p, PadDims.of([p]))
        mod = ModState.init(pp)

        def body(tr, xs):
            t, key = xs
            arr, tr2 = draw_arrivals(spec, key, jnp.float32(lam), t, tr, mod)
            return tr2, arr

        keys = jax.random.split(jax.random.key(seed), T)
        _, arrs = jax.lax.scan(body, TraceState.init(spec),
                               (jnp.arange(T), keys))
        return np.asarray(arrs)                        # [T, K]

    def test_mixture_rates_and_determinism(self):
        spec = get_trace("bursty_mix")
        lam, T = 4.0, 4000
        arrs = self._scan_trace(spec, lam, T)
        assert arrs.shape == (T, 2)
        # long-run per-class rate matches lam * frac; total matches lam
        np.testing.assert_allclose(arrs.mean(0), [2.0, 2.0], rtol=0.1)
        assert arrs.sum(1).mean() == pytest.approx(lam, rel=0.07)
        np.testing.assert_array_equal(arrs, self._scan_trace(spec, lam, T))

    def test_markov_classes_burst_independently(self):
        spec = TraceSpec("two_bursts", (QueryClass("a", "markov_onoff", 0.5),
                                        QueryClass("b", "markov_onoff", 0.5)))
        arrs = self._scan_trace(spec, 4.0, 2000)
        # each class is silent during its own OFF phases, and the phases
        # are driven by independent keys -> the silence patterns differ
        off_a, off_b = arrs[:, 0] == 0.0, arrs[:, 1] == 0.0
        assert 0.1 < off_a.mean() < 0.9 and 0.1 < off_b.mean() < 0.9
        assert (off_a != off_b).mean() > 0.05


# ---------------------------------------------------------------------------
# latency accumulators
# ---------------------------------------------------------------------------

class TestLatency:
    HORIZON, BINS = 64, 32          # bin width 2 slots

    def _run(self, T, delay, rate=1.0):
        """Admit `rate`/slot; deliver the same fluid `delay` slots later."""
        lat = LatencyStats.zero(self.HORIZON, self.BINS)
        for t in range(T):
            adm = rate * (t + 1)
            dlv = rate * max(t + 1 - delay, 0)
            out = rate if t >= delay else 0.0
            lat = latency_update(lat, jnp.int32(t), jnp.float32(adm),
                                 jnp.float32(dlv), jnp.float32(out),
                                 horizon=self.HORIZON, n_bins=self.BINS)
        return lat

    def test_constant_lag_measures_exact_delay(self):
        d = 6
        lat = self._run(40, d)
        assert float(latency_mean(lat)) == pytest.approx(d)
        p50, p99 = np.asarray(latency_quantiles(
            lat.hist, (0.5, 0.99), horizon=self.HORIZON, n_bins=self.BINS))
        # quantiles report the bin's upper edge: conservative by < 1 bin
        assert d <= p50 <= d + 2 and d <= p99 <= d + 2

    def test_empty_histogram_reports_zero(self):
        lat = LatencyStats.zero(self.HORIZON, self.BINS)
        q = latency_quantiles(lat.hist, (0.5, 0.99), horizon=self.HORIZON,
                              n_bins=self.BINS)
        assert float(latency_mean(lat)) == 0.0
        np.testing.assert_array_equal(np.asarray(q), [0.0, 0.0])

    def test_delay_caps_at_horizon_in_overflow_bin(self):
        # admitted mass never delivered: once the ring wraps, the virtual
        # delay saturates at the cap and lands in the overflow bin
        lat = LatencyStats.zero(self.HORIZON, self.BINS)
        for t in range(self.HORIZON + 8):
            lat = latency_update(lat, jnp.int32(t), jnp.float32(t + 1.0),
                                 jnp.float32(0.0), jnp.float32(1.0),
                                 horizon=self.HORIZON, n_bins=self.BINS)
        assert float(lat.hist[-1]) > 0
        q = latency_quantiles(lat.hist, (0.99,), horizon=self.HORIZON,
                              n_bins=self.BINS)
        assert float(q[0]) == self.HORIZON


# ---------------------------------------------------------------------------
# admission gate
# ---------------------------------------------------------------------------

class TestAdmission:
    CFG = AdmissionConfig(shed_tol=0.10, gap_tol=0.05, readmit_tol=0.02,
                          k_shed=2, k_readmit=2)
    WIN, BURN = 64, 128

    def test_admit_applies_gate_and_counts(self):
        adm = AdmissionState.zero(2)
        arr = jnp.array([3.0, 1.0])
        adm, tot = admission_admit(adm, arr)
        assert float(tot) == 4.0
        adm = adm._replace(gate=jnp.float32(0.0))
        adm, tot = admission_admit(adm, arr)
        assert float(tot) == 0.0
        np.testing.assert_allclose(np.asarray(adm.admitted), [3.0, 1.0])
        np.testing.assert_allclose(np.asarray(adm.shed), [3.0, 1.0])

    def _drive(self, T, service=3.0, arrivals=5.0, lam=4.0,
               drift=None):
        """Closed loop: queue grows while the gate admits, drains shut.

        Returns the per-slot gate trace (numpy, length T)."""
        drift = drift or DriftStats.zero()
        adm = AdmissionState.zero(1)
        q = dlv = 0.0
        gates = []
        for t in range(T):
            adm, admitted = admission_admit(adm, jnp.array([arrivals]))
            q = max(q + float(admitted) - service, 0.0)
            dlv += service if q > 0 or admitted > 0 else 0.0
            adm = admission_update(self.CFG, adm, jnp.int32(t),
                                   jnp.float32(q), jnp.float32(dlv),
                                   jnp.float32(lam), drift,
                                   window=self.WIN, burn_in=self.BURN)
            gates.append(float(adm.gate))
        return np.asarray(gates), adm

    def test_gate_moves_only_at_window_boundaries(self):
        gates, _ = self._drive(8 * self.WIN)
        flips = np.nonzero(np.diff(gates))[0] + 1
        assert len(flips) > 0                       # overloaded: it closes
        # the gate re-evaluates at slot t with (t+1) % window == 0
        assert all((f + 1) % self.WIN == 0 for f in flips)

    def test_hysteresis_flip_spacing(self):
        """Consecutive flips are >= min(k_shed, k_readmit) windows apart —
        the gate cannot flip-flop inside one verdict window."""
        gates, adm = self._drive(32 * self.WIN)
        flips = np.nonzero(np.diff(gates))[0] + 1
        assert len(flips) >= 2                      # duty-cycles both ways
        k = min(self.CFG.k_shed, self.CFG.k_readmit)
        assert np.all(np.diff(flips) >= k * self.WIN), flips
        assert int(adm.flips) == len(flips)

    def test_underload_never_closes(self):
        gates, adm = self._drive(16 * self.WIN, service=7.0)
        assert np.all(gates == 1.0) and int(adm.flips) == 0

    def test_burn_in_suppresses_early_evidence(self):
        # with burn_in past the whole run, even hard overload can't close
        adm = AdmissionState.zero(1)
        for t in range(4 * self.WIN):
            adm, _ = admission_admit(adm, jnp.array([9.0]))
            adm = admission_update(self.CFG, adm, jnp.int32(t),
                                   jnp.float32(9.0 * (t + 1)),
                                   jnp.float32(0.0), jnp.float32(4.0),
                                   DriftStats.zero(), window=self.WIN,
                                   burn_in=100 * self.WIN)
        assert float(adm.gate) == 1.0 and int(adm.flips) == 0

    def test_unstable_run_corroborates_first_close_only(self):
        """The verdict's evidence streak can close a never-flipped gate on
        its own, but after any flip the windowed conjunction governs."""
        streak = DriftStats.zero()._replace(unstable_run=jnp.int32(1))
        adm = AdmissionState.zero(1)
        # flat backlog, no gap: only the streak supplies evidence
        for t in range(self.BURN + 2 * self.WIN):
            adm = admission_update(self.CFG, adm, jnp.int32(t),
                                   jnp.float32(0.0), jnp.float32(0.0),
                                   jnp.float32(4.0), streak,
                                   window=self.WIN, burn_in=self.BURN)
        assert float(adm.gate) == 0.0               # first close: streak
        # flat backlog reads as recovered -> it reopens ...
        for t in range(t + 1, t + 1 + 2 * self.WIN):
            adm = admission_update(self.CFG, adm, jnp.int32(t),
                                   jnp.float32(0.0), jnp.float32(0.0),
                                   jnp.float32(4.0), streak,
                                   window=self.WIN, burn_in=self.BURN)
        assert float(adm.gate) == 1.0
        # ... and the still-raised streak alone can never close it again
        for t in range(t + 1, t + 1 + 8 * self.WIN):
            adm = admission_update(self.CFG, adm, jnp.int32(t),
                                   jnp.float32(0.0), jnp.float32(0.0),
                                   jnp.float32(4.0), streak,
                                   window=self.WIN, burn_in=self.BURN)
        assert float(adm.gate) == 1.0 and int(adm.flips) == 2


# ---------------------------------------------------------------------------
# scheduler: chunked streaming == closed form, bit for bit
# ---------------------------------------------------------------------------

class TestSchedulerEquality:
    def test_chunked_equals_closed_bitwise(self):
        p = paper_grid_problem()
        pp = pad_problem(p, PadDims.of([p]))
        cfg = ServingJob(policy="pi3_reg").policy_config()
        runner = make_serving_runner(cfg, get_trace("bursty"), T=256,
                                     chunk=64)
        lam = jnp.float32(4.0)
        eps = jnp.float32(0.05)
        ek = jnp.int32(event_code(get_scenario("paper_grid").events))
        key = jax.random.PRNGKey(3)

        carry = runner.init_carry(pp)
        for _ in range(runner.n_chunks):
            carry = runner.chunk_step(pp, lam, eps, ek, key, carry)
        chunked = runner.finalize(lam, eps, carry)
        closed = runner(pp, lam, eps, ek, key)
        assert set(chunked) == set(closed)
        for k in chunked:
            np.testing.assert_array_equal(np.asarray(chunked[k]),
                                          np.asarray(closed[k]), err_msg=k)


# ---------------------------------------------------------------------------
# engine + report (CI smoke: works on 1 device; scripts/test.sh gives it 8)
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestServingEngine:
    def test_light_load_admits_everything(self):
        bound = policy_bound_exact("paper_grid", "pi3_reg", 0.05, 0)
        jobs = [ServingJob(trace=tr, lam=0.6 * bound, seed=s)
                for tr in ("steady", "bursty") for s in (0, 1)]
        res = run_serving(jobs, T=1024, chunk=256)
        assert res.n_sims == 4
        assert res.n_programs == 2          # one program per (policy, trace)
        np.testing.assert_array_equal(res.column("shed_frac"), 0.0)
        np.testing.assert_array_equal(res.column("gate_flips"), 0.0)
        np.testing.assert_array_equal(res.column("gate"), 1.0)
        assert np.all(res.column("delivered_qps") >= 0.8 * 0.6 * bound)
        assert np.all(res.column("p99_sojourn") > 0)

    def test_overload_fairness_across_classes(self):
        """Class-uniform shedding: under 1.3x-bound overload of the
        half-bursty mixture, both classes keep the same admitted share."""
        bound = policy_bound_exact("paper_grid", "pi3_reg", 0.05, 0)
        jobs = [ServingJob(trace="bursty_mix", lam=1.3 * bound, seed=s)
                for s in (0, 1)]
        res = run_serving(jobs, T=4096, chunk=512)
        for m in res.metrics:
            fa, fb = m["class_admit_frac"]
            assert m["shed_frac"] > 0.1          # it actually shed
            assert abs(fa - fb) < 0.05, (fa, fb)
            assert 0.4 < fa < 0.9
        # hysteresis at engine scale: with k_shed = k_readmit = 2 the gate
        # can flip at most once per 2 admission windows
        n_windows = 4096 // 512
        assert np.all(res.column("gate_flips") <= n_windows // 2)

    def test_outage_sheds_then_recovers(self):
        """Comp-node outage mid-trace (outage_grid, slots [1024, 1536)):
        the gate sheds during the outage and re-admits after the Up
        transition, restoring delivered QPS to >= 0.9 x bound; the fault
        planner classifies the same outage as an evictable straggler."""
        bound = policy_bound_exact("outage_grid", "pi3_reg", 0.05, 0)
        jobs = [ServingJob(scenario="outage_grid", trace="bursty",
                           lam=0.95 * bound, seed=s) for s in (0, 1)]
        res = run_serving(jobs, T=4096, chunk=256, stream=True)
        shed = res.column("shed_frac")
        assert np.all(shed > 0.05), shed         # the outage forced shedding
        assert np.all(res.column("gate") == 1.0)  # ... and the gate reopened
        assert np.all(res.column("gate_flips") >= 2.0)
        # recovery: windowed delivered QPS back above 0.9 x bound for every
        # post-recovery chunk (the outage ends at t=1536; give the backlog
        # 3072 - 1536 slots to drain)
        tail = [r for r in res.stream_records if r["t"] > 3072]
        assert tail, "no post-recovery stream records"
        for r in tail:
            assert r["qps_med"] >= 0.9 * bound, r

        # the same incident through the fault-tolerance planner: the
        # outage node's step times blow up -> straggler -> rebalance plan
        det = StragglerDetector([f"n{i}" for i in range(4)],
                                StragglerConfig(window=8, factor=1.5,
                                                patience=2,
                                                heartbeat_timeout_s=60))
        for t in range(12):
            for h in ("n1", "n2", "n3"):
                det.record(h, 1.0, now=float(t))
            det.record("n0", 5.0, now=float(t))   # the Down comp node
            slow = det.stragglers()               # streak builds per check
        assert slow == ["n0"]
        plan = plan_recovery(n_hosts=4, devices_per_host=1, dead=[],
                             stragglers=slow, model_parallel=1)
        assert plan.action == "rebalance" and plan.evict == ("n0",)

    def test_report_and_stream_jsonl(self, tmp_path):
        rep = serving_report("paper_grid", "pi3_reg", "bursty",
                             rate_fracs=(0.6,), seeds=(0,), T=512,
                             chunk=128, stream=True)
        row = rep["rows"]["0.6"]
        assert row["shed_frac"] == 0.0
        assert row["delivered_over_bound"] >= 0.5
        assert rep["bound_exact"] > 0
        res = rep["result"]
        assert len(res.stream_records) == res.T // 128
        path = tmp_path / "stream.jsonl"
        n = write_stream_jsonl(res, str(path))
        lines = path.read_text().splitlines()
        assert n == len(lines) == len(res.stream_records)
        assert lines[0] == jsonl_line(res.stream_records[0])
        rec = res.stream_records[-1]
        assert {"t", "qps_med", "shed_frac_med", "p99_med",
                "gate_open_frac", "verdicts"} <= set(rec)


# ---------------------------------------------------------------------------
# backend parity (marker: pallas — re-run under JAX_PLATFORMS=cpu)
# ---------------------------------------------------------------------------

@pytest.mark.pallas
class TestServingBackendParity:
    def test_pallas_serving_path_bit_identical(self):
        bound = policy_bound_exact("paper_grid", "pi3_reg", 0.05, 0)
        results = {}
        for backend in ("xla", "pallas"):
            jobs = [ServingJob(trace="bursty", lam=0.95 * bound, seed=s,
                               backend=backend) for s in (0, 1)]
            results[backend] = run_serving(jobs, T=512, chunk=128)
        for mx, mp in zip(results["xla"].metrics,
                          results["pallas"].metrics):
            assert set(mx) == set(mp)
            for k in mx:
                np.testing.assert_array_equal(np.asarray(mx[k]),
                                              np.asarray(mp[k]), err_msg=k)
