"""Behavioural tests for the paper's policies on small networks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ComputeProblem, PolicyConfig, capacity_upper_bound,
                        paper_grid_problem, triangle_graph)
from repro.sim import simulate


def _stable(res, T):
    """Sub-linear backlog: final backlog far below lam*T growth and the
    trailing-window average close to the overall average."""
    q = np.asarray(res.total_queue)
    head, tail = q[: T // 2].mean(), q[T // 2:].mean()
    return tail < 2.0 * head + 50.0


TRI = ComputeProblem(triangle_graph(4.0), s1=0, s2=1, dest=2,
                     comp_nodes=(2,), comp_caps=(2.0,))


class TestSingleNode:
    def test_pi1_stable_below_capacity(self):
        # lam* = min(C=2, cut 4): 2.0
        assert capacity_upper_bound(TRI).lam_star == pytest.approx(2.0)
        res = simulate(TRI, PolicyConfig(name="pi1"), lam=1.6, T=4000, seed=0)
        assert _stable(res, 4000)
        assert float(res.useful_rate(1000)) == pytest.approx(1.6, abs=0.2)

    def test_pi1_unstable_above_capacity(self):
        res = simulate(TRI, PolicyConfig(name="pi1"), lam=2.6, T=4000, seed=0)
        q = np.asarray(res.total_queue)
        assert q[-1] > 0.4 * (2.6 - 2.0) * 4000   # linear-ish growth

    def test_pi1_throughput_saturates_at_capacity(self):
        res = simulate(TRI, PolicyConfig(name="pi1"), lam=3.5, T=4000, seed=0)
        assert float(res.useful_rate(1500)) == pytest.approx(2.0, abs=0.25)

    def test_pi1p_threshold_defers_computation(self):
        # With a huge threshold, pi1' never computes (dominance direction of
        # Lemma 2: pi1 backlog <=_st pi1' backlog).
        res_p = simulate(TRI, PolicyConfig(name="pi1p", threshold=1e6),
                         lam=1.5, T=1500, seed=0)
        res_1 = simulate(TRI, PolicyConfig(name="pi1"), lam=1.5, T=1500, seed=0)
        assert float(res_p.final_state.X.sum()) >= float(res_1.final_state.X.sum())
        assert float(res_p.delivered[-1]) == 0.0

    def test_pi1p_moderate_threshold_still_stable(self):
        res = simulate(TRI, PolicyConfig(name="pi1p", threshold=30.0),
                       lam=1.5, T=6000, seed=0)
        assert _stable(res, 6000)

    def test_pi2_regulator_delivers_dummies_but_counts_useful(self):
        res = simulate(TRI, PolicyConfig(name="pi2", eps_b=0.05),
                       lam=1.5, T=4000, seed=0)
        assert _stable(res, 4000)
        assert float(res.delivered[-1]) >= float(res.delivered_useful[-1])
        assert float(res.useful_rate(1500)) == pytest.approx(1.5, abs=0.2)


class TestMultiNode:
    def test_pi3_stable_below_lambda_star(self):
        p = paper_grid_problem(C=2.0)
        res = simulate(p, PolicyConfig(name="pi3", eps_b=0.01),
                       lam=6.0, T=3000, seed=1)
        assert _stable(res, 3000)
        assert float(res.useful_rate(1000)) == pytest.approx(6.0, abs=0.4)

    def test_pi3_unstable_above_lambda_star(self):
        p = paper_grid_problem(C=2.0)
        res = simulate(p, PolicyConfig(name="pi3"), lam=9.0, T=3000, seed=1)
        q = np.asarray(res.total_queue)
        assert q[-1] > q[len(q) // 4] + 0.3 * (9.0 - 8.0) * (3000 * 0.75)

    def test_pi3bar_matches_pi3_capacity(self):
        # §V conjecture: same capacity, fewer packets at light load.
        p = paper_grid_problem(C=2.0)
        r3 = simulate(p, PolicyConfig(name="pi3"), lam=5.0, T=3000, seed=2)
        rb = simulate(p, PolicyConfig(name="pi3bar"), lam=5.0, T=3000, seed=2)
        assert _stable(r3, 3000) and _stable(rb, 3000)
        assert float(rb.avg_queue) <= 1.15 * float(r3.avg_queue)

    def test_pi3_load_balances_across_nodes(self):
        p = paper_grid_problem(C=2.0)
        res = simulate(p, PolicyConfig(name="pi3"), lam=6.0, T=3000, seed=3)
        counts = np.bincount(np.asarray(res.n_star), minlength=4)
        assert counts.min() > 0.10 * counts.sum()   # every node used

    def test_pairing_models_agree_on_throughput(self):
        p = paper_grid_problem(C=2.0)
        fifo = simulate(p, PolicyConfig(name="pi3bar", pairing="fifo"),
                        lam=6.0, T=3000, seed=4)
        bnd = simulate(p, PolicyConfig(name="pi3bar", pairing="bound"),
                       lam=6.0, T=3000, seed=4)
        assert float(fifo.useful_rate(1000)) == pytest.approx(
            float(bnd.useful_rate(1000)), abs=0.5)


class TestInvariants:
    def test_no_negative_queues_and_conservation(self):
        p = paper_grid_problem(C=2.0)
        res = simulate(p, PolicyConfig(name="pi3", eps_b=0.02),
                       lam=7.0, T=1500, seed=5)
        s = res.final_state
        for arr in (s.Q, s.X, s.Y, s.H, s.Ddum):
            assert float(jnp.min(arr)) >= -1e-3
        # dummy content never exceeds its processed queue
        nidx = np.arange(4)
        assert np.all(np.asarray(s.Ddum) <= np.asarray(s.Q[:, 0, :]) + 1e-3)
        # pairs combined never exceed arrivals on either side
        assert np.all(np.asarray(s.cum_comb)[None].T <= np.asarray(s.cum_arr) + 1e-3)


class TestWireless:
    """Paper §IV-C: pi3 under node-exclusive interference with greedy
    maximal matching link activation (refs [17, 18])."""

    def test_matching_is_valid_and_maximal(self):
        import jax.numpy as jnp
        from repro.core.policies import greedy_maximal_matching
        edges = jnp.array([[0, 1], [1, 2], [2, 3], [3, 0], [0, 2]])
        w = jnp.array([5.0, 4.0, 3.0, 2.0, 1.0])
        sel = np.asarray(greedy_maximal_matching(edges, w, 4))
        # (0,1) picked first, blocks (1,2) and (3,0) and (0,2); (2,3) fits
        np.testing.assert_array_equal(sel, [True, False, True, False, False])
        # node-exclusive: no two selected edges share a node
        used = np.zeros(4, int)
        for e, s in zip(np.asarray(edges), sel):
            if s:
                used[e[0]] += 1
                used[e[1]] += 1
        assert used.max() <= 1

    def test_zero_weight_links_stay_idle(self):
        import jax.numpy as jnp
        from repro.core.policies import greedy_maximal_matching
        edges = jnp.array([[0, 1], [2, 3]])
        sel = np.asarray(greedy_maximal_matching(
            edges, jnp.array([0.0, 1.0]), 4))
        np.testing.assert_array_equal(sel, [False, True])

    def test_wireless_pi3_stable_at_low_rate(self):
        p = paper_grid_problem(C=2.0)
        res = simulate(p, PolicyConfig(name="pi3", wireless=True),
                       lam=1.5, T=3000, seed=6)
        assert _stable(res, 3000)
        assert float(res.useful_rate(1000)) == pytest.approx(1.5, abs=0.3)

    def test_wireless_capacity_below_wired(self):
        """Interference shrinks the rate region: at a rate the wired system
        sustains, the wireless one saturates lower."""
        p = paper_grid_problem(C=3.0)
        wired = simulate(p, PolicyConfig(name="pi3bar"), lam=9.0, T=3000,
                         seed=7)
        wless = simulate(p, PolicyConfig(name="pi3bar", wireless=True),
                         lam=9.0, T=3000, seed=7)
        assert float(wless.useful_rate(1000)) < float(wired.useful_rate(1000))
        q = np.asarray(wless.total_queue)
        assert q[-1] > q[len(q) // 2]       # backlog grows: above wireless cap
