"""End-to-end behaviour tests for the full system: paper pipeline
(capacity -> policy -> throughput), training driver with crash/restart,
and the serving driver."""
import numpy as np
import pytest

from repro.core import (ComputeProblem, PolicyConfig, capacity_upper_bound,
                        triangle_graph)
from repro.sim import simulate


def test_paper_pipeline_end_to_end():
    """LP capacity, pi3 stability below it, saturation above it — the
    paper's whole story on one small instance."""
    p = ComputeProblem(triangle_graph(4.0), s1=0, s2=1, dest=2,
                       comp_nodes=(0, 2), comp_caps=(1.0, 1.5))
    lam_star = capacity_upper_bound(p).lam_star
    assert 0 < lam_star <= 2.5
    below = simulate(p, PolicyConfig(name="pi3"), 0.8 * lam_star, 3000, seed=0)
    q = np.asarray(below.total_queue)
    assert (q[-1] - q[len(q) // 2]) / (len(q) // 2) < 0.3      # stable
    assert float(below.useful_rate(1000)) == pytest.approx(0.8 * lam_star,
                                                           rel=0.15)
    above = simulate(p, PolicyConfig(name="pi3"), 1.6 * lam_star, 3000, seed=0)
    assert float(above.useful_rate(1000)) <= lam_star * 1.1    # capped


def test_train_driver_end_to_end(tmp_path):
    """launch.train: loss decreases; crash + --resume continues training."""
    from repro.launch.train import main as train
    common = ["--arch", "qwen2-0.5b", "--reduced", "--batch", "4",
              "--seq", "32", "--ckpt-dir", str(tmp_path),
              "--ckpt-every", "20", "--log-every", "50"]
    with pytest.raises(SystemExit):
        train(common + ["--steps", "100", "--crash-at", "45"])
    losses = train(common + ["--steps", "100", "--resume"])
    # resumed from step 40 -> 60 steps run; loss dropped vs start of phase 2
    assert len(losses) == 60
    assert np.mean(losses[-10:]) < np.mean(losses[:5])


def test_serve_driver_end_to_end():
    from repro.launch.serve import main as serve
    fin = serve(["--arch", "qwen2-0.5b", "--requests", "5",
                 "--slots", "2", "--max-new", "6", "--max-len", "64"])
    assert len(fin) == 5
    assert all(len(r.out) == 6 for r in fin.values())


def test_moe_training_with_backpressure_router():
    """A MoE arch trains end-to-end with the paper's router in the loop and
    the H queues stay bounded (drained by capacity)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import RunConfig, ShapeConfig, get_config, reduced
    from repro.data import DataConfig, TokenStream
    from repro.runtime.step import init_train_state, make_train_step

    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                     activ_dtype="float32", remat="none")
    state, _ = init_train_state(rcfg, key=jax.random.key(0))
    step = jax.jit(make_train_step(rcfg), donate_argnums=(0,))
    data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    first = None
    for i in range(25):
        state, m = step(state, {"tokens": jnp.asarray(data.batch(i)["tokens"])})
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first
    H = np.asarray(state.router_H)
    # virtual queues bounded well below total routed tokens (stability)
    assert H.max() < 25 * 4 * 32 * cfg.top_k


def test_grad_compression_training_converges():
    import jax
    import jax.numpy as jnp
    from repro.configs import RunConfig, ShapeConfig, get_config, reduced
    from repro.data import DataConfig, TokenStream
    from repro.runtime.step import init_train_state, make_train_step

    cfg = reduced(get_config("olmo-1b"))
    losses = {}
    for comp in ("none", "int8_ef"):
        rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                         activ_dtype="float32", remat="none",
                         grad_compression=comp)
        state, _ = init_train_state(rcfg, key=jax.random.key(1))
        step = jax.jit(make_train_step(rcfg), donate_argnums=(0,))
        data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=4, seed=1))
        ls = []
        for i in range(30):
            state, m = step(state, {"tokens":
                                    jnp.asarray(data.batch(i)["tokens"])})
            ls.append(float(m["loss"]))
        losses[comp] = ls
    # compressed training tracks uncompressed within a loose factor
    assert losses["int8_ef"][-1] < losses["int8_ef"][0]
    assert abs(losses["int8_ef"][-1] - losses["none"][-1]) < 1.0


def test_grad_accum_matches_full_batch():
    """grad_accum=2 must give (nearly) the same first-step loss/update as
    the full batch — the accumulation is mathematically a mean."""
    import jax
    import jax.numpy as jnp
    from repro.configs import RunConfig, ShapeConfig, get_config, reduced
    from repro.data import DataConfig, TokenStream
    from repro.optim import global_norm
    from repro.runtime.step import init_train_state, make_train_step

    cfg = reduced(get_config("olmo-1b"))
    batch = {"tokens": jnp.asarray(
        TokenStream(DataConfig(vocab=cfg.vocab, seq_len=32,
                               global_batch=8, seed=2)).batch(0)["tokens"])}
    outs = {}
    for ga in (1, 2):
        rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                         activ_dtype="float32", remat="none", grad_accum=ga)
        state, _ = init_train_state(rcfg, key=jax.random.key(3))
        step = jax.jit(make_train_step(rcfg))
        new, m = step(state, batch)
        outs[ga] = (float(m["loss"]), float(global_norm(new.params)))
    assert outs[1][0] == pytest.approx(outs[2][0], rel=1e-4)
    assert outs[1][1] == pytest.approx(outs[2][1], rel=1e-4)
