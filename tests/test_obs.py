"""Telemetry-plane tests (DESIGN.md §11): the versioned stream-record
schema, observer-effect freedom (telemetry-on runs bit-identical to
telemetry-off across fleet/serving/atlas, including early stop), the
no-recompilation contract (the emit program must not fork the compiled
chunk step), and the `capacity_report --follow` renderer."""
import json
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.fleet import (FleetJob, make_group_launch, make_stream_runner,
                         registry_cells, resolve_verdict, run_fleet,
                         sweep_lambda_max)
from repro.obs import emitter as obs_emitter
from repro.obs import follow as follow_mod
from repro.obs import schema
from repro.obs.follow import RollingMedian, follow, render
from repro.serving import ServingJob, run_serving


def _fleet_rec(chunk=0, t=64, **over):
    fields = dict(group=0, chunk=chunk, t=t, n_sims=4,
                  useful_rate_med=0.5, backlog_med=0.1, max_queue_med=3.0,
                  drift_med=-0.01, n_decided=1,
                  verdicts={"STABLE": 1, "UNDECIDED": 3})
    fields.update(over)
    return schema.make_record("fleet", **fields)


# ---------------------------------------------------------------------------
# Schema: versioning, typed field tables, monotone clocks
# ---------------------------------------------------------------------------

class TestSchema:
    def test_digest_is_blessed(self):
        """Editing a field table without bumping SCHEMA_VERSION (and
        blessing the new digest) must trip scripts/check_stream.py."""
        assert schema.BLESSED_DIGESTS[schema.SCHEMA_VERSION] == \
            schema.schema_digest()

    def test_make_record_valid(self):
        rec = _fleet_rec()
        assert rec["schema_version"] == schema.SCHEMA_VERSION
        assert rec["kind"] == "fleet"
        assert schema.validate_record(rec) == []
        # records are plain JSON: a round trip is exact
        assert json.loads(schema.jsonl_line(rec)) == rec

    def test_make_record_rejects_missing_and_unknown(self):
        with pytest.raises(ValueError, match="missing"):
            schema.make_record("fleet", group=0, chunk=0, t=1, n_sims=1)
        with pytest.raises(ValueError, match="bump SCHEMA_VERSION"):
            _fleet_rec(bogus_field=1.0)

    def test_validate_catches_type_and_version_drift(self):
        rec = _fleet_rec()
        bad = dict(rec, useful_rate_med="fast")
        assert any("useful_rate_med" in e for e in
                   schema.validate_record(bad))
        old = dict(rec, schema_version=schema.SCHEMA_VERSION + 1)
        assert any("schema_version" in e for e in
                   schema.validate_record(old))

    def test_validate_stream_monotone_clocks(self):
        ok = [_fleet_rec(chunk=c, t=64 * (c + 1)) for c in range(3)]
        assert schema.validate_stream(ok) == []
        # a frozen group may repeat t (non-decreasing), but never rewind
        flat = [_fleet_rec(chunk=0, t=64), _fleet_rec(chunk=1, t=64)]
        assert schema.validate_stream(flat) == []
        rewound = [_fleet_rec(chunk=0, t=128), _fleet_rec(chunk=1, t=64)]
        assert any("t" in e for e in schema.validate_stream(rewound))
        stuck = [_fleet_rec(chunk=1, t=64), _fleet_rec(chunk=1, t=128)]
        assert any("chunk" in e for e in schema.validate_stream(stuck))

    def test_jsonl_roundtrip_and_truncation(self, tmp_path):
        recs = [_fleet_rec(chunk=c, t=64 * (c + 1)) for c in range(4)]
        path = tmp_path / "s_stream.jsonl"
        n = schema.write_stream_jsonl(recs, str(path))
        assert n == 4
        assert schema.read_stream_jsonl(str(path)) == recs
        # a writer mid-append leaves a truncated last line; the reader
        # must keep the complete prefix instead of crashing
        with open(path, "a") as f:
            f.write('{"kind": "fl')
        assert schema.read_stream_jsonl(str(path)) == recs


# ---------------------------------------------------------------------------
# Observer-effect freedom: telemetry-on is bit-identical to telemetry-off
# ---------------------------------------------------------------------------

FLEET_JOBS = [FleetJob(scenario=scen, policy="pi3_reg", lam=lam,
                       eps_b=0.05, seed=s)
              for scen, lam in (("paper_grid", 4.0), ("ge_grid", 3.0))
              for s in (0, 1)]


def _assert_metrics_identical(off, on):
    assert len(off) == len(on)
    for m0, m1 in zip(off, on):
        assert set(m0) == set(m1)
        for k in m0:
            assert m0[k] == m1[k], (k, m0[k], m1[k])


@pytest.mark.fleet_smoke
class TestObserverEffect:
    def test_fleet_stream_bit_identical(self, tmp_path):
        off = run_fleet(FLEET_JOBS, T=512, chunk=128)
        path = tmp_path / "FLEET_stream.jsonl"
        on = run_fleet(FLEET_JOBS, T=512, chunk=128, stream_path=str(path))
        _assert_metrics_identical(off.metrics, on.metrics)
        assert off.stream_records == []
        # one record per (group, chunk launch), all schema-valid
        assert len(on.stream_records) == off.n_programs * (512 // 128)
        assert schema.validate_stream(on.stream_records) == []
        assert schema.read_stream_jsonl(str(path)) == on.stream_records

    def test_fleet_early_stop_stream_bit_identical(self):
        off = run_fleet(FLEET_JOBS, T=2048, chunk=256, early_stop=True)
        on = run_fleet(FLEET_JOBS, T=2048, chunk=256, early_stop=True,
                       stream=True)
        _assert_metrics_identical(off.metrics, on.metrics)
        assert off.slots_saved == on.slots_saved
        assert off.launch_slots_saved == on.launch_slots_saved
        assert schema.validate_stream(on.stream_records) == []
        # the stream mirrors exactly the launches that happened — one
        # record per launch, contiguous chunk indices, no phantom records
        # past a group's early exit
        by_group = {}
        for r in on.stream_records:
            by_group.setdefault(r["group"], []).append(r["chunk"])
        assert len(by_group) == off.n_programs
        for chunks in by_group.values():
            assert chunks == list(range(len(chunks)))
            assert len(chunks) <= 2048 // 256
        assert any(r["n_decided"] > 0 for r in on.stream_records)

    def test_serving_stream_bit_identical(self, tmp_path):
        jobs = [ServingJob(trace="bursty", lam=3.0, seed=s) for s in (0, 1)]
        off = run_serving(jobs, T=512, chunk=128)
        path = tmp_path / "SERVING_stream.jsonl"
        on = run_serving(jobs, T=512, chunk=128, stream_path=str(path))
        _assert_metrics_identical(off.metrics, on.metrics)
        assert schema.validate_stream(on.stream_records) == []
        assert schema.read_stream_jsonl(str(path)) == on.stream_records

    def test_atlas_stream_bit_identical(self, tmp_path):
        cells = registry_cells(("paper_grid", "ring"), topo_seeds=(0, 1),
                               eps_b=0.05)
        kw = dict(seeds=(0,), T=512, chunk=256, rel_tol=0.1, max_calls=4)
        off = sweep_lambda_max(cells, **kw)
        path = tmp_path / "ATLAS_stream.jsonl"
        on = sweep_lambda_max(cells, **kw, stream_path=str(path))
        assert off.stream_records == []
        for r0, r1 in zip(off.rows, on.rows):
            assert (r0.lam_max, r0.lo, r0.hi, r0.n_calls) == \
                (r1.lam_max, r1.lo, r1.hi, r1.n_calls)
        assert (off.n_launches, off.n_programs) == \
            (on.n_launches, on.n_programs)
        assert on.stream_records, "atlas sweep emitted no records"
        assert schema.validate_stream(on.stream_records) == []
        assert schema.read_stream_jsonl(str(path)) == on.stream_records
        # the atlas clock is the dispatch clock (g_launches x chunk),
        # monotone even though lane carries reset t on probe rewrites
        for r in on.stream_records:
            assert r["t"] == (r["chunk"] + 1) * 256


# ---------------------------------------------------------------------------
# No recompilation: the emit program must not fork the chunk step
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestNoRecompilation:
    def test_stream_does_not_fork_step_program(self):
        """Telemetry taps the carry with a *separate* jitted program; the
        donated chunk-step program must stay at exactly one compilation
        across off-then-on runs of the same policy group."""
        # a threshold unique to this test keeps the memoized runner/launch
        # caches from aliasing other tests' entries
        jobs = [FleetJob(scenario="paper_grid", policy="pi3bar", lam=2.0,
                         threshold=0.071293, seed=s) for s in (0, 1)]
        run_fleet(jobs, T=256, chunk=64)
        res = run_fleet(jobs, T=256, chunk=64, stream=True)
        assert len(res.stream_records) == 4
        runner = make_stream_runner(jobs[0].policy_config(), T=256,
                                    chunk=64, window=None,
                                    verdict=resolve_verdict(None, False))
        mesh = Mesh(np.array(jax.devices()), ("fleet",))
        _, step_fn, _ = make_group_launch(runner, mesh)
        assert step_fn._cache_size() == 1, (
            f"telemetry forked the chunk step: {step_fn._cache_size()} "
            "compilations")

    def test_emitter_handles_unregistered_after_close(self, tmp_path):
        before = dict(obs_emitter._SINKS)
        res = run_fleet([FleetJob(scenario="paper_grid", policy="pi3",
                                  lam=2.0, seed=0)],
                        T=256, chunk=64,
                        stream_path=str(tmp_path / "f_stream.jsonl"))
        assert res.stream_records
        assert obs_emitter._SINKS == before, (
            "ChunkEmitter.close() leaked handles")


# ---------------------------------------------------------------------------
# GPU-safe emit: probe leaves are copied before the donated launch lands
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestDonationSafeEmit:
    def test_emit_operand_survives_donated_overwrite(self):
        """The emit program must snapshot (`jnp.copy`) its leaves: after
        dispatching emit on a buffer and immediately overwriting that
        buffer through a donating jit, the callback must still observe
        the pre-overwrite values.  (On CPU in-order execution masks the
        race this guards against on GPU; the copy makes the contract
        backend-independent.)"""
        mesh = Mesh(np.array(jax.devices()), ("fleet",))
        emit = obs_emitter._emit_fn(mesh)
        seen = []
        handle = next(obs_emitter._HANDLES)
        obs_emitter._SINKS[handle] = lambda leaves: seen.append(
            {k: np.asarray(v) for k, v in leaves.items()})
        rep = NamedSharding(mesh, P())
        try:
            x = jax.device_put(jnp.arange(8, dtype=jnp.float32), rep)

            @partial(jax.jit, donate_argnums=0)
            def clobber(v):
                return v * 0.0 - 1.0

            emit(jax.device_put(jnp.int32(handle), rep), {"x": x})
            x = clobber(x)              # donated: may reuse x's buffer
            jax.block_until_ready(x)
            jax.effects_barrier()
        finally:
            obs_emitter._SINKS.pop(handle, None)
        assert len(seen) == 1
        np.testing.assert_array_equal(seen[0]["x"],
                                      np.arange(8, dtype=np.float32))

    def test_fleet_stream_bit_identical_with_copy(self, tmp_path):
        """End-to-end regression for the copy fix: telemetry-on metrics
        and records stay bit-identical to telemetry-off (the observer-
        effect contract survives the extra copy in the emit program)."""
        jobs = [FleetJob(scenario="paper_grid", policy="pi3", lam=3.0,
                         eps_b=0.0517, seed=s) for s in (0, 1)]
        off = run_fleet(jobs, T=512, chunk=128)
        on = run_fleet(jobs, T=512, chunk=128,
                       stream_path=str(tmp_path / "c_stream.jsonl"))
        _assert_metrics_identical(off.metrics, on.metrics)
        assert schema.validate_stream(on.stream_records) == []


# ---------------------------------------------------------------------------
# The follow renderer (capacity_report)
# ---------------------------------------------------------------------------

class TestFollow:
    def test_rolling_median_window(self):
        rm = RollingMedian(window=3)
        for x in (1.0, 100.0, 2.0, 3.0, 4.0):
            rm.push(x)
        assert rm.value == 3.0          # 100.0 aged out of the window
        assert len(rm) == 3

    def test_empty_window_is_nan_not_zero(self):
        """Regression: an empty buffer used to report 0.0 — the exact
        drift-alert boundary — before any record arrived.  It must be
        NaN (renders as — and never trips a threshold)."""
        rm = RollingMedian(2)
        assert math.isnan(rm.value)
        assert follow_mod._fmt(rm.value) == "—"
        assert not (rm.value >= 0.0)     # NaN skips threshold checks
        rm.push(0.25)
        assert rm.value == 0.25

    def test_fleet_drift_renders_and_alerts(self):
        stable = [_fleet_rec(chunk=c, t=64 * (c + 1), drift_med=-0.2)
                  for c in range(3)]
        out = render(stable)
        assert "drift ~-0.200" in out and "!!" not in out
        crossing = [_fleet_rec(chunk=c, t=64 * (c + 1), drift_med=0.05)
                    for c in range(3)]
        assert "!! drift>=0" in render(crossing)

    def test_serving_shed_spike_alert_skips_empty_window(self):
        def srec(chunk, shed):
            return schema.make_record(
                "serving", group=0, chunk=chunk, t=64 * (chunk + 1),
                n_sims=2, qps_med=2.0, admitted_qps_med=2.0,
                shed_frac_med=shed, p99_med=40.0, gate_open_frac=1.0,
                gate_flips=0, verdicts={"UNDECIDED": 2})
        calm = [srec(c, 0.01) for c in range(4)]
        assert "!! shed spike" not in render(calm)
        spike = calm + [srec(4, 0.4)]
        assert "!! shed spike" in render(spike)
        # a lone high-shed record is its own window median: steady-state
        # high shed is not a *spike* (and an empty window alerts never)
        steady = [srec(c, 0.4) for c in range(4)]
        assert "!! shed spike" not in render(steady)

    def test_render_fleet_and_bad_records(self):
        recs = [_fleet_rec(chunk=c, t=64 * (c + 1)) for c in range(3)]
        out = render(recs)
        assert "fleet" in out and "STABLE:1" in out
        out = render(recs + [dict(recs[0], useful_rate_med="fast",
                                  chunk=9)])
        assert "failed schema validation" in out
        assert render([]) == "(no records yet)"

    def test_render_stream_log_callback_records(self):
        """The live path: run_fleet(stream_log=...) delivers the same
        records the result carries, render-ready, on the callback thread."""
        seen = []
        res = run_fleet([FleetJob(scenario="paper_grid", policy="pi3",
                                  lam=2.0, seed=0)],
                        T=256, chunk=64, stream_log=seen.append)
        assert seen == res.stream_records
        assert "fleet" in render(seen)

    def test_follow_renders_files_once(self, tmp_path, capsys):
        path = tmp_path / "x_stream.jsonl"
        schema.write_stream_jsonl(
            [_fleet_rec(chunk=c, t=64 * (c + 1)) for c in range(2)],
            str(path))
        lines = []
        ticks = follow([str(path)], interval=0.0, max_ticks=1,
                       out=lines.append)
        assert ticks == 1
        assert "fleet" in lines[0] and str(path) in lines[0]
