"""Capacity-atlas tests (DESIGN.md §10): the pure `Bisection` machine
against a reference reimplementation of the sequential loop (property
tests + deterministic grid), batched-vs-sequential bit-equivalence of the
mini-atlas, UNDECIDED-vs-UNSTABLE surfacing on the golden frontier, and
the early-stop interaction regression on a mixed multi-rate batch."""
import pytest

try:        # property tests widen coverage when hypothesis exists;
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # the deterministic grid always runs
    HAVE_HYPOTHESIS = False

import dataclasses

import numpy as np

from repro.fleet import (AtlasJob, Bisection, FleetJob, PadDims,
                         atlas_table, find_lambda_max, get_scenario,
                         make_buckets, pad_problem, policy_surface_table,
                         problem_shape, run_fleet, sweep_lambda_max,
                         sweep_policy_surface, validate_buckets)

# ---------------------------------------------------------------------------
# The pure bisection machine (satellite: in-place probe-rewrite properties)
# ---------------------------------------------------------------------------


def _reference_search(oracle, k_lo, k_hi, max_calls):
    """The PR-5 sequential control flow, verbatim: shrink the floor, push
    the ceiling, integer-bisect — with the memo and the conservative
    budget-exhausted pseudo-verdict inline.  The `Bisection` machine must
    reproduce this probe-for-probe."""
    probes, cache = [], {}

    def evaluate(k):
        if k <= 0:
            return True
        if k in cache:
            return cache[k]
        if len(probes) >= max_calls:
            return False
        sus, _ = oracle(k)
        cache[k] = sus
        probes.append(k)
        return sus

    while k_lo > 0 and not evaluate(k_lo):
        k_lo //= 2
    while evaluate(k_hi) and len(probes) < max_calls:
        k_lo = max(k_lo, k_hi)
        k_hi *= 2
    n_iters = 0
    while k_hi - k_lo > 1 and len(probes) < max_calls:
        mid = (k_lo + k_hi) // 2
        if evaluate(mid):
            k_lo = mid
        else:
            k_hi = mid
        n_iters += 1
    return probes, k_lo, k_hi, n_iters


def _drive(oracle, k_lo, k_hi, max_calls):
    """Pull probes from a `Bisection` until done; returns the machine and
    its probe order."""
    bis = Bisection(k_lo, k_hi, max_calls=max_calls)
    order = []
    for _ in range(4 * max_calls + 200):      # hard stop: must terminate
        k = bis.next_rate_index()
        if k is None:
            break
        order.append(k)
        bis.record(k, *oracle(k))
    else:
        pytest.fail("Bisection did not terminate")
    return bis, order


def _seeded_oracle(seed, p_sus=0.5, p_und=0.3):
    """Deterministic pseudo-random verdict oracle: same k -> same outcome."""
    def oracle(k):
        rng = np.random.default_rng((seed, k))
        sus = bool(rng.random() < p_sus)
        und = bool(not sus and rng.random() < p_und)
        return sus, und
    return oracle


def _monotone_oracle(k_star, und_above=()):
    """sustainable iff k <= k_star; indices in `und_above` block with
    UNDECIDED evidence instead of a proven UNSTABLE latch."""
    def oracle(k):
        sus = k <= k_star
        return sus, (not sus and k in und_above)
    return oracle


class TestBisectionMachine:
    # deterministic fallback grid, always run (hypothesis widens it below)
    GRID = [(s, lo, hi, mc) for s in (0, 1, 2, 3)
            for lo, hi in ((5, 11), (0, 4), (20, 21), (1, 64))
            for mc in (0, 1, 3, 8, 24)]

    @pytest.mark.parametrize("seed,k_lo,k_hi,max_calls", GRID)
    def test_matches_sequential_reference(self, seed, k_lo, k_hi, max_calls):
        oracle = _seeded_oracle(seed)
        bis, order = _drive(oracle, k_lo, k_hi, max_calls)
        ref_order, ref_lo, ref_hi, ref_iters = _reference_search(
            oracle, k_lo, k_hi, max_calls)
        assert order == ref_order
        assert (bis.k_lo, bis.k_hi, bis.n_iters) == (ref_lo, ref_hi,
                                                     ref_iters)
        assert bis.n_evals == len(order) <= max_calls

    if HAVE_HYPOTHESIS:
        @settings(max_examples=200, deadline=None)
        @given(seed=st.integers(0, 2 ** 16), k_lo=st.integers(0, 64),
               k_hi=st.integers(1, 128), max_calls=st.integers(0, 24),
               p_sus=st.floats(0.0, 1.0), p_und=st.floats(0.0, 1.0))
        def test_property_matches_reference(self, seed, k_lo, k_hi,
                                            max_calls, p_sus, p_und):
            oracle = _seeded_oracle(seed, p_sus, p_und)
            bis, order = _drive(oracle, k_lo, k_hi, max_calls)
            ref_order, ref_lo, ref_hi, ref_iters = _reference_search(
                oracle, max(k_lo, 0), max(k_hi, k_lo + 1, 1), max_calls)
            assert order == ref_order
            assert (bis.k_lo, bis.k_hi, bis.n_iters) == (ref_lo, ref_hi,
                                                         ref_iters)

        @settings(max_examples=100, deadline=None)
        @given(seed=st.integers(0, 2 ** 16), k_lo=st.integers(0, 64),
               k_hi=st.integers(1, 128), max_calls=st.integers(1, 24))
        def test_property_probes_on_grid_and_unique(self, seed, k_lo, k_hi,
                                                    max_calls):
            """Probes stay on the positive integer grid and a grid index is
            never re-probed (the sequential memo, machine edition)."""
            _, order = _drive(_seeded_oracle(seed), k_lo, k_hi, max_calls)
            assert all(isinstance(k, int) and k >= 1 for k in order)
            assert len(order) == len(set(order)) <= max_calls

        @settings(max_examples=100, deadline=None)
        @given(k_star=st.integers(0, 100), k_lo=st.integers(0, 64),
               k_hi=st.integers(1, 128))
        def test_property_monotone_oracle_converges(self, k_star, k_lo,
                                                    k_hi):
            """With a monotone oracle and ample budget the machine always
            localizes the boundary to (k_star, k_star + 1) — invariant to
            the starting bracket."""
            bis, _ = _drive(_monotone_oracle(k_star), k_lo, k_hi,
                            max_calls=64)
            assert bis.k_lo == k_star
            assert bis.k_hi == k_star + 1

    def test_brackets_narrow_monotonically(self):
        """Once the grow phase ends, every recorded probe shrinks the
        bracket: each (k_lo, k_hi) interval nests inside the previous."""
        oracle = _monotone_oracle(13)
        bis = Bisection(5, 11, max_calls=24)
        growing = True
        prev = None
        while (k := bis.next_rate_index()) is not None:
            bis.record(k, *oracle(k))
            if growing and bis._phase == "mid":
                growing = False
                prev = (bis.k_lo, bis.k_hi)
            elif not growing:
                lo, hi = bis.k_lo, bis.k_hi
                assert prev[0] <= lo <= hi <= prev[1]
                assert hi - lo < prev[1] - prev[0] or bis.done
                prev = (lo, hi)
        assert bis.k_lo == 13 and bis.k_hi == 14

    def test_decided_machine_never_gets_a_new_rate(self):
        """A finished machine returns None forever and rejects records —
        the atlas invariant that decided cells never get their lanes
        rewritten."""
        bis, _ = _drive(_monotone_oracle(7), 5, 11, max_calls=24)
        assert bis.done
        for _ in range(3):
            assert bis.next_rate_index() is None
        with pytest.raises(ValueError):
            bis.record(7, True)

    def test_undecided_at_horizon_widens_reported_bracket(self):
        """UNDECIDED blocking evidence keeps the conservative bracket but
        is surfaced: `undecided_hi` flags the upper end, `k_hi_certain`
        is the nearest *proven* UNSTABLE index (None when none exists)."""
        # boundary at 8; 9 and 10 blocked by horizon-limited evidence, 11
        # genuinely diverges.
        bis, _ = _drive(_monotone_oracle(8, und_above=(9, 10)), 5, 11,
                        max_calls=24)
        assert bis.k_lo == 8 and bis.k_hi == 9
        assert bis.undecided_hi
        assert bis.k_hi_certain == 11
        # ... and with *only* undecided blocks there is no certain ceiling
        bis2, _ = _drive(_monotone_oracle(8, und_above=(9, 10, 11, 16, 22)),
                         5, 11, max_calls=24)
        assert bis2.undecided_hi and bis2.k_hi_certain is None
        # a proven UNSTABLE boundary reports no widening at all
        bis3, _ = _drive(_monotone_oracle(8), 5, 11, max_calls=24)
        assert not bis3.undecided_hi
        assert bis3.k_hi_certain == bis3.k_hi == 9


# ---------------------------------------------------------------------------
# Batched-vs-sequential equivalence: the mini-atlas is bit-identical
# ---------------------------------------------------------------------------

# Heterogeneous topologies (grid / cycle / tree / circulant) in one padded
# batch; eps_b is off-default so the runner memo key — hence the compile
# count below — is private to this test module.
MINI_CELLS = [AtlasJob(s, policy="pi3", eps_b=0.0521)
              for s in ("paper_grid", "ring", "tree", "expander")]
MINI_KW = dict(seeds=(0,), T=2048, chunk=256, rel_tol=0.2, max_calls=8)


@pytest.fixture(scope="module")
def mini_atlas():
    return sweep_lambda_max(MINI_CELLS, **MINI_KW)


@pytest.mark.fleet_smoke
class TestAtlasEquivalence:
    def test_bit_identical_to_sequential_frontier(self, mini_atlas):
        """Every cell of the 4-scenario mini-atlas must reproduce
        per-scenario `find_lambda_max` *bit-identically* — same quantized
        grid, same fold_seed streams, same probe order, same verdicts —
        when the sequential path runs at the atlas-wide PadDims."""
        res = mini_atlas
        assert res.n_cells == 4 and res.n_programs == 1
        for row in res.rows:
            seq = find_lambda_max(
                row.scenario, row.policy, eps_b=row.eps_b,
                topo_seed=row.topo_seed, dims=res.dims, **MINI_KW)
            assert row.lam_max == seq.lam_max, row.scenario
            assert (row.lo, row.hi, row.ratio) == (seq.lo, seq.hi,
                                                   seq.ratio)
            assert row.bound_exact == seq.bound_exact
            assert (row.n_calls, row.n_iters) == (seq.n_calls, seq.n_iters)
            assert row.undecided == seq.undecided
            assert row.hi_certain == seq.hi_certain
            assert row.probes == seq.probes, (
                f"{row.scenario}: probe streams diverged")
            assert (row.total_slots, row.slots_saved) == (
                seq.total_slots, seq.slots_saved)

    def test_single_step_compile_per_policy_group(self, mini_atlas):
        """TestNoRecompilation, atlas edition: hundreds of in-place probe
        rewrites must never re-trace — one compiled chunk-step program per
        policy group, total."""
        res = mini_atlas
        assert res.n_step_compiles == res.n_programs == 1, (
            f"atlas retraced: {res.n_step_compiles} chunk-step programs "
            f"for {res.n_programs} groups")
        assert res.n_launches < res.seq_launches
        assert res.launch_speedup > 1.0
        assert res.n_rewrites >= res.n_cells     # every cell re-probed

    def test_atlas_table_reports_families(self, mini_atlas):
        tbl = atlas_table(mini_atlas)
        assert set(tbl["families"]) == {c.scenario for c in MINI_CELLS}
        for fam in tbl["families"].values():
            assert fam["n_cells"] == 1
            assert 0.0 <= fam["ratio_median"] <= 1.0
            cell = fam["cells"][0]
            assert {"lam_max", "bound_exact", "undecided_hi",
                    "hi_certain"} <= set(cell)
        assert tbl["n_step_compiles"] == tbl["n_programs"] == 1


# ---------------------------------------------------------------------------
# UNDECIDED surfacing on the golden frontier (fix satellite)
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestFrontierUndecidedSurfacing:
    def test_golden_bracket_distinguishes_unstable_from_undecided(self):
        """paper_grid at T=2048 ends its search blocked by an UNDECIDED
        probe one grid step above a genuinely UNSTABLE one: the result
        must keep the conservative bracket *and* surface the distinction
        (probe flags, result.undecided, the widened hi_certain)."""
        r = find_lambda_max("paper_grid", "pi3", eps_b=0.05, seeds=(0,),
                            T=2048, chunk=256, rel_tol=0.1, max_calls=8)
        by_k = {p.rate_index: p for p in r.probes}
        kinds = {n for p in r.probes for n in p.verdicts}
        assert {"STABLE", "UNSTABLE", "UNDECIDED"} <= kinds
        unstable = [p for p in r.probes if "UNSTABLE" in p.verdicts]
        undecided = [p for p in r.probes if p.undecided]
        assert unstable and undecided
        for p in undecided:           # the flag means: blocked, not proven
            assert not p.sustainable and "UNSTABLE" not in p.verdicts
        for p in unstable:
            assert not p.undecided
        # conservative bracket unchanged; honest reading surfaced on top
        k_hi = round(r.hi / (0.1 * r.bound_exact))
        assert by_k[k_hi].undecided == r.undecided
        if r.undecided:
            assert r.hi_certain is not None and r.hi_certain > r.hi
        assert r.lam_max >= 0.8 * r.bound_exact

    def test_horizon_too_short_reports_undecided_not_unstable(self):
        """At T=512/chunk=256 no verdict can latch (first possible latch
        is 6 windows = 1536 slots), so every probe is horizon-blocked:
        the search must say UNDECIDED-everywhere (lam_max collapses to 0
        conservatively, nothing is *proven* infeasible)."""
        r = find_lambda_max("paper_grid", "pi3", eps_b=0.05, seeds=(0,),
                            T=512, chunk=256, rel_tol=0.1, max_calls=6)
        assert r.lam_max == 0.0
        assert all(p.undecided for p in r.probes)
        assert all(set(p.verdicts) == {"UNDECIDED"} for p in r.probes)
        assert r.undecided and r.hi_certain is None


# ---------------------------------------------------------------------------
# Early-stop interaction on a mixed multi-rate batch (regression satellite)
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestMixedRateEarlyStopRegression:
    def test_undecided_sims_bit_equal_despite_mid_chunk_deciders(self):
        """The atlas carry in miniature: one padded batch probing three
        different rates, where the stable and unstable sims decide
        mid-run and freeze while the near-critical one rides to the
        horizon.  The undecided sim's metrics must be bit-equal to an
        early_stop=False run — deciders freezing around it must not
        perturb its lanes."""
        jobs = [FleetJob(scenario="paper_grid", policy="pi3", lam=lam,
                         eps_b=0.05, seed=0) for lam in (4.0, 8.2, 8.8)]
        a = run_fleet(jobs, T=2048, chunk=256, early_stop=True)
        b = run_fleet(jobs, T=2048, chunk=256, early_stop=False)
        va, vb = a.verdicts(), b.verdicts()
        assert va == vb == ["STABLE", "UNDECIDED", "UNSTABLE"]
        # the mix is real: both deciders latched before the horizon
        assert a.metrics[0]["decided_at_slot"] < 2048
        assert a.metrics[2]["decided_at_slot"] < 2048
        # ... and the undecided sim is bit-untouched by their freezing
        mu_a, mu_b = dict(a.metrics[1]), dict(b.metrics[1])
        mu_a.pop("slots_saved"), mu_b.pop("slots_saved")
        assert mu_a == mu_b, {
            k: (mu_a[k], mu_b[k]) for k in mu_a if mu_a[k] != mu_b[k]}
        assert a.metrics[1]["slots_saved"] == 0.0
        # deciders agree on verdict/decision slot across modes
        for i in (0, 2):
            assert a.metrics[i]["decided_at_slot"] == \
                b.metrics[i]["decided_at_slot"]


# ---------------------------------------------------------------------------
# Size bucketing (DESIGN.md §13): pure partition properties + validation
# ---------------------------------------------------------------------------

class TestBucketing:
    MIXED = [get_scenario(s).build(0)
             for s in ("ring", "tree", "paper_grid", "expander")]

    def test_single_bucket_is_the_global_hull(self):
        dims, assignment = make_buckets(self.MIXED, n_buckets=1)
        assert dims == [PadDims.of(self.MIXED)]
        assert assignment == [0] * len(self.MIXED)

    def test_two_buckets_cover_and_shrink(self):
        dims, assignment = make_buckets(self.MIXED, n_buckets=2)
        assert len(dims) == 2
        hull = PadDims.of(self.MIXED)
        for p, b in zip(self.MIXED, assignment):
            assert dims[b].fits(p)
        # the small bucket must actually be smaller than the hull on the
        # dominant (edge) axis — the whole point of bucketing
        assert min(d.n_edges for d in dims) < hull.n_edges
        # buckets are ordered by size: bucket 0 never exceeds bucket 1
        assert dims[0].n_edges <= dims[1].n_edges

    def test_identical_shapes_share_a_bucket(self):
        probs = [get_scenario("ring").build(ts) for ts in (0, 1, 2)]
        probs += [get_scenario("expander").build(0)]
        _, assignment = make_buckets(probs, n_buckets=3)
        assert len(set(assignment[:3])) == 1      # all rings together

    def test_more_buckets_than_shapes_drops_empties(self):
        probs = [get_scenario("ring").build(0),
                 get_scenario("expander").build(0)]
        dims, assignment = make_buckets(probs, n_buckets=5)
        assert len(dims) == len(set(assignment)) == 2

    def test_empty_problem_list_raises_clearly(self):
        with pytest.raises(ValueError, match="empty problem sequence"):
            PadDims.of([])
        with pytest.raises(ValueError, match="empty problem sequence"):
            make_buckets([])

    def test_pad_problem_overflow_names_shapes(self):
        big = get_scenario("expander").build(0)
        small = PadDims.of([get_scenario("ring").build(0)])
        with pytest.raises(ValueError, match=r"exceeds pad dims"):
            pad_problem(big, small)

    def test_validate_buckets_actionable_errors(self):
        probs = self.MIXED[:2]
        dims = [PadDims.of(probs)]
        with pytest.raises(ValueError, match="bucket assignments"):
            validate_buckets(probs, dims, [0])
        with pytest.raises(ValueError, match="only 1 buckets exist"):
            validate_buckets(probs, dims, [0, 3])
        tiny = PadDims(n_nodes=2, n_edges=1, n_comp=1)
        with pytest.raises(ValueError, match=r"exceeds bucket 0 dims"):
            validate_buckets(probs, [tiny], [0, 0])


# ---------------------------------------------------------------------------
# Bucketed atlas: bit-equality to the single-bucket path at bucket dims
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestBucketedEquivalence:
    @pytest.fixture(scope="class")
    def bucketed(self):
        return sweep_lambda_max(MINI_CELLS, n_buckets=2, **MINI_KW)

    def test_two_buckets_two_programs_one_group(self, bucketed):
        res = bucketed
        assert res.n_buckets == 2
        # one policy group x 2 buckets: one launch unit (and one compiled
        # step trace) per bucket, counted once per group
        assert res.n_programs == 2
        assert res.n_step_compiles == 2
        assert sum(res.bucket_cells.values()) == res.n_cells
        assert sum(res.bucket_launches.values()) == res.n_launches
        assert all(n > 0 for n in res.bucket_launches.values())
        # result.dims is the hull of the bucket dims
        assert res.dims == PadDims(
            n_nodes=max(d.n_nodes for d in res.bucket_dims),
            n_edges=max(d.n_edges for d in res.bucket_dims),
            n_comp=max(d.n_comp for d in res.bucket_dims))

    def test_rows_bit_identical_to_single_bucket_at_bucket_dims(
            self, bucketed):
        """Per-cell searches must not notice bucketing: every row equals
        the row the single-bucket sweep produces when forced (via explicit
        ``dims``) to the cell's bucket dims."""
        res = bucketed
        by_cell = {(r.scenario, r.topo_seed): r for r in res.rows}
        for b, bdims in enumerate(res.bucket_dims):
            cells_b = [c for c in MINI_CELLS
                       if by_cell[(c.scenario, c.topo_seed)].bucket == b]
            assert cells_b, f"bucket {b} has no cells"
            single = sweep_lambda_max(cells_b, dims=bdims, **MINI_KW)
            for row in single.rows:
                got = by_cell[(row.scenario, row.topo_seed)]
                assert dataclasses.replace(got, bucket=0) == row, (
                    f"{row.scenario}: bucketed != single-bucket at "
                    f"bucket {b} dims")

    def test_cells_assigned_to_fitting_buckets(self, bucketed):
        res = bucketed
        for r in res.rows:
            shape = problem_shape(get_scenario(r.scenario).build(r.topo_seed))
            d = res.bucket_dims[r.bucket]
            assert shape <= (d.n_nodes, d.n_edges, d.n_comp) or \
                d.fits(get_scenario(r.scenario).build(r.topo_seed))


# ---------------------------------------------------------------------------
# Adaptive horizons: UNDECIDED-at-top cells re-queue instead of collapsing
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestAdaptiveRequeue:
    # T=512/chunk=256 can never latch a verdict (first possible latch is
    # 1280 slots), so every fixed-horizon probe is UNDECIDED and the
    # bracket collapses to lam_max = 0 — the bug the re-queue fixes.
    CELLS = [AtlasJob("paper_grid", eps_b=0.05)]
    KW = dict(seeds=(0,), T=512, chunk=256, rel_tol=0.1, max_calls=6)

    def test_fixed_horizon_collapses(self):
        res = sweep_lambda_max(self.CELLS, **self.KW)
        row = res.rows[0]
        assert row.lam_max == 0.0 and row.undecided
        assert res.n_requeues == 0 and row.n_requeues == 0

    def test_requeue_recovers_a_real_bracket(self):
        """With max_requeues=2 the cell restarts at 2xT (1024 slots —
        still short of the 1280-slot latch) and then 4xT (2048 slots),
        where verdicts latch and the search localizes a genuine
        bracket: zero silently-collapsed cells."""
        res = sweep_lambda_max(self.CELLS, max_requeues=2, **self.KW)
        row = res.rows[0]
        assert res.n_requeues == 2 and row.n_requeues == 2
        assert row.lam_max > 0.0, "re-queued cell still collapsed"
        assert row.hi > row.lo > 0.0
        # A 2048-slot horizon localizes conservatively (the bench runs
        # far longer); the point here is a real bracket, not precision.
        assert row.lam_max >= 0.6 * row.bound_exact
        # honest reporting either way: decided, or widened with evidence
        if row.undecided:
            assert row.hi_certain is not None
        # probe streams are decoupled per attempt: call_index == attempt
        attempts = {p.call_index for p in row.probes}
        assert attempts == {0, 1, 2}
        # first-attempt probes are the fixed-horizon probes, bit-equal
        fixed = sweep_lambda_max(self.CELLS, **self.KW).rows[0]
        first = tuple(p for p in row.probes if p.call_index == 0)
        assert first == fixed.probes

    def test_budget_cap_reports_honestly(self):
        """One escalation (1024 slots) still cannot latch: the budget-
        capped cell must report the collapse with its attempt count, not
        pretend it converged."""
        res = sweep_lambda_max(self.CELLS, max_requeues=1, **self.KW)
        row = res.rows[0]
        assert res.n_requeues == 1 and row.n_requeues == 1
        assert row.undecided and row.lam_max == 0.0

    def test_certain_collapse_requeues_too(self):
        """A bracket that collapses with *proven*-UNSTABLE evidence (not
        UNDECIDED) must also burn the re-queue ladder.  At rates far
        below capacity the backpressure gradient fills so slowly that
        the whole horizon sits inside the transient and the drift + gap
        tests latch a *false* UNSTABLE — paper_grid topo_seed 8 / seed 1
        at T=4096 reads proven-UNSTABLE at 0.1x its own exact bound and
        collapses with certainty (hi_certain populated, not UNDECIDED).
        One 2xT rung must repair it: the fresh attempt's top-of-bracket
        probe decides STABLE on the longer run and the search ascends
        to the true bound instead of reporting 0."""
        cells = [AtlasJob("paper_grid", topo_seed=8, eps_b=0.05)]
        kw = dict(seeds=(1,), T=4096, chunk=512, rel_tol=0.1, max_calls=8)
        base = sweep_lambda_max(cells, **kw).rows[0]
        # the bug: a false-certain collapse — no UNDECIDED escape hatch
        assert base.lam_max == 0.0 and not base.undecided
        assert base.hi_certain is not None
        res = sweep_lambda_max(cells, max_requeues=1, **kw)
        row = res.rows[0]
        assert res.n_requeues == 1 and row.n_requeues == 1
        # the rung disambiguates transient from instability: full repair
        assert row.lam_max == pytest.approx(row.bound_exact)
        # both attempts ran, with decoupled fold_seed streams
        assert {p.call_index for p in row.probes} == {0, 1}
        # first-attempt probes are the fixed-horizon probes, bit-equal
        first = tuple(p for p in row.probes if p.call_index == 0)
        assert first == base.probes


# ---------------------------------------------------------------------------
# Seed replication: rows and bands invariant to cell dispatch order
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestSeedBandsDeterminism:
    CELLS = [AtlasJob("random_geometric", topo_seed=ts, eps_b=0.05)
             for ts in (0, 1, 2)]
    KW = dict(seeds=(0, 1), T=1536, chunk=256, rel_tol=0.2, max_calls=6,
              n_buckets=2)

    def test_bands_invariant_to_dispatch_order(self):
        a = sweep_lambda_max(self.CELLS, **self.KW)
        b = sweep_lambda_max(list(reversed(self.CELLS)), **self.KW)
        rows_a = {(r.scenario, r.topo_seed): r for r in a.rows}
        rows_b = {(r.scenario, r.topo_seed): r for r in b.rows}
        assert rows_a == rows_b
        ta, tb = atlas_table(a), atlas_table(b)
        assert ta["families"] == tb["families"]
        band = ta["families"]["random_geometric"]["band"]
        assert band["q10"] <= band["q90"]
        assert band["width"] == band["q90"] - band["q10"]
        assert a.bucket_cells == b.bucket_cells

    def test_atlas_table_reports_buckets_and_bands(self):
        res = sweep_lambda_max(self.CELLS, **self.KW)
        tbl = atlas_table(res)
        assert tbl["n_buckets"] == res.n_buckets
        assert len(tbl["bucket_dims"]) == res.n_buckets
        assert tbl["n_requeues"] == res.n_requeues
        fam = tbl["families"]["random_geometric"]
        assert {"band", "n_requeued"} <= set(fam)
        for cell in fam["cells"]:
            assert {"bucket", "n_requeues"} <= set(cell)


# ---------------------------------------------------------------------------
# Atlas-over-policies: the policy-surface table
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestPolicySurface:
    def test_surface_shares_grid_and_pivots(self):
        res = sweep_policy_surface(
            ["paper_grid"], [0], policies=("pi3", "pi3bar"), eps_b=0.05,
            seeds=(0,), T=2048, chunk=256, rel_tol=0.2, max_calls=6)
        assert res.n_cells == 2
        policies = {r.policy for r in res.rows}
        assert policies == {"pi3", "pi3bar"}
        # both policies measured against the same exact bound per cell
        bounds = {r.policy: r.bound_exact for r in res.rows}
        assert bounds["pi3"] > 0 and bounds["pi3bar"] > 0
        tbl = policy_surface_table(res)
        assert set(tbl["policies"]) == policies
        assert tbl["families"] == ["paper_grid"]
        gaps = [tbl["policies"][p]["paper_grid"]["gap_vs_best"]
                for p in policies]
        assert min(gaps) == 0.0 and all(g >= 0.0 for g in gaps)
        for p in policies:
            row = tbl["policies"][p]["paper_grid"]
            assert row["band"]["width"] >= 0.0
