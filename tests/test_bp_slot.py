"""Parity suite for the fused Pallas slot-step kernels (bp_slot).

Every test runs the kernels in interpret mode (the CPU CI code path,
`scripts/test.sh` re-runs this module under `JAX_PLATFORMS=cpu`) and
asserts *bit-exact* agreement with the pure-jnp oracle `bp_slot/ref.py` —
the contract that lets `PolicyConfig.backend` switch the fleet's hot loop
freely (DESIGN.md §7).  Marker: `pallas`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # property test below widens coverage when hypothesis exists;
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # the deterministic grid always runs
    HAVE_HYPOTHESIS = False

from repro.core import PolicyConfig, paper_grid_problem
from repro.core.policies import slot_step
from repro.core.queues import init_state
from repro.fleet import PadDims, get_scenario, pad_problem
from repro.kernels.bp_slot.kernel import comp_balance_decide, slot_route_decide
from repro.kernels.bp_slot.ops import slot_route_op, slot_route_op_ref
from repro.kernels.bp_slot.ref import comp_balance_ref, slot_route_ref

pytestmark = pytest.mark.pallas


def _state_leaves_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# Kernel-level parity (tiled passes vs the materializing oracle)
# ---------------------------------------------------------------------------

class TestRouteDecide:
    @pytest.mark.parametrize("block_e,block_c", [(128, None), (8, 4), (16, 3),
                                                 (7, 5)])
    def test_blocks_match_ref_bitwise(self, block_e, block_c):
        key = jax.random.key(0)
        N, C, E = 24, 15, 50
        Qf = jax.random.uniform(key, (N, C)) * 100
        m = jax.random.randint(jax.random.fold_in(key, 1), (E,), 0, N)
        l = (m + 1 + jax.random.randint(jax.random.fold_in(key, 2), (E,),
                                        0, N - 1)) % N
        best, dmax = slot_route_decide(Qf, m, l, block_e=block_e,
                                       block_c=block_c)
        rbest, rdmax = slot_route_ref(Qf, m, l)
        np.testing.assert_array_equal(np.asarray(best), np.asarray(rbest))
        np.testing.assert_array_equal(np.asarray(dmax), np.asarray(rdmax))

    def test_tie_break_matches_argmax_first_occurrence(self):
        """Regression (tie-break contract, DESIGN.md §7): duplicated class
        columns force exact ties across tiles; the kernel must keep the
        *lowest* flat index, like `jnp.argmax`, even when the duplicate
        lands in a later tile."""
        key = jax.random.key(3)
        base = jax.random.uniform(key, (10, 4)) * 50
        Qf = jnp.tile(base, (1, 3))                     # classes repeat x3
        m = jnp.arange(5, dtype=jnp.int32)
        l = jnp.arange(5, 10, dtype=jnp.int32)
        for block_c in (4, 3, 2, 12):
            best, dmax = slot_route_decide(Qf, m, l, block_e=5,
                                           block_c=block_c)
            rbest, rdmax = slot_route_ref(Qf, m, l)
            np.testing.assert_array_equal(np.asarray(best), np.asarray(rbest),
                                          err_msg=f"block_c={block_c}")
            assert np.all(np.asarray(rbest) < 4)        # ties resolve low
            np.testing.assert_array_equal(np.asarray(dmax), np.asarray(rdmax))

    def test_all_zero_diff_keeps_index_zero(self):
        Qf = jnp.ones((6, 9)) * 7.0
        m = jnp.array([0, 1], jnp.int32)
        l = jnp.array([2, 3], jnp.int32)
        best, dmax = slot_route_decide(Qf, m, l, block_e=2, block_c=3)
        np.testing.assert_array_equal(np.asarray(best), 0)
        np.testing.assert_array_equal(np.asarray(dmax), 0.0)

    def test_standalone_op_full_decision(self):
        key = jax.random.key(9)
        N, NC, E = 16, 4, 45
        Q = jax.random.uniform(key, (N, 3, NC)) * 100
        edges = jax.random.randint(jax.random.fold_in(key, 1), (E, 2), 0, N)
        edges = edges.at[:, 1].set((edges[:, 1] + 1 + edges[:, 0]) % N)
        cap = jax.random.uniform(jax.random.fold_in(key, 2), (E,)) * 5
        out = slot_route_op(Q, edges, cap)
        ref = slot_route_op_ref(Q, edges, cap)
        for got, want, name in zip(out, ref, ("class", "comp", "dir", "rate")):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=name)


class TestCompBalanceDecide:
    def _panels(self, key, NC, mask=None):
        r = lambda i, lo=0.0, hi=10.0: lo + jax.random.uniform(
            jax.random.fold_in(key, i), (NC,)) * (hi - lo)
        return dict(
            q0=r(0), q1=r(1), q2=r(2), H=r(3), caps=r(4, 0.5, 3.0),
            mask=jnp.ones((NC,)) if mask is None else mask,
            x1=r(5), x2=r(6), ca1=r(7, 5.0, 20.0), ca2=r(8, 5.0, 20.0),
            cc=r(9, 0.0, 5.0), x_net=r(10))

    @pytest.mark.parametrize("pairing", ["fifo", "bound"])
    @pytest.mark.parametrize("block_n", [128, 4, 3])
    def test_blocks_match_ref_bitwise(self, pairing, block_n):
        NC = 10
        p = self._panels(jax.random.key(1), NC)
        eps = jnp.float32(0.05)
        Z, n_star = comp_balance_decide(eps, *p.values(), pairing=pairing,
                                        block_n=block_n)
        rZ, rn = comp_balance_ref(eps, **p, pairing=pairing,
                                  thresholded=False, threshold=0.0)
        np.testing.assert_array_equal(np.asarray(Z), np.asarray(rZ))
        assert int(n_star) == int(rn)

    def test_thresholded_gate(self):
        NC = 6
        p = self._panels(jax.random.key(2), NC)
        eps = jnp.float32(0.01)
        for thr in (0.0, 5.0, 100.0):
            Z, n = comp_balance_decide(eps, *p.values(), thresholded=True,
                                       threshold=thr, block_n=3)
            rZ, rn = comp_balance_ref(eps, **p, pairing="fifo",
                                      thresholded=True, threshold=thr)
            np.testing.assert_array_equal(np.asarray(Z), np.asarray(rZ))
            assert int(n) == int(rn)

    def test_masked_nodes_never_win_even_all_masked(self):
        NC = 8
        key = jax.random.key(4)
        down = (jax.random.uniform(jax.random.fold_in(key, 99), (NC,))
                > 0.5).astype(jnp.float32)
        for mask in (down, jnp.zeros((NC,))):
            p = self._panels(key, NC, mask=mask)
            Z, n_star = comp_balance_decide(jnp.float32(0.1), *p.values(),
                                            block_n=4)
            rZ, rn = comp_balance_ref(jnp.float32(0.1), **p, pairing="fifo",
                                      thresholded=False, threshold=0.0)
            np.testing.assert_array_equal(np.asarray(Z), np.asarray(rZ))
            assert int(n_star) == int(rn)
            if bool(mask.any()):
                assert float(mask[int(n_star)]) == 1.0

    def test_eps_is_traced_per_job_data(self):
        """vmap over eps_B must not fork the kernel and must match the
        oracle per job."""
        NC = 5
        p = self._panels(jax.random.key(7), NC)
        epss = jnp.array([0.0, 0.05, 0.3], jnp.float32)
        Zs, ns = jax.vmap(lambda e: comp_balance_decide(
            e, *p.values(), block_n=2))(epss)
        for i, e in enumerate(epss):
            rZ, rn = comp_balance_ref(e, **p, pairing="fifo",
                                      thresholded=False, threshold=0.0)
            np.testing.assert_array_equal(np.asarray(Zs[i]), np.asarray(rZ))
            assert int(ns[i]) == int(rn)


# ---------------------------------------------------------------------------
# slot_step backend parity over random masked PaddedProblems
# ---------------------------------------------------------------------------

SCEN_NAMES = ("paper_grid", "ring", "fat_tree")


def _check_slot_step_parity(scen, policy, pad_extra, eps_b, fail_pattern,
                            seed):
    """`slot_step(backend="pallas", interpret=True)` must equal
    `backend="xla"` bit-exactly on padded problems with failed comp nodes
    and a traced eps_B — every state leaf, every metric, every slot."""
    problem = get_scenario(scen).build(0)
    dims = PadDims(problem.graph.n_nodes + pad_extra,
                   problem.graph.n_edges + 2 * pad_extra,
                   problem.n_comp + pad_extra)
    pp = pad_problem(problem, dims)
    # knock out comp nodes by bit pattern (never all of the real ones)
    comp_scale = jnp.array(
        [0.0 if (fail_pattern >> (i % 3)) & 1 and i > 0 else 1.0
         for i in range(dims.n_comp)], jnp.float32)
    pp = pp.with_capacity_scales(jnp.ones(pp.n_edges), comp_scale)

    key = jax.random.key(seed)
    states, metrics = [], []
    for backend in ("xla", "pallas"):
        cfg = PolicyConfig(name=policy, eps_b=eps_b, threshold=1.5,
                           backend=backend)
        state = init_state(pp)
        ms = []
        for t in range(8):
            kt = jax.random.fold_in(key, t)
            arr = jnp.float32(1.0 + 0.5 * t)
            state, m = slot_step(pp, cfg, state, arr, kt,
                                 eps_b=jnp.float32(eps_b))
            ms.append(m)
        states.append(state)
        metrics.append(ms)
    assert _state_leaves_equal(states[0], states[1])
    for mx, mp in zip(metrics[0], metrics[1]):
        for k in mx:
            np.testing.assert_array_equal(np.asarray(mx[k]),
                                          np.asarray(mp[k]), err_msg=k)


@pytest.mark.parametrize(
    "scen,policy,pad_extra,eps_b,fail_pattern,seed",
    [("paper_grid", "pi3", 2, 0.05, 5, 0),
     ("paper_grid", "pi1p", 0, 0.0, 0, 1),
     ("ring", "pi3bar", 3, 0.2, 3, 2),
     ("fat_tree", "pi3", 1, 0.01, 6, 3)])
def test_slot_step_backend_parity_grid(scen, policy, pad_extra, eps_b,
                                       fail_pattern, seed):
    """Deterministic selection of the parity property (always runs, even
    without hypothesis)."""
    _check_slot_step_parity(scen, policy, pad_extra, eps_b, fail_pattern,
                            seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(scen=st.sampled_from(SCEN_NAMES),
           policy=st.sampled_from(("pi3", "pi3bar", "pi1", "pi1p")),
           pad_extra=st.integers(0, 3),
           eps_b=st.sampled_from((0.0, 0.01, 0.2)),
           fail_pattern=st.integers(0, 7),
           seed=st.integers(0, 99))
    def test_slot_step_backend_parity_property(scen, policy, pad_extra,
                                               eps_b, fail_pattern, seed):
        _check_slot_step_parity(scen, policy, pad_extra, eps_b, fail_pattern,
                                seed)


def test_slot_step_parity_regulated_jitted_scan():
    """The fleet path: jitted scan over slots, regulated policy, padded
    problem, traced eps — bit-exact across backends."""
    p = paper_grid_problem()
    pp = pad_problem(p, PadDims(20, 30, 6))

    def run(backend):
        cfg = PolicyConfig(name="pi3_reg", eps_b=0.05, backend=backend)

        @jax.jit
        def go(key):
            def body(carry, t):
                state = carry
                kt = jax.random.fold_in(key, t)
                state, m = slot_step(pp, cfg, state, jnp.float32(3.0), kt,
                                     eps_b=jnp.float32(0.05))
                return state, m["delivered_useful"]
            return jax.lax.scan(body, init_state(pp), jnp.arange(64))
        return go(jax.random.key(5))

    sx, dx = run("xla")
    sp_, dp = run("pallas")
    assert _state_leaves_equal(sx, sp_)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(dp))
    assert float(np.asarray(dx)[-1]) > 0.0      # the run actually delivers


def test_backend_validation():
    with pytest.raises(ValueError, match="unknown backend"):
        PolicyConfig(name="pi3", backend="cuda")
