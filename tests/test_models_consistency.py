"""Decode/prefill consistency: step-by-step decoding must reproduce the
parallel (train/prefill) forward logits.  This validates the KV caches,
RoPE offsets, ring-buffer windows, the Mamba2 chunked SSD scan against its
own recurrence, and the mLSTM parallel form against its recurrent form.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import get_model, split_tree

S = 12
B = 2

CONSISTENCY_ARCHS = [
    "olmo-1b",            # plain dense
    "qwen2-0.5b",         # GQA + bias
    "gemma3-27b",         # local:global pattern + ring-buffer window caches
    "granite-moe-1b-a400m",  # MoE decode
    "zamba2-2.7b",        # Mamba2 chunked scan vs recurrence + shared attn
    "xlstm-350m",         # mLSTM parallel vs recurrent + sLSTM scan
    "seamless-m4t-large-v2",  # enc-dec with cross cache
]


def _setup(arch):
    cfg = reduced(get_config(arch))
    api = get_model(cfg)
    params, _ = split_tree(api.init(key=jax.random.key(0)))
    tokens = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)
    return cfg, api, params, tokens


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    cfg, api, params, tokens = _setup(arch)
    ms = api.init_state()

    if cfg.family == "encdec":
        from repro.models import encdec
        frames = jax.random.normal(jax.random.key(4), (B, S, cfg.d_model))
        memory = encdec.encode(cfg, params, frames, remat="none")
        full = encdec.decode_fwd(cfg, params, tokens, memory,
                                 activ_dtype=jnp.float32, remat="none")
        caches = encdec.build_cross_cache(cfg, params, memory, S + 2,
                                          jnp.float32)
    else:
        batch = {"tokens": tokens}
        full, _, _ = api.logits(params, batch, activ_dtype=jnp.float32,
                                router_H=ms.router_H)
        caches = api.init_decode(B, S + 2, jnp.float32)

    full = np.asarray(full)           # [B, S, V]
    for t in range(S):
        step, caches = api.decode_step(params, caches,
                                       {"tokens": tokens[:, t]},
                                       activ_dtype=jnp.float32,
                                       router_H=ms.router_H)
        np.testing.assert_allclose(np.asarray(step), full[:, t],
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"{arch} step {t}")


def test_mamba_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    import dataclasses
    from repro.models.mamba import init_mamba, mamba_fwd
    from repro.models.common import Init, split_tree as st
    cfg16 = reduced(get_config("zamba2-2.7b"))
    p, _ = st(init_mamba(cfg16, Init(key=jax.random.key(0))))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg16.d_model))
    outs = []
    for chunk in (4, 8, 16, 32):
        c = dataclasses.replace(cfg16, ssm_chunk=chunk)
        outs.append(np.asarray(mamba_fwd(c, p, x)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)


def test_sliding_window_masks_distant_tokens():
    """With a window, changing tokens far in the past must not change the
    current logits (locality), but changing recent ones must."""
    cfg = reduced(get_config("gemma3-27b"))
    # single local layer stack for a sharp test
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=6, local_global=5, window=4)
    api = get_model(cfg)
    params, _ = split_tree(api.init(key=jax.random.key(0)))
    toks = jax.random.randint(jax.random.key(5), (1, 16), 0, cfg.vocab)
    base, _, _ = api.logits(params, {"tokens": toks},
                            activ_dtype=jnp.float32)
    # NOTE: global layers see everything, so only check the *local* masking
    # via the attention module directly.
    from repro.models.attention import _mask
    pos = jnp.arange(10)[None, :]
    m = _mask(pos, pos, causal=True, window=4)
    m = np.asarray(m[0])
    assert m[9, 9] and m[9, 6]
    assert not m[9, 5] and not m[9, 0]
    assert not m[0, 9]
