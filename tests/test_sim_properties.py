"""Hypothesis property tests on the queue-network invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import (ComputeProblem, PolicyConfig, grid_graph,
                        triangle_graph)
from repro.sim import simulate
from repro.sim.workload import constant_arrivals


def _run(policy, lam, T, seed, problem=None, **kw):
    p = problem or ComputeProblem(triangle_graph(4.0), 0, 1, 2, (2,), (2.0,))
    return p, simulate(p, PolicyConfig(name=policy, **kw), lam, T, seed=seed)


@settings(max_examples=8, deadline=None)
@given(lam=st.floats(0.2, 3.5), seed=st.integers(0, 2**16),
       policy=st.sampled_from(["pi1", "pi2", "pi3", "pi3bar"]))
def test_packet_conservation(lam, seed, policy):
    """Raw packets in = raw in queues + combined*2; results out <= combined."""
    p, res = _run(policy, lam, 400, seed)
    s = res.final_state
    injected = 2.0 * float(s.cum_arr.sum() / 2 + 0)  # arrivals tracked below
    raw_in_net = float(s.Q[:, 1:, :].sum())
    raw_at_comp = float(s.X.sum())
    combined = float(s.cum_comb.sum())
    # Each query injects 2 raw packets. Total injected raw = in-network raw
    # + raw at comp nodes + 2 * combined.
    total_raw_injected = raw_in_net + raw_at_comp + 2.0 * combined
    # delivered useful results can never exceed what was combined
    assert float(s.delivered_useful) <= combined + 1e-2
    # all tracked quantities non-negative
    assert min(raw_in_net, raw_at_comp, combined) >= -1e-3
    assert total_raw_injected >= 2.0 * combined - 1e-2


@settings(max_examples=6, deadline=None)
@given(lam=st.floats(0.2, 1.8), seed=st.integers(0, 2**16))
def test_delivered_monotone_nondecreasing(lam, seed):
    _, res = _run("pi3", lam, 300, seed,
                  problem=ComputeProblem(grid_graph(3, 3, 3.0), 0, 2, 8,
                                         (4,), (2.0,)))
    d = np.asarray(res.delivered)
    assert np.all(np.diff(d) >= -1e-4)
    du = np.asarray(res.delivered_useful)
    assert np.all(du <= d + 1e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), eps=st.floats(0.01, 0.3))
def test_dummy_fraction_bounded_by_eps(seed, eps):
    """Long-run dummy fraction of delivered packets ~ eps_B/(1+eps_B)."""
    p = ComputeProblem(triangle_graph(4.0), 0, 1, 2, (0,), (2.0,))
    res = simulate(p, PolicyConfig(name="pi2", eps_b=eps), 1.0, 2500, seed=seed)
    d, du = float(res.delivered[-1]), float(res.delivered_useful[-1])
    if d > 100:
        frac = (d - du) / d
        assert frac <= eps / (1 + eps) + 0.1


@settings(max_examples=4, deadline=None)
@given(lam=st.floats(0.5, 1.8))
def test_fluid_constant_arrivals_track_rate(lam):
    """With deterministic fluid arrivals below capacity, the delivered-useful
    rate converges to lambda."""
    p = ComputeProblem(triangle_graph(4.0), 0, 1, 2, (2,), (2.0,))
    arr = constant_arrivals(lam, 2500)
    res = simulate(p, PolicyConfig(name="pi1"), lam, 2500, seed=0, arrivals=arr)
    assert abs(float(res.useful_rate(800)) - lam) < 0.25
