"""Arrival-process statistics and randomized greedy-matching properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import grid_graph
from repro.core.policies import greedy_maximal_matching
from repro.sim.workload import (bernoulli_batch_arrivals, constant_arrivals,
                                poisson_arrivals)


class TestBernoulliBatchArrivals:
    @pytest.mark.parametrize("lam,batch", [(0.5, 4), (1.0, 4), (2.0, 8),
                                           (3.5, 4)])
    def test_mean_rate(self, lam, batch):
        """E[A(t)] = lam as long as lam <= batch (p = lam/batch <= 1)."""
        T = 40_000
        arr = bernoulli_batch_arrivals(jax.random.key(0), lam, T, batch=batch)
        assert float(arr.mean()) == pytest.approx(lam, rel=0.05)

    def test_values_are_zero_or_batch(self):
        arr = bernoulli_batch_arrivals(jax.random.key(1), 1.0, 5000, batch=4)
        vals = set(np.unique(np.asarray(arr)).tolist())
        assert vals <= {0.0, 4.0}

    def test_rate_saturates_at_batch(self):
        """p is clipped at 1: requesting lam > batch delivers exactly batch
        every slot (the documented burst ceiling)."""
        arr = bernoulli_batch_arrivals(jax.random.key(2), 10.0, 1000, batch=4)
        assert float(arr.min()) == 4.0 and float(arr.max()) == 4.0

    def test_other_processes_match_rates(self):
        T = 40_000
        pois = poisson_arrivals(jax.random.key(3), 2.0, T)
        assert float(pois.mean()) == pytest.approx(2.0, rel=0.05)
        const = constant_arrivals(1.7, 100)
        assert float(const.min()) == float(const.max()) == pytest.approx(1.7)


class TestGreedyMatchingProperties:
    """Randomized invariants beyond the fixed cases in test_policies.py."""

    @pytest.mark.parametrize("seed", range(6))
    def test_node_exclusive_on_random_weights(self, seed):
        g = grid_graph(4, 4, 1.0)
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.uniform(0.0, 10.0, size=g.n_edges))
        sel = np.asarray(greedy_maximal_matching(
            jnp.asarray(g.edges), w, g.n_nodes))
        used = np.zeros(g.n_nodes, int)
        for (m, l), s in zip(g.edges, sel):
            if s:
                used[m] += 1
                used[l] += 1
        assert used.max() <= 1, "two active links share a node"

    @pytest.mark.parametrize("seed", range(6))
    def test_matching_is_maximal(self, seed):
        """No positive-weight link with two free endpoints stays idle."""
        g = grid_graph(3, 5, 1.0)
        rng = np.random.default_rng(100 + seed)
        w_np = rng.uniform(0.1, 5.0, size=g.n_edges)
        sel = np.asarray(greedy_maximal_matching(
            jnp.asarray(g.edges), jnp.asarray(w_np), g.n_nodes))
        used = np.zeros(g.n_nodes, bool)
        for (m, l), s in zip(g.edges, sel):
            if s:
                used[m] = used[l] = True
        for (m, l), s in zip(g.edges, sel):
            assert s or used[m] or used[l], (
                f"link ({m},{l}) could have been activated")

    @pytest.mark.parametrize("seed", range(4))
    def test_zero_weight_links_never_activate(self, seed):
        g = grid_graph(4, 4, 1.0)
        rng = np.random.default_rng(200 + seed)
        w_np = rng.uniform(0.0, 5.0, size=g.n_edges)
        zero = rng.uniform(size=g.n_edges) < 0.5
        w_np[zero] = 0.0
        sel = np.asarray(greedy_maximal_matching(
            jnp.asarray(g.edges), jnp.asarray(w_np), g.n_nodes))
        assert not sel[zero].any()
