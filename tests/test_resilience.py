"""Resilience-plane tests (DESIGN.md §12): hardened checkpointing (atomic
publish, per-leaf sha256, corrupt-step fallback), the injectable fault
plane (launch failures -> bounded retry, preemption -> durable snapshot,
host dropout -> graceful lane degradation), and the headline contract —
kill-and-resume at any chunk boundary reproduces the uninterrupted run
bit-exactly (metrics, lambda_max brackets, slot accounting, stream
records) for all three engines."""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointCorruption
from repro.fleet import (FleetJob, registry_cells, run_fleet,
                         sweep_lambda_max)
from repro.obs import schema
from repro.obs.emitter import StreamSink
from repro.runtime.fault import (FaultExhausted, FaultPlane, InjectedFault,
                                 Preempted)
from repro.runtime.resilience import (ResilienceConfig, host_lane_mask,
                                      maybe_resilient, run_signature)
from repro.serving import ServingJob, run_serving

REPO = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# Checkpointer hardening: atomic publish, checksums, corruption fallback
# ---------------------------------------------------------------------------

def _state(seed):
    rng = np.random.default_rng(seed)
    return {"a": rng.standard_normal((4, 3)).astype(np.float32),
            "t": np.int32(seed)}


class TestCheckpointer:
    def test_save_restore_with_extra_payload(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        st = _state(1)
        ck.save(1, st, extra={"group": 0, "launched": 3, "pi": 0.25})
        out = ck.restore(st)
        np.testing.assert_array_equal(out["a"], st["a"])
        assert out["t"] == st["t"]
        assert ck.extra(1) == {"group": 0, "launched": 3, "pi": 0.25}
        # atomic publish: no tmp dirs survive a completed save
        assert not list(tmp_path.glob(".tmp_*"))

    def test_background_save_then_wait(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(1, _state(1), blocking=False)
        ck.wait()
        assert ck.all_steps() == [1]
        np.testing.assert_array_equal(ck.restore(_state(0))["a"],
                                      _state(1)["a"])

    def test_corruption_detected_and_fallback(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=3)
        ck.save(1, _state(1), extra={"step": 1})
        ck.save(2, _state(2), extra={"step": 2})
        # torn write / bit rot in the newest step's array payload
        arr = tmp_path / "step_00000002" / "arr_0.npy"
        raw = bytearray(arr.read_bytes())
        raw[-1] ^= 0xFF
        arr.write_bytes(bytes(raw))
        with pytest.raises(CheckpointCorruption, match="sha256"):
            ck.restore(_state(0))
        # fallback walks back to the newest *intact* step: one snapshot
        # interval lost, never the run
        assert ck.restored_step(fallback=True) == 1
        out = ck.restore(_state(0), fallback=True)
        np.testing.assert_array_equal(out["a"], _state(1)["a"])

    def test_unreadable_manifest_falls_back(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(1, _state(1))
        ck.save(2, _state(2))
        (tmp_path / "step_00000002" / "manifest.json").write_text("{tor")
        assert ck.restored_step(fallback=True) == 1

    def test_keep_last_k_gc(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _state(s))
        assert ck.all_steps() == [3, 4]


# ---------------------------------------------------------------------------
# Fault plane: deterministic schedules, bounded retry, dropout sets
# ---------------------------------------------------------------------------

class TestFaultPlane:
    def test_preempt_fires_exactly_once_at_boundary(self):
        fp = FaultPlane.preempt_after(3)
        fp.maybe_preempt(2)
        with pytest.raises(Preempted):
            fp.maybe_preempt(3)
        fp.maybe_preempt(4)

    def test_launch_fail_budget_is_shared_across_attempts(self):
        fp = FaultPlane.launch_fail(at_launch=5, fails=2)
        for _ in range(2):
            with pytest.raises(InjectedFault):
                fp.on_launch(0, 5)
        fp.on_launch(0, 5)          # budget spent: the retry succeeds
        assert fp.n_injected == 2

    def test_dead_hosts_monotone(self):
        fp = FaultPlane([*FaultPlane.host_dropout(2, at_launch=1).specs,
                         *FaultPlane.host_dropout(0, at_launch=3).specs])
        assert fp.dead_hosts(0) == ()
        assert fp.dead_hosts(1) == (2,)
        assert fp.dead_hosts(3) == (0, 2)
        assert fp.dead_hosts(99) == (0, 2)

    def test_host_lane_mask_contiguous_blocks(self):
        mask = host_lane_mask(8, 4, (1, 3))
        np.testing.assert_array_equal(
            mask, [False, False, True, True, False, False, True, True])

    def test_retry_recovers_within_budget(self):
        rt = maybe_resilient(
            ResilienceConfig(fault_plane=FaultPlane.launch_fail(0, fails=2),
                             max_retries=3),
            "unit")
        calls = []
        out = rt.launch(0, 0, lambda x: calls.append(x) or x, 7)
        assert out == 7 and calls == [7]
        assert rt.n_retries == 2

    def test_retry_exhaustion_raises(self):
        rt = maybe_resilient(
            ResilienceConfig(fault_plane=FaultPlane.launch_fail(0, fails=9),
                             max_retries=2),
            "unit")
        with pytest.raises(FaultExhausted):
            rt.launch(0, 0, lambda: 0)
        assert rt.n_retries == 3    # initial + 2 retries, all failed

    def test_signature_guards_against_run_blending(self, tmp_path):
        assert run_signature("fleet", T=512) == run_signature("fleet", T=512)
        assert run_signature("fleet", T=512) != run_signature("fleet", T=256)
        ck = Checkpointer(tmp_path)
        ck.save(1, (), extra={"engine": "fleet",
                              "signature": run_signature("fleet", T=512)})
        with pytest.raises(ValueError, match="signature mismatch"):
            maybe_resilient(ResilienceConfig(checkpoint_dir=str(tmp_path)),
                            "fleet", T=256)
        with pytest.raises(ValueError, match="belongs to"):
            maybe_resilient(ResilienceConfig(checkpoint_dir=str(tmp_path)),
                            "serving", T=512)


# ---------------------------------------------------------------------------
# Kill-and-resume bit-equality, all three engines
# ---------------------------------------------------------------------------

FLEET_JOBS = [FleetJob(scenario=scen, policy="pi3_reg", lam=lam,
                       eps_b=0.05, seed=s)
              for scen, lam in (("paper_grid", 4.0), ("ge_grid", 3.0))
              for s in (0, 1)]
SERVING_JOBS = [ServingJob(trace="bursty", lam=3.0, seed=s) for s in (0, 1)]
ATLAS_CELLS = registry_cells(("paper_grid", "ring"), topo_seeds=(0, 1),
                             eps_b=0.05)
ATLAS_KW = dict(seeds=(0,), T=512, chunk=256, rel_tol=0.1, max_calls=4)


def _metrics_equal(off, on):
    assert len(off) == len(on)
    for m0, m1 in zip(off, on):
        assert set(m0) == set(m1)
        for k in m0:
            assert m0[k] == m1[k], (k, m0[k], m1[k])


def _stream_equal(base_path, resumed_path):
    """The resumed file, resume seam markers stripped, must be the base
    stream byte-for-byte (records are canonical sorted-key JSON)."""
    with open(base_path) as f:
        base = [json.loads(x) for x in f]
    with open(resumed_path) as f:
        merged = [json.loads(x) for x in f]
    seams = [r for r in merged if r["kind"] == "resume"]
    assert seams, "resumed run emitted no resume record"
    assert [r for r in merged if r["kind"] != "resume"] == base
    assert schema.validate_stream(merged) == []
    return seams


def _kill_and_resume(run, kill_at, ckpt_dir, stream_path):
    """Run `run` with a preempt at boundary `kill_at`, then resume it."""
    with pytest.raises(Preempted):
        run(resilience=ResilienceConfig(
            checkpoint_dir=str(ckpt_dir),
            fault_plane=FaultPlane.preempt_after(kill_at)),
            stream_path=str(stream_path))
    return run(resilience=ResilienceConfig(checkpoint_dir=str(ckpt_dir)),
               stream_path=str(stream_path))


@pytest.mark.fleet_smoke
class TestFleetResume:
    @pytest.fixture(scope="class")
    def base(self, tmp_path_factory):
        path = tmp_path_factory.mktemp("fleet") / "base_stream.jsonl"
        res = run_fleet(FLEET_JOBS, T=512, chunk=128, stream_path=str(path))
        return res, path

    # one program group, 4 chunk launches: every boundary incl. the last
    # (post-launch, pre-finalize — resume recomputes the finalize)
    @pytest.mark.parametrize("kill_at", range(1, 5))
    def test_kill_at_every_boundary_bit_exact(self, base, tmp_path,
                                              kill_at):
        base_res, base_path = base
        res = _kill_and_resume(
            lambda **kw: run_fleet(FLEET_JOBS, T=512, chunk=128, **kw),
            kill_at, tmp_path / "ckpt", tmp_path / "stream.jsonl")
        _metrics_equal(base_res.metrics, res.metrics)
        assert res.slots_saved == base_res.slots_saved
        assert res.launch_slots_saved == base_res.launch_slots_saved
        assert res.resumed_from == kill_at
        assert res.degraded == {} and res.n_fault_retries == 0
        seams = _stream_equal(base_path, tmp_path / "stream.jsonl")
        assert seams[0]["engine"] == "fleet"
        assert seams[0]["ckpt_step"] == kill_at

    def test_early_stop_resume_bit_exact(self, tmp_path):
        kw = dict(T=2048, chunk=256, early_stop=True)
        base = run_fleet(FLEET_JOBS, **kw)
        res = _kill_and_resume(
            lambda **over: run_fleet(FLEET_JOBS, **kw, **over),
            2, tmp_path / "ckpt", tmp_path / "stream.jsonl")
        _metrics_equal(base.metrics, res.metrics)
        assert res.slots_saved == base.slots_saved
        assert res.launch_slots_saved == base.launch_slots_saved

    def test_resume_false_starts_fresh(self, base, tmp_path):
        base_res, _ = base
        with pytest.raises(Preempted):
            run_fleet(FLEET_JOBS, T=512, chunk=128,
                      resilience=ResilienceConfig(
                          checkpoint_dir=str(tmp_path),
                          fault_plane=FaultPlane.preempt_after(2)))
        res = run_fleet(FLEET_JOBS, T=512, chunk=128,
                        resilience=ResilienceConfig(
                            checkpoint_dir=str(tmp_path), resume=False))
        assert res.resumed_from is None
        _metrics_equal(base_res.metrics, res.metrics)


@pytest.mark.fleet_smoke
class TestServingResume:
    @pytest.fixture(scope="class")
    def base(self, tmp_path_factory):
        path = tmp_path_factory.mktemp("serving") / "base_stream.jsonl"
        res = run_serving(SERVING_JOBS, T=512, chunk=128,
                          stream_path=str(path))
        return res, path

    @pytest.mark.parametrize("kill_at", range(1, 5))
    def test_kill_at_every_boundary_bit_exact(self, base, tmp_path,
                                              kill_at):
        base_res, base_path = base
        res = _kill_and_resume(
            lambda **kw: run_serving(SERVING_JOBS, T=512, chunk=128, **kw),
            kill_at, tmp_path / "ckpt", tmp_path / "stream.jsonl")
        _metrics_equal(base_res.metrics, res.metrics)
        assert res.resumed_from == kill_at
        seams = _stream_equal(base_path, tmp_path / "stream.jsonl")
        assert seams[0]["engine"] == "serving"


@pytest.mark.fleet_smoke
class TestAtlasResume:
    @pytest.fixture(scope="class")
    def base(self, tmp_path_factory):
        path = tmp_path_factory.mktemp("atlas") / "base_stream.jsonl"
        res = sweep_lambda_max(ATLAS_CELLS, **ATLAS_KW,
                               stream_path=str(path))
        return res, path

    @pytest.mark.parametrize("kill_at", range(1, 8))
    def test_kill_at_every_boundary_bit_exact(self, base, tmp_path,
                                              kill_at):
        base_res, base_path = base
        res = _kill_and_resume(
            lambda **kw: sweep_lambda_max(ATLAS_CELLS, **ATLAS_KW, **kw),
            kill_at, tmp_path / "ckpt", tmp_path / "stream.jsonl")
        # rows are frozen dataclasses (brackets, probes, slot accounting):
        # == is full bit-equality of the lambda_max search
        assert res.rows == base_res.rows
        assert res.n_launches == base_res.n_launches
        assert res.seq_launches == base_res.seq_launches
        assert res.launch_slots_saved == base_res.launch_slots_saved
        assert res.resumed_from == kill_at
        # memoized launch builders: a same-process resume recompiles nothing
        assert res.n_step_compiles == base_res.n_step_compiles
        seams = _stream_equal(base_path, tmp_path / "stream.jsonl")
        assert seams[0]["engine"] == "atlas"


# ---------------------------------------------------------------------------
# Graceful degradation: host dropout parks lanes, reports, never aborts
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestDegradation:
    def test_atlas_host_dropout_degrades_not_aborts(self):
        base = sweep_lambda_max(ATLAS_CELLS, **ATLAS_KW)
        res = sweep_lambda_max(
            ATLAS_CELLS, **ATLAS_KW,
            resilience=ResilienceConfig(
                fault_plane=FaultPlane.host_dropout(host=0, at_launch=2)))
        assert len(res.rows) == len(ATLAS_CELLS)
        assert res.degraded, "dropout was silent"
        for ci, why in res.degraded.items():
            assert why.startswith("host_dropout:")
        flagged = {i for i, r in enumerate(res.rows) if r.degraded}
        assert flagged == set(res.degraded)
        # unaffected cells keep bit-identical brackets
        for i, (r0, r1) in enumerate(zip(base.rows, res.rows)):
            if i not in flagged:
                assert r0 == r1
        assert res.recovery_plan is not None
        assert res.recovery_plan.action == "remesh"
        assert res.recovery_plan.evict == ("host0",)

    def test_fleet_host_dropout_degrades_not_aborts(self):
        base = run_fleet(FLEET_JOBS, T=512, chunk=128)
        res = run_fleet(FLEET_JOBS, T=512, chunk=128,
                        resilience=ResilienceConfig(
                            fault_plane=FaultPlane.host_dropout(
                                host=0, at_launch=2)))
        assert res.degraded, "dropout was silent"
        assert len(res.metrics) == len(FLEET_JOBS)
        for j, (m0, m1) in enumerate(zip(base.metrics, res.metrics)):
            if j not in res.degraded:
                _metrics_equal([m0], [m1])
        assert res.recovery_plan is not None
        assert res.recovery_plan.action == "remesh"

    def test_fleet_transient_launch_failure_retries(self):
        base = run_fleet(FLEET_JOBS, T=512, chunk=128)
        res = run_fleet(FLEET_JOBS, T=512, chunk=128,
                        resilience=ResilienceConfig(
                            fault_plane=FaultPlane.launch_fail(
                                at_launch=1, fails=2)))
        _metrics_equal(base.metrics, res.metrics)
        assert res.n_fault_retries == 2
        assert res.degraded == {}


# ---------------------------------------------------------------------------
# Resume-aware stream append: dedupe clock, seam records, --resumed gate
# ---------------------------------------------------------------------------

def _fleet_rec(chunk, t, **over):
    fields = dict(group=0, chunk=chunk, t=t, n_sims=4,
                  useful_rate_med=0.5, backlog_med=0.1, max_queue_med=3.0,
                  drift_med=-0.01, n_decided=1, verdicts={"UNDECIDED": 4})
    fields.update(over)
    return schema.make_record("fleet", **fields)


def _resume_rec(chunk, t):
    return schema.make_record("resume", group=0, chunk=chunk, t=t,
                              n_sims=4, engine="fleet", ckpt_step=chunk,
                              n_preloaded=chunk)


class TestStreamResume:
    def test_append_dedupes_by_chunk_clock(self, tmp_path):
        path = tmp_path / "s_stream.jsonl"
        first = StreamSink(path=str(path))
        for c in (0, 1):
            first.write(_fleet_rec(c, 64 * (c + 1)))
        first.close()
        sink = StreamSink(path=str(path), append=True)
        assert sink.n_preloaded == 2
        sink.write(_resume_rec(1, 128))          # seam marker: never deduped
        sink.write(_fleet_rec(1, 128))           # replayed: suppressed
        sink.write(_fleet_rec(2, 192))           # fresh: appended
        sink.close()
        recs = schema.read_stream_jsonl(str(path))
        assert [r["kind"] for r in recs] == ["fleet", "fleet", "resume",
                                             "fleet"]
        assert [r["chunk"] for r in recs if r["kind"] == "fleet"] == \
            [0, 1, 2]
        assert schema.validate_stream(recs) == []

    def test_append_drops_torn_trailing_line(self, tmp_path):
        path = tmp_path / "s_stream.jsonl"
        with open(path, "w") as f:
            f.write(schema.jsonl_line(_fleet_rec(0, 64)) + "\n")
            f.write('{"kind": "fl')               # killed mid-append
        sink = StreamSink(path=str(path), append=True)
        assert sink.n_preloaded == 1
        sink.write(_fleet_rec(1, 128))
        sink.close()
        assert len(schema.read_stream_jsonl(str(path))) == 2

    def test_validate_stream_allows_repeated_resume_seams(self):
        recs = [_fleet_rec(0, 64), _resume_rec(0, 64), _resume_rec(0, 64),
                _fleet_rec(1, 128)]
        assert schema.validate_stream(recs) == []
        dup = [_fleet_rec(0, 64), _fleet_rec(0, 64)]
        assert any("chunk" in e for e in schema.validate_stream(dup))

    def test_check_stream_resumed_gate(self, tmp_path):
        good = tmp_path / "ok_stream.jsonl"
        schema.write_stream_jsonl(
            [_fleet_rec(0, 64), _resume_rec(0, 64), _fleet_rec(1, 128)],
            str(good))
        r = subprocess.run(
            [sys.executable, "scripts/check_stream.py", "--resumed",
             str(good)], cwd=REPO, capture_output=True, text=True)
        assert r.returncode == 0, r.stderr
        bare = tmp_path / "bare_stream.jsonl"
        schema.write_stream_jsonl([_fleet_rec(0, 64)], str(bare))
        r = subprocess.run(
            [sys.executable, "scripts/check_stream.py", "--resumed",
             str(bare)], cwd=REPO, capture_output=True, text=True)
        assert r.returncode == 1
        assert "no resume record" in r.stderr


# ---------------------------------------------------------------------------
# Kill/resume through buckets and adaptive re-queues (DESIGN.md §13)
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestBucketedRequeueResume:
    """The PR-9 snapshot must carry the bucketed-atlas cursor: launch-unit
    index (the bucket cursor), per-bucket launch counters, and per-cell
    attempt counters — a kill mid-re-queue or mid-bucket resumes
    bit-exactly."""

    # paper_grid + ring land in different size buckets; T=512/chunk=256
    # cannot latch, so every cell escalates through both re-queues —
    # every boundary is either mid-bucket or mid-attempt.
    CELLS = registry_cells(("paper_grid", "ring"), topo_seeds=(0,),
                           eps_b=0.05)
    KW = dict(seeds=(0,), T=512, chunk=256, rel_tol=0.1, max_calls=4,
              n_buckets=2, max_requeues=2)

    @pytest.fixture(scope="class")
    def base(self, tmp_path_factory):
        path = tmp_path_factory.mktemp("req") / "base_stream.jsonl"
        res = sweep_lambda_max(self.CELLS, **self.KW,
                               stream_path=str(path))
        return res, path

    def _kill_points(self, base_res):
        n = base_res.n_launches
        return sorted({1, 2, n // 2, n - 1, n})

    def test_kill_mid_requeue_and_mid_bucket_bit_exact(self, base,
                                                       tmp_path):
        base_res, base_path = base
        assert base_res.n_buckets == 2
        assert base_res.n_requeues == 2 * len(self.CELLS)
        for kill_at in self._kill_points(base_res):
            ckpt = tmp_path / f"ckpt_{kill_at}"
            stream = tmp_path / f"stream_{kill_at}.jsonl"
            res = _kill_and_resume(
                lambda **kw: sweep_lambda_max(self.CELLS, **self.KW, **kw),
                kill_at, ckpt, stream)
            assert res.rows == base_res.rows, f"kill_at={kill_at}"
            assert res.n_requeues == base_res.n_requeues
            assert res.bucket_launches == base_res.bucket_launches
            assert res.bucket_cells == base_res.bucket_cells
            assert res.n_launches == base_res.n_launches
            assert res.resumed_from == kill_at
            # attempt counters survived: per-row re-queue counts intact
            assert [r.n_requeues for r in res.rows] == \
                [r.n_requeues for r in base_res.rows]
            seams = _stream_equal(base_path, stream)
            assert seams[0]["engine"] == "atlas"
