"""Fleet subsystem tests: padded batching equivalence, mask semantics,
streaming (chunked-scan) metrics vs the trace simulator, scenario registry,
and the sharded engine smoke run (marker: fleet_smoke)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ComputeProblem, PolicyConfig, paper_grid_problem,
                        triangle_graph)
from repro.core.policies import bp_route_slot, load_balance_slot
from repro.core.queues import StaticProblem, init_state
from repro.sim import SimResult, simulate
from repro.sim.simulator import make_trace_runner
from repro.sim.workload import poisson_arrivals
from repro.fleet import (FleetJob, ModState, PadDims, exact_lam_star,
                         get_scenario, list_scenarios, make_group_launch,
                         make_stream_runner, pad_problem, policy_bound,
                         policy_bound_exact, run_fleet, stack_problems,
                         stream_simulate, sweep_jobs)
from repro.fleet.scenarios import (ARRIVAL_MODELS, EVENT_MODELS,
                                   EVENT_MODEL_ORDER, GE_BAD_SCALE,
                                   GE_COMP_P_DU, GE_COMP_P_UD, GE_P_BG,
                                   GE_P_GB, MMPP_P_OFF_ON, MMPP_P_ON_OFF,
                                   SCENARIOS)

TRI = ComputeProblem(triangle_graph(4.0), s1=0, s2=1, dest=2,
                     comp_nodes=(2,), comp_caps=(2.0,))


# ---------------------------------------------------------------------------
# useful_rate regression (satellite: off-by-one / wraparound)
# ---------------------------------------------------------------------------

class TestUsefulRate:
    def _result(self, du):
        du = jnp.asarray(du, jnp.float32)
        zeros = jnp.zeros_like(du)
        return SimResult(None, zeros, du, du, zeros, zeros)

    def test_constant_rate_for_every_window(self):
        """With one delivery per slot, every window must report rate 1."""
        T = 16
        res = self._result(jnp.arange(1, T + 1))
        for w in [1, 2, T // 2, T - 2, T - 1, T, T + 5, None]:
            assert float(res.useful_rate(w)) == pytest.approx(1.0)

    def test_boundary_window_does_not_wrap(self):
        """A huge early value must not leak into a trailing window via
        negative-index wraparound."""
        d = np.zeros(10, np.float32)
        d[0] = 1e6                     # burst in slot 0
        d = np.cumsum(np.r_[d[:1], np.ones(9, np.float32)]) - 1 + d[0]
        res = self._result(d)
        # windows that exclude slot 0 only see the 1-per-slot tail
        for w in (1, 4, 8):
            assert float(res.useful_rate(w)) == pytest.approx(1.0)
        # the full trace includes the burst
        assert float(res.useful_rate(None)) > 1e4


# ---------------------------------------------------------------------------
# Padded batching
# ---------------------------------------------------------------------------

class TestPaddedBatching:
    def test_exact_dims_match_seed_all_policies(self):
        """Padding with the instance's own dims is a pure re-encoding: every
        policy reproduces the seed simulator bit-for-bit."""
        p = paper_grid_problem()
        T = 150
        key = jax.random.key(0)
        ak, sk = jax.random.split(key)
        arr = poisson_arrivals(ak, 5.0, T)
        pp = pad_problem(p, PadDims(p.graph.n_nodes, p.graph.n_edges, p.n_comp))
        for name in ("pi1", "pi2", "pi3", "pi3bar"):
            cfg = PolicyConfig(name=name)
            r_seed = simulate(p, cfg, 5.0, T, seed=0)
            r_pad = make_trace_runner(pp, cfg)(arr, sk)
            np.testing.assert_allclose(np.asarray(r_seed.total_queue),
                                       np.asarray(r_pad.total_queue),
                                       rtol=1e-6, err_msg=name)

    def test_padding_is_inert_for_keyfree_policies(self):
        """Extra padded nodes/edges/comp slots change nothing for policies
        that draw no randomness (the regulator's per-node draw is shape-
        sensitive, so pi2/pi3 are only statistically equivalent)."""
        p = paper_grid_problem()
        T = 150
        key = jax.random.key(1)
        ak, sk = jax.random.split(key)
        arr = poisson_arrivals(ak, 5.0, T)
        big = pad_problem(p, PadDims(24, 48, 7))
        for name in ("pi1", "pi3bar"):
            cfg = PolicyConfig(name=name)
            r_seed = simulate(p, cfg, 5.0, T, seed=1)
            r_pad = make_trace_runner(big, cfg)(arr, sk)
            np.testing.assert_allclose(np.asarray(r_seed.total_queue),
                                       np.asarray(r_pad.total_queue),
                                       rtol=1e-6, err_msg=name)
            np.testing.assert_allclose(
                float(r_seed.delivered_useful[-1]),
                float(r_pad.delivered_useful[-1]), rtol=1e-6)

    def test_stacked_batch_vmaps(self):
        problems = [TRI, paper_grid_problem(),
                    get_scenario("ring").build(0)]
        batch = stack_problems(problems)
        assert batch.edges.shape[0] == 3
        cfg = PolicyConfig(name="pi3bar")
        T = 64
        arr = jnp.ones((3, T), jnp.float32)
        keys = jax.random.split(jax.random.key(0), 3)

        def run_one(pp, a, k):
            return make_trace_runner(pp, cfg)(a, k).delivered_useful[-1]

        out = jax.vmap(run_one)(batch, arr, keys)
        assert out.shape == (3,)
        assert np.all(np.asarray(out) >= 0.0)

    def test_masked_edge_carries_no_flow(self):
        """Zeroing an edge's mask is equivalent to removing the link."""
        import dataclasses as _dc
        sp = StaticProblem.build(TRI)
        state = init_state(sp)
        # put backlog on node 0 so the (0,1) and (0,2) links want to fire
        state = state._replace(Q=state.Q.at[0, 1, 0].set(50.0))
        masked = _dc.replace(sp, edge_mask=np.array([0.0, 0.0, 1.0], np.float32))
        new_masked, _ = bp_route_slot(masked, state)
        new_open, _ = bp_route_slot(sp, state)
        # with links (0,1), (0,2) masked, node 0's raw backlog cannot move
        assert float(new_masked.Q[0, 1, 0]) == pytest.approx(50.0)
        assert float(new_open.Q[0, 1, 0]) < 50.0

    def test_zero_capacity_link_frees_wireless_matching_slot(self):
        """A link whose capacity an event model zeroed must not win a
        greedy-matching slot and idle its endpoints (reviewed regression)."""
        import dataclasses as _dc
        from repro.core import line_graph
        p = ComputeProblem(line_graph(3, 1.0), 0, 1, 2, (1,), (1.0,))
        sp = StaticProblem.build(p)
        down = _dc.replace(sp, edge_cap=np.array([0.0, 1.0], np.float32))
        state = init_state(sp)
        # edge (0,1) has the larger differential backlog but zero capacity;
        # edge (1,2) must still transmit even though it shares node 1
        state = state._replace(
            Q=state.Q.at[0, 1, 0].set(50.0).at[1, 2, 0].set(30.0))
        new, _ = bp_route_slot(down, state, wireless=True)
        assert float(new.Q[1, 2, 0]) < 30.0

    def test_masked_comp_node_never_selected(self):
        p = paper_grid_problem()
        sp = StaticProblem.build(p)
        import dataclasses as _dc
        mask = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
        masked = _dc.replace(sp, comp_mask=mask)
        cfg = PolicyConfig(name="pi3")
        state = init_state(sp)
        picks = set()
        for a in range(20):
            _, _, m = load_balance_slot(masked, cfg, state,
                                        jnp.float32(1.0 + a))
            picks.add(int(m["n_star"]))
        assert picks <= {0, 2}

    def test_regulator_inert_on_padded_comp_slots(self):
        """Padded (masked-out) computation slots must never accumulate
        regulator state or push dummies: the regulator sees assigned == 0
        there, so Y and Ddum stay exactly zero (the regulator-as-padding
        correspondence, DESIGN.md §2/§3)."""
        p = paper_grid_problem()
        nc = p.n_comp
        big = pad_problem(p, PadDims(20, 30, nc + 3))
        out = stream_simulate(p, PolicyConfig(name="pi3_reg", eps_b=0.2),
                              lam=4.0, T=300, chunk=100, seed=5,
                              dims=PadDims(20, 30, nc + 3))
        assert float(out["delivered_useful"]) > 0.0
        # reach the final NetState through the reference trace path
        arr = poisson_arrivals(jax.random.key(0), 4.0, 300)
        res = make_trace_runner(big, PolicyConfig(name="pi3_reg", eps_b=0.2))(
            arr, jax.random.key(1))
        final = res.final_state
        assert np.all(np.asarray(final.Y[nc:]) == 0.0)
        assert np.all(np.asarray(final.H[nc:]) == 0.0)
        assert np.all(np.asarray(final.Ddum[:, nc:]) == 0.0)


# ---------------------------------------------------------------------------
# Streaming engine (chunked scan + online accumulators)
# ---------------------------------------------------------------------------

class TestStreaming:
    def test_matches_trace_simulator_100k_slots(self):
        """Acceptance: T=100k chunked-scan run matches the seed simulator's
        delivered_useful on an identical arrival trace to <= 1e-3 relative."""
        T = 100_000
        cfg = PolicyConfig(name="pi3bar")
        key = jax.random.key(3)
        arr = poisson_arrivals(key, 1.5, T)
        r_seed = simulate(TRI, cfg, 1.5, T, seed=3, arrivals=arr)
        out = stream_simulate(TRI, cfg, 1.5, T, chunk=1000, seed=3,
                              arrivals=arr)
        du_seed = float(r_seed.delivered_useful[-1])
        du_stream = float(out["delivered_useful"])
        assert abs(du_seed - du_stream) / max(du_seed, 1.0) <= 1e-3
        # windowed rate consistency with the trace-side computation
        assert float(out["useful_rate"]) == pytest.approx(
            float(r_seed.useful_rate(T // 2)), rel=1e-3)

    def test_no_T_shaped_metric_arrays(self):
        """The compiled streaming program must hold no array with a horizon-
        sized dimension: metrics are online accumulators only."""
        T, chunk = 100_000, 1000
        cfg = PolicyConfig(name="pi3")
        run = make_stream_runner(cfg, T, chunk=chunk)
        pp = pad_problem(TRI, PadDims.of([TRI]))
        jaxpr = jax.make_jaxpr(
            functools.partial(run, arrivals=None))(
                pp, jnp.float32(1.0), jnp.float32(0.01), jnp.int32(0),
                jnp.int32(0), jax.random.PRNGKey(0))

        def max_dim(jxp):
            dims = [0]
            for eqn in jxp.eqns:
                for v in list(eqn.outvars) + list(eqn.invars):
                    aval = getattr(v, "aval", None)
                    if aval is not None and getattr(aval, "shape", None):
                        dims.extend(d for d in aval.shape
                                    if isinstance(d, int))
                for p in eqn.params.values():
                    inner = getattr(p, "jaxpr", None)
                    if inner is not None:
                        dims.append(max_dim(inner))
            return max(dims)

        biggest = max_dim(jaxpr.jaxpr)
        assert biggest < chunk + 1, (
            f"streaming program materializes a {biggest}-sized axis")

    def test_stability_verdict(self):
        # far below capacity: stable; far above: unstable
        cfg = PolicyConfig(name="pi3bar")
        lo = stream_simulate(TRI, cfg, 1.0, 3000, chunk=500, seed=0)
        hi = stream_simulate(TRI, cfg, 4.0, 3000, chunk=500, seed=0)
        assert float(lo["stable"]) == 1.0
        assert float(hi["stable"]) == 0.0
        assert float(hi["mean_queue_tail"]) > float(hi["mean_queue_mid"])

    def test_horizon_rounds_up_to_chunks(self):
        run = make_stream_runner(PolicyConfig(name="pi1"), T=1001, chunk=100)
        assert run.T == 1100 and run.chunk == 100


# ---------------------------------------------------------------------------
# Scenario registry
# ---------------------------------------------------------------------------

class TestScenarios:
    def test_registry_contents(self):
        names = list_scenarios()
        for expected in ("paper_grid", "random_geometric", "ring", "tree",
                         "expander", "fat_tree", "wireless_grid"):
            assert expected in names

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_every_scenario_builds_valid_problems(self, name):
        for seed in (0, 1):
            p = get_scenario(name).build(seed)
            assert isinstance(p, ComputeProblem)
            # endpoints distinct enough to pose a real routing problem
            assert p.s1 != p.s2
            assert p.n_comp >= 1
            # connected: BFS from s1 reaches everything
            adj = [[] for _ in range(p.graph.n_nodes)]
            for m, l in p.graph.edges:
                adj[m].append(int(l))
                adj[l].append(int(m))
            seen, stack = {p.s1}, [p.s1]
            while stack:
                for v in adj[stack.pop()]:
                    if v not in seen:
                        seen.add(v)
                        stack.append(v)
            assert len(seen) == p.graph.n_nodes, f"{name} disconnected"

    def test_topology_seeds_vary_random_graphs(self):
        a = get_scenario("random_geometric").build(0)
        b = get_scenario("random_geometric").build(1)
        assert (a.graph.n_edges != b.graph.n_edges or
                not np.array_equal(a.graph.edges, b.graph.edges))

    def test_event_models_shapes_and_ranges(self):
        pp = pad_problem(TRI, PadDims.of([TRI]))
        key = jax.random.key(0)
        mod0 = ModState.init(pp)
        for name in EVENT_MODEL_ORDER:
            es, cs, mod = EVENT_MODELS[name](pp, jnp.int32(17), key, mod0)
            assert es.shape == (pp.n_edges,)
            assert cs.shape == (pp.n_comp,)
            assert mod.link.shape == mod0.link.shape
            assert float(es.min()) >= 0.0 and float(es.max()) <= 1.0 + 1e-6
            assert float(cs.min()) >= 0.0 and float(cs.max()) <= 1.0 + 1e-6
        # static model is the identity and passes the state through untouched
        es, cs, mod = EVENT_MODELS["static"](pp, jnp.int32(0), key, mod0)
        assert float(es.min()) == 1.0 and float(cs.min()) == 1.0
        assert mod is mod0

    def test_gilbert_elliott_stationary_bad_fraction(self):
        """The per-link Good/Bad chain must mix to P(Bad) = P_GB/(P_GB+P_BG)
        and emit only the two scales {bad_scale, 1}."""
        pp = pad_problem(TRI, PadDims.of([TRI]))
        ge = EVENT_MODELS["gilbert_elliott"]

        def body(carry, k):
            es, _, mod = ge(pp, jnp.int32(0), k, carry)
            return mod, es

        T = 4000
        keys = jax.random.split(jax.random.key(7), T)
        _, scales = jax.lax.scan(body, ModState.init(pp), keys)
        vals = np.unique(np.asarray(scales).round(6))
        assert set(vals) <= {np.float32(GE_BAD_SCALE), np.float32(1.0)}
        # drop the burn-in, compare against the stationary distribution
        bad = np.asarray(scales[T // 4:] < 0.5).mean()
        pi_bad = GE_P_GB / (GE_P_GB + GE_P_BG)
        assert bad == pytest.approx(pi_bad, abs=0.03)

    def test_gilbert_elliott_outages_are_correlated(self):
        """Consecutive-slot Bad states must co-occur far more often than the
        i.i.d. square of the marginal (the point of the Markov model)."""
        pp = pad_problem(TRI, PadDims.of([TRI]))
        ge = EVENT_MODELS["gilbert_elliott"]

        def body(carry, k):
            es, _, mod = ge(pp, jnp.int32(0), k, carry)
            return mod, es < 0.5

        T = 4000
        keys = jax.random.split(jax.random.key(3), T)
        _, bad = jax.lax.scan(body, ModState.init(pp), keys)
        bad = np.asarray(bad[T // 4:])
        p_bad = bad.mean()
        p_joint = (bad[1:] & bad[:-1]).mean()
        # Markov chain: P(bad, bad) = pi_bad * (1 - P_BG) >> pi_bad^2
        assert p_joint > 3.0 * p_bad ** 2

    def test_ge_comp_chain_stationarity(self):
        """The per-comp-node Up/Down chain must mix to the chain's stationary
        distribution P(Up) = P_DU/(P_UD+P_DU), emit only {0, 1} scales, and
        produce multi-slot outages (the correlated regime)."""
        pp = pad_problem(paper_grid_problem(), PadDims(16, 24, 4))
        ge = EVENT_MODELS["ge_comp"]

        def body(carry, k):
            es, cs, mod = ge(pp, jnp.int32(0), k, carry)
            return mod, (es, cs)

        T = 8000
        keys = jax.random.split(jax.random.key(5), T)
        _, (es, cs) = jax.lax.scan(body, ModState.init(pp), keys)
        assert np.asarray(es).min() == 1.0          # links untouched
        vals = np.unique(np.asarray(cs))
        assert set(vals) <= {np.float32(0.0), np.float32(1.0)}
        up = np.asarray(cs[T // 4:])                # drop the burn-in
        pi_up = GE_COMP_P_DU / (GE_COMP_P_UD + GE_COMP_P_DU)
        assert up.mean() == pytest.approx(pi_up, abs=0.03)
        # outages persist: consecutive Down slots co-occur far above iid^2
        down = up < 0.5
        p_down = down.mean()
        p_joint = (down[1:] & down[:-1]).mean()
        assert p_joint > 3.0 * p_down ** 2

    def test_ge_full_advances_both_chains(self):
        pp = pad_problem(paper_grid_problem(), PadDims(16, 24, 4))
        ge = EVENT_MODELS["ge_full"]

        def body(carry, k):
            es, cs, mod = ge(pp, jnp.int32(0), k, carry)
            return mod, (es, cs)

        keys = jax.random.split(jax.random.key(2), 2000)
        _, (es, cs) = jax.lax.scan(body, ModState.init(pp), keys)
        assert np.asarray(es).min() == pytest.approx(GE_BAD_SCALE)  # links fade
        assert np.asarray(cs).min() == 0.0                          # nodes fail

    def test_markov_onoff_arrivals_preserve_mean_and_burst(self):
        """Long-run mean must equal lam; ON/OFF runs must be multi-slot."""
        lam = 2.0
        arr_fn = ARRIVAL_MODELS["markov_onoff"]
        pp = pad_problem(TRI, PadDims.of([TRI]))

        def body(carry, k):
            a, mod = arr_fn(k, jnp.float32(lam), carry)
            return mod, (a, mod.burst)

        T = 20000
        keys = jax.random.split(jax.random.key(11), T)
        _, (arr, on) = jax.lax.scan(body, ModState.init(pp), keys)
        arr, on = np.asarray(arr), np.asarray(on)
        assert arr.mean() == pytest.approx(lam, rel=0.05)
        pi_on = MMPP_P_OFF_ON / (MMPP_P_ON_OFF + MMPP_P_OFF_ON)
        assert on.mean() == pytest.approx(pi_on, abs=0.05)
        assert np.all(arr[on < 0.5] == 0.0)          # OFF slots are silent
        # mean ON-run length 1/P_OFF: count runs via transitions
        flips = np.abs(np.diff(on)).sum()
        mean_run = len(on) / max(flips, 1)
        assert mean_run > 3.0                        # i.i.d. would give ~1-2


# ---------------------------------------------------------------------------
# Sharded engine (CI smoke: works on 1 device; scripts/test.sh gives it 8)
# ---------------------------------------------------------------------------

@pytest.mark.fleet_smoke
class TestFleetEngine:
    def test_sweep_mixed_scenarios_one_program_per_policy(self):
        jobs = [FleetJob(scenario=s, policy=pol, lam=lam, seed=seed)
                for s in ("paper_grid", "ring", "fat_tree")
                for pol in ("pi3", "pi3bar")
                for lam in (1.0, 2.5)
                for seed in (0,)]
        res = run_fleet(jobs, T=256, chunk=64)
        assert res.n_sims == len(jobs) == 12
        # one compiled program per policy group, not per topology
        assert res.n_programs == 2
        useful = res.column("useful_rate")
        assert useful.shape == (12,)
        assert np.all(np.isfinite(useful)) and np.all(useful >= 0.0)
        assert np.all(np.isfinite(res.column("mean_queue")))

    def test_batch_not_divisible_by_mesh(self):
        """Odd job counts are padded onto the mesh and trimmed back."""
        n = len(jax.devices()) + 1 if len(jax.devices()) > 1 else 3
        jobs = [FleetJob(scenario="paper_grid", policy="pi3bar",
                         lam=1.0 + 0.5 * i, seed=i) for i in range(n)]
        res = run_fleet(jobs, T=128, chunk=64)
        assert res.n_sims == n
        assert len(res.metrics) == n
        offered = res.column("offered")
        np.testing.assert_allclose(offered, [1.0 + 0.5 * i for i in range(n)])

    def test_wireless_scenario_forms_own_group(self):
        jobs = [FleetJob(scenario="paper_grid", policy="pi3", lam=1.0),
                FleetJob(scenario="wireless_grid", policy="pi3", lam=1.0)]
        res = run_fleet(jobs, T=128, chunk=64)
        assert res.n_programs == 2

    def test_eps_b_sweep_and_reg_alias_share_one_program(self):
        """eps_B is traced per-job data and pi3/pi3_reg are semantically one
        policy: a sweep over both axes must compile exactly one program."""
        jobs = [FleetJob(scenario="paper_grid", policy=pol, lam=2.0,
                         eps_b=eps, seed=0)
                for pol in ("pi3", "pi3_reg")
                for eps in (0.01, 0.05, 0.2)]
        res = run_fleet(jobs, T=256, chunk=64)
        assert res.n_programs == 1
        np.testing.assert_allclose(res.column("eps_b"),
                                   [0.01, 0.05, 0.2] * 2, rtol=1e-6)
        # the traced eps_B must actually reach the regulator: with identical
        # seeds the Bernoulli draws are monotone-coupled in eps (uniform < p),
        # so eps 0.2 must deliver strictly more dummies than eps 0.01
        dummy = res.column("delivered_dummy")
        assert np.all(np.isfinite(dummy)) and np.all(dummy >= -1e-4)
        for base in (0, 3):                       # pi3 block, pi3_reg block
            assert dummy[base + 2] > dummy[base] + 1.0, dummy

    def test_pallas_backend_matches_xla_in_fleet(self):
        """backend="pallas" (interpret mode) through the full sharded
        engine: separate compiled program, bit-identical metrics
        (DESIGN.md §7)."""
        mk = lambda backend: [
            FleetJob(scenario="paper_grid", policy="pi3_reg", lam=3.0 + s,
                     eps_b=0.05, seed=s, backend=backend) for s in range(2)]
        res_x = run_fleet(mk("xla"), T=128, chunk=64)
        res_p = run_fleet(mk("pallas"), T=128, chunk=64)
        for k in ("useful_rate", "delivered", "mean_queue", "max_queue"):
            np.testing.assert_array_equal(res_x.column(k), res_p.column(k),
                                          err_msg=k)
        # mixing backends in one sweep forks the compiled program (backend
        # changes control flow, unlike eps_b)
        res_mix = run_fleet(mk("xla") + mk("pallas"), T=128, chunk=64)
        assert res_mix.n_programs == 2

    def test_markov_scenarios_run_in_fleet(self):
        """Gilbert–Elliott fading, comp-node failure chains, and bursty
        arrivals all ride the same compiled program as static scenarios
        (chain state lives in the scan carry)."""
        jobs = [FleetJob(scenario=s, policy="pi3_reg", lam=2.0, eps_b=0.05)
                for s in ("paper_grid", "ge_grid", "bursty_grid",
                          "ge_comp_grid", "ge_full_grid")]
        res = run_fleet(jobs, T=256, chunk=64)
        assert res.n_programs == 1
        useful = res.column("useful_rate")
        assert np.all(np.isfinite(useful)) and np.all(useful >= 0.0)
        # comp outages must cost throughput relative to the static grid at
        # identical load... but over 256 slots noise dominates; just check
        # the failing scenarios still deliver
        assert np.all(res.column("delivered_useful") > 0.0)


# ---------------------------------------------------------------------------
# Comp-node outage mask threading (event scale -> comp_mask -> argmin)
# ---------------------------------------------------------------------------

class TestCompOutageMasking:
    def test_zero_comp_scale_excluded_from_argmin(self):
        """A comp node whose event-model scale is 0 this slot must neither
        win the load-balance argmin nor combine pairs — the modulated mask
        path (with_capacity_scales gates comp_mask)."""
        p = paper_grid_problem()
        pp = pad_problem(p, PadDims.of([p]))
        cfg = PolicyConfig(name="pi3")
        state = init_state(pp)
        down = jnp.array([1.0, 0.0, 1.0, 0.0], jnp.float32)
        scaled = pp.with_capacity_scales(jnp.ones(pp.n_edges), down)
        picks = set()
        for a in range(16):
            _, _, m = load_balance_slot(scaled, cfg, state,
                                        jnp.float32(1.0 + a))
            picks.add(int(m["n_star"]))
        assert picks <= {0, 2}
        # and the mask composes with padding: a padded problem keeps its
        # padded slots masked after scaling
        big = pad_problem(p, PadDims(20, 30, 6))
        scaled_big = big.with_capacity_scales(
            jnp.ones(big.n_edges), jnp.ones(big.n_comp))
        assert np.asarray(scaled_big.comp_mask)[4:].max() == 0.0

    def test_downed_node_combines_nothing(self):
        from repro.core.policies import computation_slot
        p = paper_grid_problem()
        pp = pad_problem(p, PadDims.of([p]))
        state = init_state(pp)
        # give every comp node combinable pairs
        state = state._replace(
            X=jnp.full((4, 2), 5.0),
            cum_arr=jnp.full((4, 2), 5.0))
        down = jnp.array([1.0, 0.0, 1.0, 1.0], jnp.float32)
        scaled = pp.with_capacity_scales(jnp.ones(pp.n_edges), down)
        new, m = computation_slot(scaled, PolicyConfig(name="pi3bar"), state,
                                  jnp.zeros(4), jax.random.key(0))
        consumed = np.asarray(state.X - new.X)[:, 0]
        assert consumed[1] == 0.0                 # Down node combined nothing
        assert (consumed[[0, 2, 3]] > 0.0).all()  # Up nodes worked


# ---------------------------------------------------------------------------
# Donated chunked-scan carry (the engine's memory audit)
# ---------------------------------------------------------------------------

class TestDonation:
    def test_chunk_runner_carry_buffers_are_donated(self):
        """The engine's chunk step must donate its carry: after a launch the
        input carry buffers are deleted (reused in place), not left alive
        as a second copy of the fleet state."""
        from jax.sharding import Mesh
        cfg = PolicyConfig(name="pi3_reg", eps_b=0.05)
        runner = make_stream_runner(cfg, T=128, chunk=64)
        mesh = Mesh(np.array(jax.devices()), ("fleet",))
        ndev = len(jax.devices())
        pp = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[pad_problem(TRI, PadDims.of([TRI]))] * ndev)
        lam = jnp.full((ndev,), 1.0, jnp.float32)
        eps = jnp.full((ndev,), 0.05, jnp.float32)
        ak = jnp.zeros((ndev,), jnp.int32)
        ek = jnp.zeros((ndev,), jnp.int32)
        keys = jnp.stack([jax.random.PRNGKey(i) for i in range(ndev)])

        init_fn, step_fn, fin_fn = make_group_launch(runner, mesh)
        carry = init_fn(pp)
        leaves = jax.tree_util.tree_leaves(carry)
        carry = step_fn(pp, lam, eps, ak, ek, keys, carry)
        assert all(leaf.is_deleted() for leaf in leaves), (
            "chunk-step carry was copied, not donated")
        # non-carry operands must NOT be donated (reused across chunks)
        assert not jax.tree_util.tree_leaves(pp)[0].is_deleted()
        carry2 = step_fn(pp, lam, eps, ak, ek, keys, carry)
        out = jax.device_get(fin_fn(lam, eps, carry2))
        assert np.all(np.isfinite(out["useful_rate"]))

    def test_chunked_launch_matches_single_program_run(self):
        """Driving chunk_step from Python (the donated path) must produce
        exactly the same metrics as the closed single-program `run`."""
        cfg = PolicyConfig(name="pi3bar")
        runner = make_stream_runner(cfg, T=256, chunk=64)
        pp = pad_problem(TRI, PadDims.of([TRI]))
        args = (jnp.float32(1.5), jnp.float32(0.01), jnp.int32(0),
                jnp.int32(0), jax.random.PRNGKey(3))
        ref = jax.jit(runner)(pp, *args)
        step = jax.jit(runner.chunk_step, donate_argnums=6)
        carry = jax.jit(runner.init_carry)(pp)
        for _ in range(runner.n_chunks):
            carry = step(pp, *args, carry)
        got = jax.jit(runner.finalize)(args[0], args[1], carry)
        for k in ref:
            np.testing.assert_allclose(np.asarray(ref[k]),
                                       np.asarray(got[k]), rtol=1e-6,
                                       err_msg=k)


# ---------------------------------------------------------------------------
# Chunk-loop compilation accounting (host-work hoisting, DESIGN.md §4/§7)
# ---------------------------------------------------------------------------

class TestNoRecompilation:
    def test_chunk_loop_compiles_step_exactly_once(self):
        """Driving many chunks through `make_group_launch`'s step_fn must
        hit one compiled program: all per-chunk operands (padded problem,
        rates, eps, model codes, keys) are built once per group, so no
        chunk-loop iteration may retrace."""
        from jax.sharding import Mesh
        # a threshold unique to this test keeps the memoized runner/launch
        # caches from aliasing other tests' entries
        cfg = PolicyConfig(name="pi3bar", threshold=0.060959)
        runner = make_stream_runner(cfg, T=256, chunk=32)
        mesh = Mesh(np.array(jax.devices()), ("fleet",))
        ndev = len(jax.devices())
        pp = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[pad_problem(TRI, PadDims.of([TRI]))] * ndev)
        lam = jnp.full((ndev,), 1.0, jnp.float32)
        eps = jnp.full((ndev,), 0.01, jnp.float32)
        ak = jnp.zeros((ndev,), jnp.int32)
        ek = jnp.zeros((ndev,), jnp.int32)
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.arange(ndev, dtype=jnp.uint32))

        init_fn, step_fn, fin_fn = make_group_launch(runner, mesh)
        carry = init_fn(pp)
        for _ in range(runner.n_chunks):
            carry = step_fn(pp, lam, eps, ak, ek, keys, carry)
        assert step_fn._cache_size() == 1, (
            f"chunk loop retraced: {step_fn._cache_size()} compilations")
        out = jax.device_get(fin_fn(lam, eps, carry))
        assert np.all(np.isfinite(out["useful_rate"]))

    def test_runner_and_launch_are_memoized(self):
        """Same (cfg, T, chunk, window) must return the *same* runner and
        launch objects — re-sweeping a policy group reuses its compiled
        programs instead of re-tracing (the per-group host-work hoist)."""
        from jax.sharding import Mesh
        cfg = PolicyConfig(name="pi3", threshold=0.060959)
        r1 = make_stream_runner(cfg, T=128, chunk=32)
        r2 = make_stream_runner(cfg, T=128, chunk=32)
        assert r1 is r2
        mesh = Mesh(np.array(jax.devices()), ("fleet",))
        assert make_group_launch(r1, mesh) is make_group_launch(r2, mesh)
        # a different horizon is a different runner
        assert make_stream_runner(cfg, T=256, chunk=32) is not r1


# ---------------------------------------------------------------------------
# Exact regulated LP bounds (report layer)
# ---------------------------------------------------------------------------

class TestExactBounds:
    def test_bound_exact_between_approx_and_lam_star(self):
        """On the paper grid: bound_approx <= bound_exact <= bound_approx *
        (1 + eps_B), and since computation (not links) binds there, the
        dummy inflation is free: bound_exact == lam_star == 8."""
        for eps in (0.01, 0.05, 0.2):
            lam_star = exact_lam_star("paper_grid", 0, 1.0)
            be = policy_bound_exact("paper_grid", "pi3_reg", eps)
            ba = policy_bound(lam_star, "pi3_reg", eps)
            assert ba <= be * (1 + 1e-9)
            assert be <= ba * (1 + eps) * (1 + 1e-9)
            assert be == pytest.approx(lam_star)     # comp-capacity bound
        # link-bound topology: the approximation is tight
        ls_ft = exact_lam_star("fat_tree", 0, 1.0)
        assert policy_bound_exact("fat_tree", "pi3_reg", 0.05) == \
            pytest.approx(ls_ft / 1.05)
        # unregulated policies: exact bound degenerates to plain lam_star
        assert policy_bound_exact("paper_grid", "pi3bar", 0.05) == \
            pytest.approx(exact_lam_star("paper_grid", 0, 1.0))

    def test_exact_lp_solves_are_cached(self):
        exact_lam_star.cache_clear()
        policy_bound_exact("paper_grid", "pi3_reg", 0.05)
        before = exact_lam_star.cache_info()
        for _ in range(5):
            policy_bound_exact("paper_grid", "pi3_reg", 0.05)
            policy_bound_exact("paper_grid", "pi2_reg", 0.05)  # same rho0
        info = exact_lam_star.cache_info()
        assert info.misses == before.misses        # no new LP solves
        assert info.hits >= before.hits + 10
        # Report-layer accounting: a full job expansion plus the hoisted
        # one-lookup-per-(scenario, policy)-group bound table must solve
        # each distinct (scenario, rho0) LP exactly once — everything else
        # is cache hits.
        exact_lam_star.cache_clear()
        spec = {"paper_grid": ("pi3bar", "pi3_reg"), "ring": ("pi3_reg",)}
        sweep_jobs(spec, rate_fracs=(0.5, 0.8, 0.95), seeds=(0, 1),
                   eps_b=0.05)
        bounds = {(s, p): policy_bound_exact(s, p, 0.05)
                  for s, pols in spec.items() for p in pols}
        info = exact_lam_star.cache_info()
        # distinct (scenario, rho0) pairs: paper_grid x {1.0, 1.05},
        # ring x {1.05}
        assert info.misses == 3, info
        assert info.hits >= len(bounds), info

    def test_fingerprint_dedupes_seed_independent_topologies(self):
        """The cache keys on the canonical problem fingerprint, not
        (scenario, topo_seed): every topo_seed of a seed-independent
        family builds the same instance, so a thousand-seed atlas grid
        costs *one* LP solve for it."""
        from repro.fleet import problem_fingerprint
        from repro.fleet.scenarios import get_scenario
        fps = {problem_fingerprint(get_scenario("fat_tree").build(ts))
               for ts in (0, 1, 2)}
        assert len(fps) == 1
        # ... while a genuinely seed-varying family hashes apart
        fps_rg = {problem_fingerprint(
            get_scenario("random_geometric").build(ts)) for ts in (0, 1)}
        assert len(fps_rg) == 2
        exact_lam_star.cache_clear()
        for ts in range(4):
            exact_lam_star("fat_tree", ts, 1.0)
        info = exact_lam_star.cache_info()
        assert info.misses == 1 and info.hits == 3, info
        # rho0 is part of the fingerprint: a regulated solve is distinct
        exact_lam_star("fat_tree", 0, 1.05)
        assert exact_lam_star.cache_info().misses == 2

    def test_lp_cache_is_bounded(self, monkeypatch):
        """At thousands of topo_seeds the cache must evict, not grow:
        with the bound pinned to 2, three distinct LPs leave exactly two
        entries (LRU), and the evicted one re-solves on return."""
        from repro.fleet import report as report_mod
        exact_lam_star.cache_clear()
        monkeypatch.setattr(report_mod, "LP_CACHE_MAX", 2)
        for scen in ("paper_grid", "ring", "fat_tree"):
            exact_lam_star(scen, 0, 1.0)
        info = exact_lam_star.cache_info()
        assert info.misses == 3 and info.currsize == 2
        # paper_grid (least recently used) was evicted: a re-solve
        exact_lam_star("paper_grid", 0, 1.0)
        assert exact_lam_star.cache_info().misses == 4
        # ring's entry survived?  No — it was evicted by the re-solve;
        # fat_tree (most recent before it) still hits.
        exact_lam_star("fat_tree", 0, 1.0)
        assert exact_lam_star.cache_info().misses == 4
        exact_lam_star.cache_clear()
        assert exact_lam_star.cache_info() == (0, 0, 2, 0)


# ---------------------------------------------------------------------------
# rho0-adjusted bounds (report layer)
# ---------------------------------------------------------------------------

class TestRegulatedBounds:
    def test_policy_bound_adjusts_only_regulated(self):
        assert policy_bound(8.0, "pi3bar", 0.05) == pytest.approx(8.0)
        assert policy_bound(8.0, "pi1", 0.05) == pytest.approx(8.0)
        for pol in ("pi2", "pi2_reg", "pi3", "pi3_reg"):
            assert policy_bound(8.0, pol, 0.05) == pytest.approx(8.0 / 1.05)

    def test_sweep_jobs_scale_offered_by_policy_bound(self):
        # approx path: regulated rates scale by lam_star/rho0
        jobs = sweep_jobs({"paper_grid": ("pi3bar", "pi3_reg")},
                          rate_fracs=(0.5,), seeds=(0,), eps_b=0.05,
                          lam_star_of={"paper_grid": 8.0}, exact=False)
        lam = {j.policy: j.lam for j in jobs}
        assert lam["pi3bar"] == pytest.approx(4.0)
        assert lam["pi3_reg"] == pytest.approx(4.0 / 1.05)
        assert all(j.eps_b == 0.05 for j in jobs)

    def test_sweep_jobs_exact_uses_regulated_lp(self):
        """Default (exact) path: on the comp-bound paper grid the regulated
        LP equals lam_star, so pi3_reg is offered the same rates as pi3bar
        — the approximation would under-load it by 1/rho0 (DESIGN.md §6)."""
        jobs = sweep_jobs({"paper_grid": ("pi3bar", "pi3_reg")},
                          rate_fracs=(0.5,), seeds=(0,), eps_b=0.05)
        lam = {j.policy: j.lam for j in jobs}
        assert lam["pi3bar"] == pytest.approx(4.0)
        assert lam["pi3_reg"] == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# Compensated delivery counters (ROADMAP numerics note)
# ---------------------------------------------------------------------------

class TestCompensatedCounters:
    def test_increments_survive_past_f32_saturation(self):
        """Plain float32 drops every sub-ulp increment once the total passes
        2^24; the compensated counters must keep them all."""
        sp = StaticProblem.build(TRI)
        st = init_state(sp)
        big = jnp.float32(2.0 ** 24)
        st = st._replace(delivered=big, delivered_useful=big)

        def body(s, _):
            return s.credit_delivery(jnp.float32(0.25), jnp.float32(0.25)), None

        st, _ = jax.lax.scan(body, st, xs=None, length=1000)
        # kahan_add keeps sum - compensation == exact total
        gained = (float(st.delivered) - float(st.delivered_c)) - 2.0 ** 24
        assert gained == pytest.approx(250.0, rel=1e-6)
        # the headline field alone is within one f32 ulp (2.0) of the truth
        assert float(st.delivered) - 2.0 ** 24 == pytest.approx(250.0, abs=2.0)
        # the naive sum loses everything — the failure mode being guarded
        naive = big
        for _ in range(10):
            naive = naive + jnp.float32(0.25)
        assert float(naive) == 2.0 ** 24
