"""Theorem 1/4 LP upper bound: sanity against hand-computable topologies."""
import numpy as np
import pytest

from repro.core import (ComputeProblem, capacity_upper_bound, grid_graph,
                        line_graph, paper_grid_problem, single_node_capacity,
                        triangle_graph)


def test_triangle_dest_computes():
    # Motivating example, computation at d: rate = min(C_d, R_1d, R_2d).
    g = triangle_graph([3.0, 2.0, 4.0])   # edges (0,1),(0,2),(1,2)
    p = ComputeProblem(g, s1=0, s2=1, dest=2, comp_nodes=(2,), comp_caps=(10.0,))
    r = capacity_upper_bound(p)
    # raw1 can use 0->2 (cap 2) and 0->1->2 sharing; LP finds the max.
    # Cut at node 2: all raw must enter via links (0,2)+(1,2) and each query
    # needs 2 raw packets -> lam <= (2+4)/2 = 3.
    assert r.lam_star == pytest.approx(3.0, abs=1e-6)


def test_triangle_computation_capacity_binds():
    g = triangle_graph(10.0)
    p = ComputeProblem(g, s1=0, s2=1, dest=2, comp_nodes=(2,), comp_caps=(1.5,))
    r = capacity_upper_bound(p)
    assert r.lam_star == pytest.approx(1.5, abs=1e-6)


def test_line_network():
    # 0 - 1 - 2, source 0 & 2, compute+deliver at 1? dest must receive results.
    g = line_graph(3, capacity=4.0)
    p = ComputeProblem(g, s1=0, s2=2, dest=1, comp_nodes=(1,), comp_caps=(100.0,))
    r = capacity_upper_bound(p)
    # each query: 1 raw over (0,1), 1 raw over (2,1); result born at dest.
    assert r.lam_star == pytest.approx(4.0, abs=1e-6)


def test_line_network_compute_at_source():
    # compute at s1: raw2 crosses both links, processed crosses (0,1) back.
    g = line_graph(3, capacity=4.0)
    p = ComputeProblem(g, s1=0, s2=2, dest=1, comp_nodes=(0,), comp_caps=(100.0,))
    r = capacity_upper_bound(p)
    # link (0,1) carries raw2 downstream lam + processed lam => 2 lam <= 4;
    # link (1,2) carries raw2 lam <= 4. So lam* = 2.
    assert r.lam_star == pytest.approx(2.0, abs=1e-6)


def test_paper_grid_capacities():
    # Calibrated placement (DESIGN.md §1): C=2 computation-bound at 8,
    # C=3 communication-bound at 10 (paper reads ~9.8 off the sim knee).
    r2 = capacity_upper_bound(paper_grid_problem(C=2.0))
    assert r2.lam_star == pytest.approx(8.0, abs=1e-6)
    np.testing.assert_allclose(r2.lam_per_node, 2.0, atol=1e-6)
    r3 = capacity_upper_bound(paper_grid_problem(C=3.0))
    assert r3.lam_star == pytest.approx(10.0, abs=1e-6)


def test_single_node_leq_multi():
    p = paper_grid_problem(C=2.0)
    multi = capacity_upper_bound(p).lam_star
    singles = [single_node_capacity(p, i).lam_star for i in range(p.n_comp)]
    assert all(s <= multi + 1e-9 for s in singles)
    # load balancing over 4 nodes beats any single node here
    assert multi > max(singles) + 0.5


def test_rho0_overhead_shrinks_capacity():
    # Dummy-packet overhead (1+eps_B) on the processed commodity can only
    # reduce capacity (Theorem 3's factor).
    p = paper_grid_problem(C=3.0)
    base = capacity_upper_bound(p, rho0=1.0).lam_star
    infl = capacity_upper_bound(p, rho0=1.5).lam_star
    assert infl <= base + 1e-9


def test_disconnected_source_zero():
    # Two disjoint components: s2 cannot reach the comp node.
    edges = np.array([(0, 1), (2, 3)])
    from repro.core.graph import Graph
    g = Graph(4, edges, np.array([5.0, 5.0]))
    p = ComputeProblem(g, s1=0, s2=2, dest=1, comp_nodes=(1,), comp_caps=(5.0,))
    r = capacity_upper_bound(p)
    assert r.lam_star == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# multi-stream (multiclass) extension — paper §II-B/§VI
# ---------------------------------------------------------------------------

class TestMultiStream:
    def test_single_stream_reduces_to_theorem4(self):
        from repro.core.capacity import multi_stream_capacity
        p = paper_grid_problem(C=2.0)
        ms = multi_stream_capacity([p], weights=[1.0])
        assert ms.lam_star == pytest.approx(8.0, abs=1e-6)

    def test_two_identical_streams_split_shared_capacity(self):
        from repro.core.capacity import multi_stream_capacity
        p = paper_grid_problem(C=2.0)
        ms = multi_stream_capacity([p, p])
        # identical streams share C exactly: total capacity unchanged,
        # each stream gets half
        assert ms.lam_star == pytest.approx(8.0, abs=1e-6)
        np.testing.assert_allclose(ms.lam_per_stream, 4.0, atol=1e-6)

    def test_disjoint_streams_add_capacity(self):
        from repro.core.capacity import multi_stream_capacity
        from repro.core.graph import grid_graph
        g = grid_graph(4, 4, 5.0)
        # two streams using DIFFERENT computation nodes and endpoints
        pa = ComputeProblem(g, s1=0, s2=3, dest=15, comp_nodes=(5,),
                            comp_caps=(2.0,))
        pb = ComputeProblem(g, s1=12, s2=15, dest=0, comp_nodes=(10,),
                            comp_caps=(2.0,))
        ms = multi_stream_capacity([pa, pb])
        # each stream can run at its own node capacity 2 -> total 4
        assert ms.lam_star == pytest.approx(4.0, abs=1e-6)

    def test_weighted_mix_moves_boundary_point(self):
        from repro.core.capacity import multi_stream_capacity
        p = paper_grid_problem(C=2.0)
        even = multi_stream_capacity([p, p], weights=[0.5, 0.5])
        skew = multi_stream_capacity([p, p], weights=[0.9, 0.1])
        # same total boundary for identical streams, different split
        assert skew.lam_star == pytest.approx(even.lam_star, abs=1e-6)
        assert skew.lam_per_stream[0] == pytest.approx(0.9 * skew.lam_star,
                                                       abs=1e-6)


class TestMotivatingExample:
    """Paper §I.A: the triangle with the three single-path options.  The LP
    optimum must (i) dominate every single-path option and (ii) equal the
    best of them when single-path is optimal, (iii) strictly beat them when
    multipath load-balancing helps."""

    def _single_path_rates(self, C, R12, R1d, R2d, lam=1e9):
        opt1 = min(C[1], lam, R12, R2d)    # compute at source 2
        opt2 = min(C[0], lam, R12, R1d)    # compute at source 1
        opt3 = min(C[2], lam, R1d, R2d)    # compute at destination
        return opt1, opt2, opt3

    def test_lp_dominates_single_paths(self):
        from repro.core.graph import Graph
        import itertools
        for C1, C2, Cd, R12, R1d, R2d in itertools.product(
                (0.5, 2.0), (1.0,), (3.0,), (1.0, 4.0), (2.0,), (1.5,)):
            g = Graph(3, np.array([(0, 1), (0, 2), (1, 2)]),
                      np.array([R12, R1d, R2d]))
            p = ComputeProblem(g, s1=0, s2=1, dest=2,
                               comp_nodes=(0, 1, 2), comp_caps=(C1, C2, Cd))
            lam = capacity_upper_bound(p).lam_star
            best_single = max(self._single_path_rates(
                (C1, C2, Cd), R12, R1d, R2d))
            assert lam >= best_single - 1e-6, (lam, best_single)

    def test_multipath_beats_single_path(self):
        # computation split across nodes: single-path best = min caps,
        # load balancing adds them up (communication permitting)
        from repro.core.graph import Graph
        g = Graph(3, np.array([(0, 1), (0, 2), (1, 2)]),
                  np.array([10.0, 10.0, 10.0]))
        p = ComputeProblem(g, s1=0, s2=1, dest=2,
                           comp_nodes=(0, 1, 2), comp_caps=(1.0, 1.0, 1.0))
        lam = capacity_upper_bound(p).lam_star
        best_single = 1.0
        assert lam == pytest.approx(3.0, abs=1e-6)   # all three nodes used
        assert lam > 2.5 * best_single
