"""Sharding rules: divisibility fallbacks, FSDP/TP/EP mapping, and an
8-device mini dry-run in a subprocess (the main test process keeps the
default single CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
from jax.sharding import PartitionSpec as P

from repro.runtime import sharding as shd


def _rules(fsdp=True, ep=True):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return shd.make_rules(mesh, fsdp=fsdp, expert_parallel=ep)


class TestSpecFor:
    def test_basic_mapping(self):
        r = _rules()
        spec = shd.spec_for((1024, 4096), ("embed", "ff"), r)
        assert spec == P(("data",), "model")

    def test_divisibility_fallback(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        r = shd.Rules(table={"heads": "model"}, mesh=mesh)
        # 14 heads % 16 != 0 on a real 16-way axis -> replicate; here the
        # axis is size 1 so anything divides — emulate via a fake size
        # direct check of the fallback logic with a 16-way mesh is done in
        # the subprocess test below; here check the zero-dim guard
        spec = shd.spec_for((0,), ("heads",), r)
        assert spec == P(None)

    def test_axis_reuse_guard(self):
        # the same mesh axis must not shard two dims of one tensor
        r = _rules()
        spec = shd.spec_for((64, 64), ("ff", "act_ff"), r)
        assert spec[0] == "model" and spec[1] is None

    def test_no_rules_context_constrain_is_identity(self):
        x = jax.numpy.ones((4, 4))
        assert shd.constrain(x, ("act_batch", None)) is x

    def test_ep_toggle(self):
        r_ep = _rules(ep=True)
        r_no = _rules(ep=False)
        assert r_ep.table["experts"] == "model"
        assert r_no.table["experts"] is None
        assert r_no.table["expert_ff"] == "model"


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import RunConfig, ShapeConfig, get_config, reduced
    from repro.runtime import sharding as shd
    from repro.runtime.step import init_train_state, make_train_step
    from repro.launch import roofline as rl

    cfg = reduced(get_config("qwen2-0.5b"), d_model=64, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 64, 8, "train"))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = shd.make_rules(mesh, fsdp=True, expert_parallel=True)
    with shd.use_rules(rules):
        state, axes = init_train_state(rcfg, abstract=True)
        st_sh = shd.tree_shardings(state, axes, rules)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 65), jnp.int32)}
        b_sh = shd.tree_shardings(
            batch, {"tokens": ("act_batch", "act_seq")}, rules)
        step = make_train_step(rcfg)
        lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None)).lower(state, batch)
        compiled = lowered.compile()
    txt = compiled.as_text()
    coll = rl.collective_bytes(txt)
    print(json.dumps({
        "ok": True,
        "n_devices": jax.device_count(),
        "has_collectives": any(v > 0 for k, v in coll.items()
                               if not k.startswith("n_")),
        "flops": rl.from_compiled(compiled, txt).flops_per_device,
    }))
""")


def test_mini_dryrun_8_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["n_devices"] == 8
    assert rec["has_collectives"], "sharded train step must emit collectives"
    assert rec["flops"] > 0


def test_main_process_sees_one_device():
    # the 512-device dryrun flag must never leak outside launch/dryrun; the
    # test process itself may legitimately run with a small fake-device mesh
    # (scripts/test.sh sets --xla_force_host_platform_device_count=8).
    import re
    counts = re.findall(r"--xla_force_host_platform_device_count=(\d+)",
                        os.environ.get("XLA_FLAGS", ""))
    # XLA honors the LAST occurrence when the flag is repeated
    expected = int(counts[-1]) if counts else 1
    assert jax.device_count() == expected
    assert jax.device_count() < 512
