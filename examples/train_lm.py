"""End-to-end example: train a ~1M-param OLMo-family model for a few hundred
steps on CPU with the full substrate (sharded data pipeline, AdamW+cosine,
checkpointing), then SIMULATE A CRASH and restart from the checkpoint —
the loss curve must continue where it left off.

  PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import tempfile

from repro.launch.train import main as train

ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
common = ["--arch", "olmo-1b", "--reduced", "--batch", "8", "--seq", "64",
          "--ckpt-dir", ckpt_dir, "--ckpt-every", "50", "--log-every", "25"]

print("=== phase 1: train 120 steps, crash at 119 (checkpoint at 100) ===")
try:
    train(common + ["--steps", "300", "--crash-at", "119"])
except SystemExit as e:
    print(f"(crashed as scripted: {e})")

print("\n=== phase 2: restart from checkpoint, train to step 300 ===")
losses = train(common + ["--steps", "300", "--resume"])

assert losses[-1] < losses[0], "loss must decrease across the restart"
print(f"\nOK: resumed training improved loss to {losses[-1]:.3f}")
shutil.rmtree(ckpt_dir, ignore_errors=True)
