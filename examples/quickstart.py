"""Quickstart: the paper in 60 seconds.

Builds the paper's 4x4 grid instance, computes the Theorem-4 capacity bound
via the multicommodity LP, runs the pi3 backpressure policy below and above
the bound, and prints the observed throughput + stability.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import PolicyConfig, capacity_upper_bound, paper_grid_problem
from repro.sim import simulate

problem = paper_grid_problem(C=2.0)           # 4x4 grid, R=5, four C=2 nodes
lam_star = capacity_upper_bound(problem).lam_star
print(f"Theorem-4 LP capacity: lambda* = {lam_star:.2f} queries/slot")

for lam in (0.75 * lam_star, 1.25 * lam_star):
    res = simulate(problem, PolicyConfig(name="pi3", eps_b=0.01),
                   lam=lam, T=3000, seed=0)
    rate = float(res.useful_rate(1000))
    q = np.asarray(res.total_queue)
    growth = (q[-1] - q[len(q) // 2]) / (len(q) // 2)   # backlog slope/slot
    growing = growth > 0.3
    print(f"  lambda={lam:4.1f}: delivered {rate:5.2f} results/slot, "
          f"backlog {'GROWS (unstable, as predicted)' if growing else 'bounded (stable)'}")

print("\npi3 = backpressure routing + join-shortest-sum-of-queues load"
      "\nbalancing + dummy-packet regulator (paper eq. 8-10).")
