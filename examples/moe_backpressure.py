"""Example: loss-free MoE load balancing via the paper's virtual queues.

Trains two tiny granite-family MoE models on the same stream — one with the
backpressure router (H-queue selection bias, paper eq. 9/10), one with plain
top-k — and prints per-expert load balance over training.

  PYTHONPATH=src python examples/moe_backpressure.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.data import DataConfig, TokenStream
from repro.runtime.step import init_train_state, make_train_step

STEPS, B, S = 40, 8, 64

for router in ("plain", "backpressure"):
    cfg = dataclasses.replace(reduced(get_config("granite-moe-1b-a400m")),
                              router=router)
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("ex", S, B, "train"),
                     activ_dtype="float32", remat="none")
    state, _ = init_train_state(rcfg, key=jax.random.key(0))
    step = jax.jit(make_train_step(rcfg), donate_argnums=(0,))
    data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=S, global_batch=B))
    for i in range(STEPS):
        state, metrics = step(state, {
            "tokens": jnp.asarray(data.batch(i)["tokens"])})
    # H tracks cumulative overflow per expert; its spread measures imbalance
    H = np.asarray(state.router_H)
    loss = float(metrics["loss"])
    spread = H.max() - H.min() if H.size else 0.0
    print(f"router={router:13s} loss={loss:.3f} "
          f"H-spread={spread:10.1f} (lower = better balanced)")
print("\nThe backpressure router keeps the virtual queues drained "
      "(bounded H) with no auxiliary loss term — the paper's H_n dynamics "
      "as loss-free expert balancing.")
