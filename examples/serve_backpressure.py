"""Example: backpressure request dispatch across model replicas (paper eq. 9
as a serving scheduler) + a real batched decode engine with dummy-slot
padding (the regulator, eq. 8).

  PYTHONPATH=src python examples/serve_backpressure.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import get_model, split_tree
from repro.serving import Engine, simulate

# --- control plane: dispatch policies under a straggling replica ----------
print("dispatch simulation: 8 replicas, one straggling at 30% speed,"
      " load 0.85")
for policy in ("rr", "jsq", "bp"):
    r = simulate(policy, ticks=2500, load=0.85, seed=3, straggler=2)
    print(f"  {policy:3s}: p50={r['p50']:6.1f}  p99={r['p99']:7.1f}  "
          f"residual backlog={r['residual_backlog']:9.0f}")

# --- data plane: actual batched decode with padding slots ------------------
print("\nbatched decode engine (qwen2-family reduced config):")
cfg = reduced(get_config("qwen2-0.5b"))
api = get_model(cfg)
params, _ = split_tree(api.init(key=jax.random.key(0)))
eng = Engine(cfg, params, slots=4, max_len=64)
rng = np.random.default_rng(0)
for _ in range(6):
    eng.submit(list(rng.integers(0, cfg.vocab, rng.integers(3, 9))),
               max_new=8)
fin = eng.run_until_done()
print(f"  served {len(fin)} requests; sample outputs:")
for rid in sorted(fin)[:3]:
    print(f"    req {rid}: {fin[rid].out}")
