"""Example: the serving subsystem (DESIGN.md §9) — live query traffic
through backpressure admission control, scored against the exact LP
bound — plus the continuous-batching LLM demo engine (dummy-slot padding
= the paper's regulator made literal, DESIGN.md §2).

  PYTHONPATH=src python examples/serve_backpressure.py
"""
import jax
import numpy as np

from repro.fleet import policy_bound_exact
from repro.serving import ServingJob, run_serving

# --- control plane: bursty queries vs the admission gate -------------------
bound = policy_bound_exact("paper_grid", "pi3_reg", 0.05)
print(f"paper grid, pi3_reg, eps_B=0.05: exact LP bound = {bound:.1f} QPS")

jobs = [ServingJob(trace="bursty", lam=frac * bound, seed=0)
        for frac in (0.6, 0.95, 1.3)]
res = run_serving(jobs, T=2048, chunk=256)
print("markov_onoff bursts at three offered loads:")
for job, m in zip(jobs, res.metrics):
    print(f"  lam={job.lam:5.2f} ({job.lam / bound:4.2f}x bound): "
          f"delivered={m['delivered_qps']:5.2f} QPS "
          f"shed={m['shed_frac']:4.2f} p99={m['p99_sojourn']:6.0f} slots "
          f"gate_open={m['gate_open_frac']:4.2f}")
# 0.6x/0.95x: everything admitted; 1.3x: the gate duty-cycles, shedding
# the excess while the admitted rate holds at capacity.

# --- data plane: actual batched decode with padding slots ------------------
print("\nbatched decode engine (qwen2-family reduced config):")
from repro.configs import get_config, reduced
from repro.launch.serve import Engine
from repro.models import get_model, split_tree

cfg = reduced(get_config("qwen2-0.5b"))
api = get_model(cfg)
params, _ = split_tree(api.init(key=jax.random.key(0)))
eng = Engine(cfg, params, slots=4, max_len=64)
rng = np.random.default_rng(0)
for _ in range(6):
    eng.submit(list(rng.integers(0, cfg.vocab, rng.integers(3, 9))),
               max_new=8)
fin = eng.run_until_done()
print(f"  served {len(fin)} requests; sample outputs:")
for rid in sorted(fin)[:3]:
    print(f"    req {rid}: {fin[rid].out}")
