#!/usr/bin/env bash
# Blessed fleet-run entrypoint with production run hygiene (DESIGN.md §11):
#
#   - tcmalloc preloaded when available (glibc malloc fragments badly
#     under XLA's large transient allocations on week-long runs), with a
#     high large-alloc report threshold so the console stays readable;
#   - TF_CPP_MIN_LOG_LEVEL=4 to silence XLA's C++ chatter (the stream
#     records are the observability channel, not stderr);
#   - 8 fake host-platform devices + src on PYTHONPATH, exactly the
#     tier-1 configuration (scripts/test.sh), so a fleet launched here
#     runs the same compiled programs CI validated.
#
# With no arguments, runs the paper-grid capacity sweep with streaming
# telemetry to FLEET_stream.jsonl — tail it live from another terminal:
#
#   PYTHONPATH=src python -m repro.obs.follow --follow   # capacity_report
#
# With arguments, execs `python "$@"` under the same hygiene, e.g.:
#
#   scripts/run_fleet.sh benchmarks/bench_fleet.py --preset smoke \
#       --out BENCH_fleet.json --stream-out FLEET_stream.jsonl
set -euo pipefail
cd "$(dirname "$0")/.."

for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
          /usr/lib/libtcmalloc.so.4 /usr/lib64/libtcmalloc.so.4; do
    if [[ -e "$so" ]]; then
        export LD_PRELOAD="$so${LD_PRELOAD:+:$LD_PRELOAD}"
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
        break
    fi
done

export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ $# -gt 0 ]]; then
    exec python "$@"
fi

echo "run_fleet: streaming to FLEET_stream.jsonl" \
     "(tail: PYTHONPATH=src python -m repro.obs.follow --follow)"
exec python - <<'PY'
from repro.fleet import capacity_report

table = capacity_report(
    {"paper_grid": ("pi1", "pi2", "pi3", "pi2_reg", "pi3_reg")},
    rate_fracs=(0.85, 0.95), seeds=(0, 1), T=8192, chunk=512,
    eps_b=0.05, stream_path="FLEET_stream.jsonl")
for scen, entry in table["scenarios"].items():
    for pol, row in entry["policies"].items():
        print(f"{scen}/{pol}: useful={row['best_useful_rate']:.3f} "
              f"bound={row['bound_exact']:.3f} "
              f"eff={row['efficiency']:.3f}")
print(f"run_fleet: done ({table.get('stream_records', 0)} stream records)")
PY
