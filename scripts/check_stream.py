#!/usr/bin/env python
"""Stream-record schema gate (CI: scripts/test.sh, after the bench runs).

Validates every emitted ``*_stream.jsonl`` against the versioned schema
in `repro.obs.schema` (DESIGN.md §11):

  1. the schema module itself is *blessed* — `schema_digest()` must match
     ``BLESSED_DIGESTS[SCHEMA_VERSION]``, so editing a field table
     without bumping SCHEMA_VERSION (and blessing the new digest) fails
     here before any file is read;
  2. every line parses as JSON and carries the current
     ``schema_version``;
  3. every record's key set and value types match its kind's field table
     exactly (unknown keys are schema drift, missing keys are truncation);
  4. the stream clock is monotone: ``t`` non-decreasing and ``chunk``
     strictly increasing per ``(kind, group)``.

Files passed explicitly must exist; with no arguments the script globs
``*_stream.jsonl`` in the repo root and soft-passes when none are there
(the benches that emit them may have been skipped).

With ``--resumed``, each file is additionally checked as the merged feed
of a preemption-safe run (DESIGN.md §12): it must carry at least one
``resume`` record, and stripping the resume seam markers must leave a
stream that still validates — i.e. the resumed writer's dedupe produced
exactly the uninterrupted record sequence, with no duplicate and no
time-traveling record across the seam.

Usage:
  python scripts/check_stream.py SERVING_stream.jsonl FLEET_stream.jsonl
  python scripts/check_stream.py            # glob *_stream.jsonl
  python scripts/check_stream.py --resumed RESUMED_stream.jsonl
"""
from __future__ import annotations

import glob
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.obs import schema  # noqa: E402  (path bootstrap above)


def check_file(path: str, resumed: bool = False) -> list[str]:
    errs: list[str] = []
    records = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                errs.append(f"{path}:{i + 1}: not valid JSON ({e})")
    if not records and not errs:
        errs.append(f"{path}: no records")
    errs.extend(f"{path}: {e}" for e in schema.validate_stream(records))
    if resumed and not errs:
        seams = [r for r in records if r.get("kind") == "resume"]
        if not seams:
            errs.append(f"{path}: --resumed but no resume record")
        spliced = [r for r in records if r.get("kind") != "resume"]
        errs.extend(f"{path} (resume seam stripped): {e}"
                    for e in schema.validate_stream(spliced))
    return errs


def main(argv: list[str]) -> int:
    errors: list[str] = []

    digest = schema.schema_digest()
    blessed = schema.BLESSED_DIGESTS.get(schema.SCHEMA_VERSION)
    if blessed is None:
        errors.append(
            f"SCHEMA_VERSION {schema.SCHEMA_VERSION} has no blessed digest "
            "in repro.obs.schema.BLESSED_DIGESTS")
    elif digest != blessed:
        errors.append(
            "schema changed without a version bump: schema_digest() = "
            f"{digest} but BLESSED_DIGESTS[{schema.SCHEMA_VERSION}] = "
            f"{blessed}. Bump SCHEMA_VERSION and bless the new digest.")

    args = argv[1:]
    resumed = "--resumed" in args
    paths = [a for a in args if a != "--resumed"]
    if not paths:
        paths = sorted(glob.glob(str(REPO / "*_stream.jsonl")))
        if not paths:
            print("check_stream: no *_stream.jsonl files found; "
                  "schema digest " +
                  ("ok" if not errors else "BROKEN"))
            return 1 if errors else 0

    n_records = 0
    for p in paths:
        if not pathlib.Path(p).exists():
            errors.append(f"{p}: missing (was its bench skipped?)")
            continue
        errs = check_file(p, resumed=resumed)
        errors.extend(errs)
        if not errs:
            n_records += sum(1 for _ in open(p))

    for e in errors:
        print(f"check_stream: ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"check_stream: {len(paths)} files, {n_records} records, "
              f"schema v{schema.SCHEMA_VERSION} "
              f"(digest {digest[:12]}...) all valid")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
