#!/usr/bin/env python
"""Docs consistency gate (CI: scripts/test.sh).

1. Cross-reference check: every ``DESIGN.md §N`` / ``README.md §N``
   reference in the source tree must resolve to a heading in that file
   (a dangling reference is how "DESIGN.md §2" shipped for two PRs with
   no DESIGN.md in the repo).
2. Named-section check: prose references like ``README.md ("Fleet sweep
   cookbook")`` must match a real heading.
3. Doctests: the runnable snippets in README.md (and any in DESIGN.md)
   are executed with ``doctest`` — run with PYTHONPATH=src.

Exit code 0 iff everything resolves and every doctest passes.
"""
from __future__ import annotations

import doctest
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "scripts", "examples")
SCAN_TOP = ("README.md", "DESIGN.md", "ROADMAP.md", "ISSUE.md")
DOC_FILES = ("README.md", "DESIGN.md")

SECTION_REF = re.compile(r"(DESIGN|README)\.md\s+§(\d+)")
NAMED_REF = re.compile(r"(DESIGN|README)\.md\s+\(\"([^\"]+)\"\)")


def _headings(path: pathlib.Path) -> str:
    return "\n".join(line for line in path.read_text().splitlines()
                     if line.startswith("#"))


def check_references() -> list[str]:
    errors = []
    heads = {f: _headings(ROOT / f) for f in DOC_FILES if (ROOT / f).exists()}
    files = [p for d in SCAN_DIRS for p in (ROOT / d).rglob("*")
             if p.suffix in (".py", ".md", ".sh") and p.is_file()]
    files += [ROOT / f for f in SCAN_TOP if (ROOT / f).exists()]
    for path in files:
        text = path.read_text(errors="replace")
        for m in SECTION_REF.finditer(text):
            doc = f"{m.group(1)}.md"
            if doc not in heads:
                errors.append(f"{path}: references missing file {doc}")
            elif f"§{m.group(2)}" not in heads[doc]:
                errors.append(
                    f"{path}: dangling reference {doc} §{m.group(2)}")
        for m in NAMED_REF.finditer(text):
            # normalize line-wrapped titles inside docstrings
            doc = f"{m.group(1)}.md"
            title = re.sub(r"\s+", " ", m.group(2))
            if doc not in heads or title not in heads[doc]:
                errors.append(
                    f"{path}: dangling reference {doc} section {title!r}")
    return errors


def run_doctests() -> list[str]:
    errors = []
    for f in DOC_FILES:
        path = ROOT / f
        if not path.exists():
            continue
        res = doctest.testfile(str(path), module_relative=False,
                               optionflags=doctest.NORMALIZE_WHITESPACE)
        if res.failed:
            errors.append(f"{f}: {res.failed}/{res.attempted} doctests failed")
        else:
            print(f"check_docs: {f}: {res.attempted} doctests passed")
    return errors


def main() -> int:
    errors = check_references() + run_doctests()
    for e in errors:
        print(f"check_docs: ERROR: {e}", file=sys.stderr)
    if not errors:
        print("check_docs: all section references resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
