#!/usr/bin/env python
"""Bench regression gate (CI: scripts/test.sh, after the bench runs).

Handles both bench tables by shape:

* **fleet** tables (`benchmarks/bench_fleet.py`) — fails on:

  1. >25% per-sim wall-time regression (`us_per_sim`), and
  2. any efficiency-gate breach — the paper-grid rows must keep
     pi3 >= 0.8 and every regulated (`*_reg`) row >= 0.9 of its *exact*
     regulated LP bound (DESIGN.md §6), and
  3. a broken bound invariant (`bound_approx <= bound_exact <=
     bound_approx * rho0`) anywhere in the table, and
  4. a non-zero xla-vs-pallas parity diff in the `backends` section
     (the bit-identical contract of DESIGN.md §7), when present, and
  5. a `frontier` section whose measured lam_max/bound_exact leaves
     FRONTIER_RATIO_BAND, whose bisection recompiled the chunk step, or
     whose early stop saved less than FRONTIER_MIN_SAVED_FRAC of the
     simulated slots (DESIGN.md §8), when present.

* **kernel** tables (`benchmarks/bench_kernels.py --out`, detected by a
  top-level `"kernels"` key) — fails on a >25% per-kernel µs regression
  against the committed `BENCH_kernels_baseline.json` (the exact-match
  assertions live in the bench itself).

* **serving** tables (`benchmarks/bench_serving.py --out`, detected by a
  top-level `"serving"` key or forced with `--mode serving`) — fails on:

  1. a nominal-load (0.95 x bound) row below SERVING_MIN_RATIO delivered
     QPS vs the exact LP bound, shedding above SERVING_MAX_SHED, or p99
     sojourn above SERVING_P99_MAX (DESIGN.md §9), and
  2. an overload row that fails to shed >= SERVING_OVERLOAD_MIN_SHED or
     admits above capacity x SERVING_OVERLOAD_RATE_SLACK, and
  3. a non-zero xla-vs-pallas parity diff on the serving decision path,
  4. a >25% per-sim wall-time regression vs the baseline's `serving`
     section.

* **atlas** tables (`benchmarks/bench_atlas.py --out`, detected by a
  top-level `"atlas"` key or forced with `--mode atlas`) — fails on:

  1. any ATLAS_BAND_FAMILIES family whose lam_max/bound_exact ratio
     median leaves ATLAS_RATIO_BAND or whose q10-q90 seed band is wider
     than ATLAS_MAX_BAND_WIDTH (DESIGN.md §10, §13), and
  2. a fleet that needed more than ATLAS_MAX_PROGRAMS compiled programs,
     recompiled a chunk step (n_step_compiles != n_programs), swept
     fewer than ATLAS_MIN_CELLS cells / ATLAS_MIN_LANES bisection lanes
     / ATLAS_MIN_BUCKETS shape buckets, blew the ATLAS_MAX_LAUNCHES
     budget (total, or ATLAS_MAX_BUCKET_LAUNCHES in any one bucket, or
     a per-bucket ledger that does not sum to the total), or batched
     below ATLAS_MIN_SPEEDUP vs the sequential per-cell launch count,
     and
  3. a >25% wall-time regression vs the committed `BENCH_atlas.json`.

`--mode {auto,fleet,kernels,serving,atlas,stream}` (default auto: sniff
the table shape) picks the checker; the baseline for serving mode is the
committed `BENCH_baseline.json`, whose `"serving"` key holds the
reference table.  A `.jsonl` current sniffs as **stream** — the file is
schema-validated against `repro.obs.schema` (delegating to
`scripts/check_stream.py`, DESIGN.md §11) and needs no baseline.

Peak chunk-step memory is reported as a delta but not gated (XLA temp
sizing is backend/version dependent).

Timing on shared CI hardware is noisy; the threshold can be relaxed via
CHECK_BENCH_MAX_REGRESSION (default 1.25) or timing can be skipped
entirely with CHECK_BENCH_SKIP_TIMING=1 (the efficiency/bound/parity
gates always run).

Usage:
  python scripts/check_bench.py BENCH_fleet.json BENCH_baseline.json
  python scripts/check_bench.py BENCH_kernels.json BENCH_kernels_baseline.json
  python scripts/check_bench.py --mode serving BENCH_serving.json \
      BENCH_baseline.json
  python scripts/check_bench.py --mode atlas BENCH_atlas_new.json \
      BENCH_atlas.json
"""
from __future__ import annotations

import importlib.util
import json
import os
import pathlib
import sys


def _load_bench_module(name: str = "bench_fleet"):
    """Import a benchmarks/ module (the single source of truth for the
    gate constants — their module top levels import nothing heavy)."""
    path = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" \
        / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


_BENCH = _load_bench_module()
#: (scenario, policy) -> minimum efficiency vs the exact regulated bound.
EFFICIENCY_GATES = _BENCH.EFFICIENCY_GATES
#: lam_max / bound_exact band for frontier targets (DESIGN.md §8).
FRONTIER_RATIO_BAND = _BENCH.FRONTIER_RATIO_BAND
#: minimum aggregate early-stop slot savings across the frontier smoke.
FRONTIER_MIN_SAVED_FRAC = _BENCH.FRONTIER_MIN_SAVED_FRAC
#: checkpoint-on us_per_sim ceiling vs plain, as a fraction (DESIGN.md §12).
RESILIENCE_MAX_OVERHEAD = _BENCH.RESILIENCE_MAX_OVERHEAD


def iter_rows(table: dict):
    for scen, entry in table.get("scenarios", {}).items():
        for pol, row in entry.get("policies", {}).items():
            yield scen, pol, row


def check_kernels(current: dict, baseline: dict) -> list[str]:
    """Per-kernel µs regression gate for bench_kernels tables."""
    errors = []
    if os.environ.get("CHECK_BENCH_SKIP_TIMING", "0") == "1":
        print("check_bench: kernel timing checks skipped "
              "(CHECK_BENCH_SKIP_TIMING=1)")
        return errors
    max_reg = float(os.environ.get("CHECK_BENCH_MAX_REGRESSION", "1.25"))
    cur = current.get("kernels", {})
    for name, base_row in baseline.get("kernels", {}).items():
        row = cur.get(name)
        if row is None:
            errors.append(f"kernel {name} missing from current table")
            continue
        base_us, cur_us = base_row.get("us"), row.get("us")
        if not base_us or cur_us is None:
            continue
        ratio = cur_us / base_us
        print(f"check_bench: kernels/{name} {cur_us:.0f}us vs baseline "
              f"{base_us:.0f}us (x{ratio:.2f}, limit x{max_reg:.2f})")
        if ratio > max_reg:
            errors.append(f"kernels/{name}: us regression {cur_us:.0f} > "
                          f"{base_us:.0f} * {max_reg:.2f}")
    return errors


def check_serving(current: dict, baseline: dict) -> list[str]:
    """Acceptance + regression gates for bench_serving tables.

    Gate constants come from benchmarks/bench_serving.py (single source
    of truth, asserted there on every bench run); the baseline's
    `serving` section supplies the timing reference."""
    sv = _load_bench_module("bench_serving")
    errors: list[str] = []
    cur = current.get("serving", current)
    base = baseline.get("serving", {})

    # --- 1. wall-time regression vs the committed serving baseline
    if os.environ.get("CHECK_BENCH_SKIP_TIMING", "0") != "1":
        max_reg = float(os.environ.get("CHECK_BENCH_MAX_REGRESSION", "1.25"))
        cur_us = current.get("us_per_sim", cur.get("us_per_sim"))
        base_us = base.get("us_per_sim")
        if cur_us is None:
            errors.append("serving table has no us_per_sim field")
        elif base_us:
            ratio = cur_us / base_us
            print(f"check_bench: serving us_per_sim {cur_us:.0f} vs "
                  f"baseline {base_us:.0f} (x{ratio:.2f}, "
                  f"limit x{max_reg:.2f})")
            if ratio > max_reg:
                errors.append(f"serving us_per_sim regression: "
                              f"{cur_us:.0f} > {base_us:.0f} * {max_reg:.2f}")

    # --- 2. nominal-load row: delivered/bound floor, shed ceiling, p99
    bound = cur.get("bound_exact", 0.0)
    rows = cur.get("rows", {})
    nom = rows.get("0.95")
    if nom is None:
        errors.append("serving table has no 0.95-load row")
    else:
        ratio = nom.get("delivered_over_bound", 0.0)
        shed = nom.get("shed_frac_max", 1.0)
        p99 = nom.get("p99_sojourn_max", float("inf"))
        print(f"check_bench: serving 0.95-load ratio={ratio:.3f} "
              f"(gate >= {sv.SERVING_MIN_RATIO}) shed={shed:.3f} "
              f"(<= {sv.SERVING_MAX_SHED}) p99={p99:.0f} "
              f"(<= {sv.SERVING_P99_MAX:.0f})")
        if ratio < sv.SERVING_MIN_RATIO:
            errors.append(f"serving 0.95-load delivered/bound {ratio:.3f} "
                          f"< {sv.SERVING_MIN_RATIO} (bound={bound})")
        if shed > sv.SERVING_MAX_SHED:
            errors.append(f"serving 0.95-load shed_frac {shed:.3f} > "
                          f"{sv.SERVING_MAX_SHED}")
        if p99 > sv.SERVING_P99_MAX:
            errors.append(f"serving 0.95-load p99 {p99:.0f} > "
                          f"{sv.SERVING_P99_MAX:.0f}")

    # --- 3. overload row: the gate must shed, admission stays bounded
    over = rows.get(f"{sv.SERVING_OVERLOAD_FRAC:g}")
    if over is None:
        errors.append(f"serving table has no "
                      f"{sv.SERVING_OVERLOAD_FRAC:g}x overload row")
    else:
        shed = over.get("shed_frac", 0.0)
        adm = over.get("admitted_rate", float("inf"))
        cap = bound * sv.SERVING_OVERLOAD_RATE_SLACK
        print(f"check_bench: serving overload shed={shed:.3f} "
              f"(gate >= {sv.SERVING_OVERLOAD_MIN_SHED}) "
              f"admitted={adm:.3f} (<= {cap:.3f})")
        if shed < sv.SERVING_OVERLOAD_MIN_SHED:
            errors.append(f"serving overload shed_frac {shed:.3f} < "
                          f"{sv.SERVING_OVERLOAD_MIN_SHED}")
        if adm > cap:
            errors.append(f"serving overload admitted_rate {adm:.3f} > "
                          f"{cap:.3f}")

    # --- 4. backend parity on the serving decision path: bit-identical
    parity = cur.get("parity")
    if parity is None:
        errors.append("serving table missing parity section")
    else:
        diff = parity.get("parity_max_abs_diff")
        if diff is None:
            errors.append("serving parity section missing "
                          "parity_max_abs_diff")
        elif diff != 0.0:
            errors.append(f"serving xla/pallas parity broken: "
                          f"max |diff| = {diff}")
        else:
            print("check_bench: serving xla/pallas parity exact (diff 0.0)")
    return errors


def check_atlas(current: dict, baseline: dict) -> list[str]:
    """Acceptance + regression gates for bench_atlas tables (DESIGN.md
    §10).  Gate constants come from benchmarks/bench_atlas.py (single
    source of truth, asserted there on every bench run); the committed
    `BENCH_atlas.json` supplies the timing reference."""
    at = _load_bench_module("bench_atlas")
    errors: list[str] = []
    cur = current.get("atlas", current)
    base = baseline.get("atlas", {})
    preset = cur.get("preset", "full")
    gates = at.ATLAS_GATES.get(preset)
    if gates is None:
        errors.append(f"atlas table preset {preset!r} not in "
                      f"{sorted(at.ATLAS_GATES)}")
        gates = at.ATLAS_GATES["full"]

    # --- 1. wall-time regression vs the committed atlas baseline — only
    # meaningful when both tables ran the same preset (the ci subsample
    # against the full baseline would pass trivially and mask a real
    # slowdown)
    same_preset = preset == base.get("preset", "full")
    if not same_preset:
        print(f"check_bench: atlas wall gate skipped (preset {preset!r} "
              f"vs baseline {base.get('preset', 'full')!r})")
    if (os.environ.get("CHECK_BENCH_SKIP_TIMING", "0") != "1"
            and same_preset):
        max_reg = float(os.environ.get("CHECK_BENCH_MAX_REGRESSION", "1.25"))
        cur_w, base_w = cur.get("wall_s"), base.get("wall_s")
        if cur_w is None:
            errors.append("atlas table has no wall_s field")
        elif base_w:
            ratio = cur_w / base_w
            print(f"check_bench: atlas wall {cur_w:.0f}s vs baseline "
                  f"{base_w:.0f}s (x{ratio:.2f}, limit x{max_reg:.2f})")
            if ratio > max_reg:
                errors.append(f"atlas wall_s regression: {cur_w:.0f} > "
                              f"{base_w:.0f} * {max_reg:.2f}")

    # --- 2. per-family ratio band + band width on the unfaded families
    lo, hi = at.ATLAS_RATIO_BAND
    fams = cur.get("families", {})
    for fam in at.ATLAS_BAND_FAMILIES:
        row = fams.get(fam)
        if row is None:
            errors.append(f"atlas table missing family {fam}")
            continue
        med = row.get("ratio_median")
        band = row.get("band") or {}
        width = band.get("width")
        print(f"check_bench: atlas {fam} ratio_median="
              f"{'missing' if med is None else format(med, '.3f')} "
              f"(band [{lo}, {hi}]) width="
              f"{'missing' if width is None else format(width, '.3f')} "
              f"(<= {at.ATLAS_MAX_BAND_WIDTH}) undecided_hi="
              f"{row.get('n_undecided_hi')}/{row.get('n_cells')} "
              f"requeued={row.get('n_requeued')}")
        if med is None or not (lo <= med <= hi + 1e-9):
            errors.append(f"atlas {fam}: lam_max/bound_exact median "
                          f"{med} outside [{lo}, {hi}]")
        if width is None or width > at.ATLAS_MAX_BAND_WIDTH + 1e-9:
            errors.append(f"atlas {fam}: seed band width {width} > "
                          f"{at.ATLAS_MAX_BAND_WIDTH} (DESIGN.md §13)")

    # --- 3. fleet-shape gates: scale, compile discipline, launch budget
    n_cells = cur.get("n_cells", 0)
    n_lanes = cur.get("n_lanes", 0)
    n_prog = cur.get("n_programs", 0)
    n_comp = cur.get("n_step_compiles")
    n_launch = cur.get("n_launches", 0)
    speedup = cur.get("launch_speedup", 0.0)
    n_buckets = cur.get("n_buckets", 1)
    bucket_launches = {int(b): int(n)
                       for b, n in (cur.get("bucket_launches") or {}).items()}
    print(f"check_bench: atlas[{preset}] cells={n_cells} lanes={n_lanes} "
          f"buckets={n_buckets} programs={n_prog} compiles={n_comp} "
          f"launches={n_launch} per-bucket={bucket_launches} "
          f"requeues={cur.get('n_requeues')} speedup=x{speedup:.1f}")
    if n_cells < gates["min_cells"]:
        errors.append(f"atlas: only {n_cells} cells "
                      f"(need >= {gates['min_cells']})")
    if n_lanes < gates["min_lanes"]:
        errors.append(f"atlas: only {n_lanes} bisection lanes "
                      f"(need >= {gates['min_lanes']})")
    if n_buckets < at.ATLAS_MIN_BUCKETS:
        errors.append(f"atlas: {n_buckets} shape buckets "
                      f"(need >= {at.ATLAS_MIN_BUCKETS})")
    if n_prog > at.ATLAS_MAX_PROGRAMS:
        errors.append(f"atlas: {n_prog} compiled programs "
                      f"(ceiling {at.ATLAS_MAX_PROGRAMS})")
    if n_comp != n_prog:
        errors.append(f"atlas: {n_comp} step compiles across {n_prog} "
                      "programs (rewrites must not retrace)")
    if sum(bucket_launches.values()) != n_launch:
        errors.append(f"atlas: per-bucket launch ledger "
                      f"{bucket_launches} does not sum to n_launches="
                      f"{n_launch}")
    for b, n in sorted(bucket_launches.items()):
        if n > gates["max_bucket_launches"]:
            errors.append(f"atlas: bucket {b} used {n} launches "
                          f"(budget {gates['max_bucket_launches']})")
    if n_launch > gates["max_launches"]:
        errors.append(f"atlas: {n_launch} chunk launches "
                      f"(budget {gates['max_launches']})")
    if speedup < gates["min_speedup"]:
        errors.append(f"atlas: launch speedup x{speedup:.1f} < "
                      f"x{gates['min_speedup']}")
    return errors


def check(current: dict, baseline: dict, mode: str = "auto") -> list[str]:
    if mode == "auto":
        mode = ("kernels" if "kernels" in current else
                "serving" if "serving" in current else
                "atlas" if "atlas" in current else "fleet")
    if mode == "kernels":
        return check_kernels(current, baseline)
    if mode == "serving":
        return check_serving(current, baseline)
    if mode == "atlas":
        return check_atlas(current, baseline)
    errors = []

    # --- 1. wall-time regression
    if os.environ.get("CHECK_BENCH_SKIP_TIMING", "0") != "1":
        max_reg = float(os.environ.get("CHECK_BENCH_MAX_REGRESSION", "1.25"))
        cur_us, base_us = current.get("us_per_sim"), baseline.get("us_per_sim")
        if cur_us is None:
            errors.append("current table has no us_per_sim field")
        elif base_us:
            ratio = cur_us / base_us
            print(f"check_bench: us_per_sim {cur_us:.0f} vs baseline "
                  f"{base_us:.0f} (x{ratio:.2f}, limit x{max_reg:.2f})")
            if ratio > max_reg:
                errors.append(
                    f"us_per_sim regression: {cur_us:.0f} > "
                    f"{base_us:.0f} * {max_reg:.2f}")

    # --- 2. efficiency gates
    rows = {(s, p): r for s, p, r in iter_rows(current)}
    for (scen, pol), floor in EFFICIENCY_GATES.items():
        row = rows.get((scen, pol))
        if row is None:
            continue                      # preset does not sweep this row
        eff = row.get("efficiency", 0.0)
        print(f"check_bench: {scen}/{pol} efficiency {eff:.3f} "
              f"(gate >= {floor})")
        if eff < floor:
            errors.append(f"{scen}/{pol}: efficiency {eff:.3f} < {floor} "
                          f"vs exact bound {row.get('bound_exact')}")

    # --- 3. bound invariants (exact regulated LP vs rho0 approximation)
    for scen, pol, row in iter_rows(current):
        be, ba = row.get("bound_exact"), row.get("bound_approx")
        rho0 = row.get("rho0", 1.0)
        if be is None or ba is None:
            errors.append(f"{scen}/{pol}: missing bound_exact/bound_approx")
            continue
        if not (ba <= be * (1 + 1e-9) and be <= ba * rho0 * (1 + 1e-9)):
            errors.append(
                f"{scen}/{pol}: bound invariant broken: approx={ba} "
                f"exact={be} rho0={rho0}")

    # --- 4. backend parity (xla vs pallas, DESIGN.md §7): bit-identical
    backends = current.get("backends")
    if backends:
        diff = backends.get("parity_max_abs_diff")
        for name, row in backends.items():
            if isinstance(row, dict) and "us_per_sim" in row:
                print(f"check_bench: backend {name} "
                      f"us_per_sim={row['us_per_sim']:.0f}")
        if diff is None:
            errors.append("backends section missing parity_max_abs_diff")
        elif diff != 0.0:
            errors.append(f"xla/pallas parity broken: max |diff| = {diff}")
        else:
            print("check_bench: xla/pallas parity exact (diff 0.0)")

    # --- 5. frontier gates (DESIGN.md §8): the measured lam_max of every
    # target stays inside the ratio band of its exact LP bound, bisection
    # steps reuse one compiled program, and the early stop pays for itself.
    frontier = current.get("frontier")
    if frontier:
        lo, hi = FRONTIER_RATIO_BAND
        for name, row in frontier.get("targets", {}).items():
            ratio = row.get("ratio")
            print(f"check_bench: frontier {name} ratio="
                  f"{'missing' if ratio is None else format(ratio, '.3f')} "
                  f"(band [{lo}, {hi}]) saved_frac="
                  f"{row.get('slots_saved_frac', 0):.3f}")
            if ratio is None or not (lo <= ratio <= hi + 1e-9):
                errors.append(f"frontier {name}: lam_max/bound_exact "
                              f"{ratio} outside [{lo}, {hi}]")
            if row.get("n_step_compiles") != 1:
                errors.append(f"frontier {name}: bisection compiled "
                              f"{row.get('n_step_compiles')} chunk-step "
                              "programs (must be 1)")
        frac = frontier.get("slots_saved_frac", 0.0)
        print(f"check_bench: frontier slots_saved_frac {frac:.3f} "
              f"(gate >= {FRONTIER_MIN_SAVED_FRAC})")
        if frac < FRONTIER_MIN_SAVED_FRAC:
            errors.append(f"frontier: early stop saved only {frac:.1%} of "
                          f"simulated slots "
                          f"(< {FRONTIER_MIN_SAVED_FRAC:.0%})")

    # --- 6. resilience overhead (DESIGN.md §12): chunk-boundary
    # checkpointing must be nearly free (snapshot-before-donate is a pure
    # host read; disk writes are backgrounded).  A timing gate, so it
    # honors CHECK_BENCH_SKIP_TIMING like every other wall-clock check.
    resilience = current.get("resilience")
    if resilience and os.environ.get("CHECK_BENCH_SKIP_TIMING", "0") != "1":
        frac = resilience.get("overhead_frac")
        print(f"check_bench: resilience checkpoint overhead "
              f"{'missing' if frac is None else format(frac, '+.3f')} "
              f"(gate <= {RESILIENCE_MAX_OVERHEAD})")
        if frac is None:
            errors.append("resilience section missing overhead_frac")
        elif frac > RESILIENCE_MAX_OVERHEAD:
            errors.append(
                f"resilience: checkpoint-on us_per_sim overhead "
                f"{frac:+.1%} > {RESILIENCE_MAX_OVERHEAD:.0%} "
                f"(plain={resilience.get('us_per_sim_plain'):.0f}us "
                f"ckpt={resilience.get('us_per_sim_ckpt'):.0f}us)")

    # --- memory delta: informational only
    cur_mem = (current.get("memory") or {}).get("peak_bytes")
    base_mem = (baseline.get("memory") or {}).get("peak_bytes")
    if cur_mem and base_mem:
        print(f"check_bench: chunk-step peak {cur_mem:.0f} B vs baseline "
              f"{base_mem:.0f} B ({cur_mem / base_mem - 1:+.1%} - not gated)")
    return errors


def check_stream_files(paths: list[str]) -> list[str]:
    """Delegate ``*_stream.jsonl`` validation to the schema gate
    (scripts/check_stream.py), so `--mode auto` covers stream files with
    the same contract CI's dedicated gate enforces."""
    spec = importlib.util.spec_from_file_location(
        "check_stream",
        pathlib.Path(__file__).resolve().parent / "check_stream.py")
    cs = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cs)
    errors = []
    for p in paths:
        if not pathlib.Path(p).exists():
            errors.append(f"{p}: missing stream file")
        else:
            errors.extend(cs.check_file(p))
    return errors


def main(argv: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Bench regression gate (see module docstring)")
    ap.add_argument("current", help="freshly produced bench JSON "
                    "(or a *_stream.jsonl to schema-validate)")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="committed baseline JSON (unused in stream mode)")
    ap.add_argument("--mode",
                    choices=("auto", "fleet", "kernels", "serving", "atlas",
                             "stream"),
                    default="auto",
                    help="which checker to run (auto: sniff table shape; "
                    "*.jsonl files sniff as stream)")
    args = ap.parse_args(argv[1:])

    # Stream sniffing: a .jsonl current (or --mode stream) is a stream
    # record file, validated against repro.obs.schema — no baseline table.
    if args.mode == "stream" or (args.mode == "auto"
                                 and args.current.endswith(".jsonl")):
        paths = [args.current]
        if args.baseline and args.baseline.endswith(".jsonl"):
            paths.append(args.baseline)
        errors = check_stream_files(paths)
        for e in errors:
            print(f"check_bench: ERROR: {e}", file=sys.stderr)
        if not errors:
            print(f"check_bench: stream schema ok ({', '.join(paths)})")
        return 1 if errors else 0

    if args.baseline is None:
        ap.error("baseline is required outside stream mode")
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    errors = check(current, baseline, mode=args.mode)
    for e in errors:
        print(f"check_bench: ERROR: {e}", file=sys.stderr)
    if not errors:
        print("check_bench: all gates pass")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
