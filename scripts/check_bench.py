#!/usr/bin/env python
"""Bench regression gate (CI: scripts/test.sh, after the bench runs).

Handles both bench tables by shape:

* **fleet** tables (`benchmarks/bench_fleet.py`) — fails on:

  1. >25% per-sim wall-time regression (`us_per_sim`), and
  2. any efficiency-gate breach — the paper-grid rows must keep
     pi3 >= 0.8 and every regulated (`*_reg`) row >= 0.9 of its *exact*
     regulated LP bound (DESIGN.md §6), and
  3. a broken bound invariant (`bound_approx <= bound_exact <=
     bound_approx * rho0`) anywhere in the table, and
  4. a non-zero xla-vs-pallas parity diff in the `backends` section
     (the bit-identical contract of DESIGN.md §7), when present, and
  5. a `frontier` section whose measured lam_max/bound_exact leaves
     FRONTIER_RATIO_BAND, whose bisection recompiled the chunk step, or
     whose early stop saved less than FRONTIER_MIN_SAVED_FRAC of the
     simulated slots (DESIGN.md §8), when present.

* **kernel** tables (`benchmarks/bench_kernels.py --out`, detected by a
  top-level `"kernels"` key) — fails on a >25% per-kernel µs regression
  against the committed `BENCH_kernels_baseline.json` (the exact-match
  assertions live in the bench itself).

Peak chunk-step memory is reported as a delta but not gated (XLA temp
sizing is backend/version dependent).

Timing on shared CI hardware is noisy; the threshold can be relaxed via
CHECK_BENCH_MAX_REGRESSION (default 1.25) or timing can be skipped
entirely with CHECK_BENCH_SKIP_TIMING=1 (the efficiency/bound/parity
gates always run).

Usage:
  python scripts/check_bench.py BENCH_fleet.json BENCH_baseline.json
  python scripts/check_bench.py BENCH_kernels.json BENCH_kernels_baseline.json
"""
from __future__ import annotations

import importlib.util
import json
import os
import pathlib
import sys


def _load_bench_module():
    """Import benchmarks/bench_fleet.py (the single source of truth for
    the gate constants — its module top level imports nothing heavy)."""
    path = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" \
        / "bench_fleet.py"
    spec = importlib.util.spec_from_file_location("bench_fleet", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


_BENCH = _load_bench_module()
#: (scenario, policy) -> minimum efficiency vs the exact regulated bound.
EFFICIENCY_GATES = _BENCH.EFFICIENCY_GATES
#: lam_max / bound_exact band for frontier targets (DESIGN.md §8).
FRONTIER_RATIO_BAND = _BENCH.FRONTIER_RATIO_BAND
#: minimum aggregate early-stop slot savings across the frontier smoke.
FRONTIER_MIN_SAVED_FRAC = _BENCH.FRONTIER_MIN_SAVED_FRAC


def iter_rows(table: dict):
    for scen, entry in table.get("scenarios", {}).items():
        for pol, row in entry.get("policies", {}).items():
            yield scen, pol, row


def check_kernels(current: dict, baseline: dict) -> list[str]:
    """Per-kernel µs regression gate for bench_kernels tables."""
    errors = []
    if os.environ.get("CHECK_BENCH_SKIP_TIMING", "0") == "1":
        print("check_bench: kernel timing checks skipped "
              "(CHECK_BENCH_SKIP_TIMING=1)")
        return errors
    max_reg = float(os.environ.get("CHECK_BENCH_MAX_REGRESSION", "1.25"))
    cur = current.get("kernels", {})
    for name, base_row in baseline.get("kernels", {}).items():
        row = cur.get(name)
        if row is None:
            errors.append(f"kernel {name} missing from current table")
            continue
        base_us, cur_us = base_row.get("us"), row.get("us")
        if not base_us or cur_us is None:
            continue
        ratio = cur_us / base_us
        print(f"check_bench: kernels/{name} {cur_us:.0f}us vs baseline "
              f"{base_us:.0f}us (x{ratio:.2f}, limit x{max_reg:.2f})")
        if ratio > max_reg:
            errors.append(f"kernels/{name}: us regression {cur_us:.0f} > "
                          f"{base_us:.0f} * {max_reg:.2f}")
    return errors


def check(current: dict, baseline: dict) -> list[str]:
    if "kernels" in current:
        return check_kernels(current, baseline)
    errors = []

    # --- 1. wall-time regression
    if os.environ.get("CHECK_BENCH_SKIP_TIMING", "0") != "1":
        max_reg = float(os.environ.get("CHECK_BENCH_MAX_REGRESSION", "1.25"))
        cur_us, base_us = current.get("us_per_sim"), baseline.get("us_per_sim")
        if cur_us is None:
            errors.append("current table has no us_per_sim field")
        elif base_us:
            ratio = cur_us / base_us
            print(f"check_bench: us_per_sim {cur_us:.0f} vs baseline "
                  f"{base_us:.0f} (x{ratio:.2f}, limit x{max_reg:.2f})")
            if ratio > max_reg:
                errors.append(
                    f"us_per_sim regression: {cur_us:.0f} > "
                    f"{base_us:.0f} * {max_reg:.2f}")

    # --- 2. efficiency gates
    rows = {(s, p): r for s, p, r in iter_rows(current)}
    for (scen, pol), floor in EFFICIENCY_GATES.items():
        row = rows.get((scen, pol))
        if row is None:
            continue                      # preset does not sweep this row
        eff = row.get("efficiency", 0.0)
        print(f"check_bench: {scen}/{pol} efficiency {eff:.3f} "
              f"(gate >= {floor})")
        if eff < floor:
            errors.append(f"{scen}/{pol}: efficiency {eff:.3f} < {floor} "
                          f"vs exact bound {row.get('bound_exact')}")

    # --- 3. bound invariants (exact regulated LP vs rho0 approximation)
    for scen, pol, row in iter_rows(current):
        be, ba = row.get("bound_exact"), row.get("bound_approx")
        rho0 = row.get("rho0", 1.0)
        if be is None or ba is None:
            errors.append(f"{scen}/{pol}: missing bound_exact/bound_approx")
            continue
        if not (ba <= be * (1 + 1e-9) and be <= ba * rho0 * (1 + 1e-9)):
            errors.append(
                f"{scen}/{pol}: bound invariant broken: approx={ba} "
                f"exact={be} rho0={rho0}")

    # --- 4. backend parity (xla vs pallas, DESIGN.md §7): bit-identical
    backends = current.get("backends")
    if backends:
        diff = backends.get("parity_max_abs_diff")
        for name, row in backends.items():
            if isinstance(row, dict) and "us_per_sim" in row:
                print(f"check_bench: backend {name} "
                      f"us_per_sim={row['us_per_sim']:.0f}")
        if diff is None:
            errors.append("backends section missing parity_max_abs_diff")
        elif diff != 0.0:
            errors.append(f"xla/pallas parity broken: max |diff| = {diff}")
        else:
            print("check_bench: xla/pallas parity exact (diff 0.0)")

    # --- 5. frontier gates (DESIGN.md §8): the measured lam_max of every
    # target stays inside the ratio band of its exact LP bound, bisection
    # steps reuse one compiled program, and the early stop pays for itself.
    frontier = current.get("frontier")
    if frontier:
        lo, hi = FRONTIER_RATIO_BAND
        for name, row in frontier.get("targets", {}).items():
            ratio = row.get("ratio")
            print(f"check_bench: frontier {name} ratio="
                  f"{'missing' if ratio is None else format(ratio, '.3f')} "
                  f"(band [{lo}, {hi}]) saved_frac="
                  f"{row.get('slots_saved_frac', 0):.3f}")
            if ratio is None or not (lo <= ratio <= hi + 1e-9):
                errors.append(f"frontier {name}: lam_max/bound_exact "
                              f"{ratio} outside [{lo}, {hi}]")
            if row.get("n_step_compiles") != 1:
                errors.append(f"frontier {name}: bisection compiled "
                              f"{row.get('n_step_compiles')} chunk-step "
                              "programs (must be 1)")
        frac = frontier.get("slots_saved_frac", 0.0)
        print(f"check_bench: frontier slots_saved_frac {frac:.3f} "
              f"(gate >= {FRONTIER_MIN_SAVED_FRAC})")
        if frac < FRONTIER_MIN_SAVED_FRAC:
            errors.append(f"frontier: early stop saved only {frac:.1%} of "
                          f"simulated slots "
                          f"(< {FRONTIER_MIN_SAVED_FRAC:.0%})")

    # --- memory delta: informational only
    cur_mem = (current.get("memory") or {}).get("peak_bytes")
    base_mem = (baseline.get("memory") or {}).get("peak_bytes")
    if cur_mem and base_mem:
        print(f"check_bench: chunk-step peak {cur_mem:.0f} B vs baseline "
              f"{base_mem:.0f} B ({cur_mem / base_mem - 1:+.1%} - not gated)")
    return errors


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        current = json.load(f)
    with open(argv[2]) as f:
        baseline = json.load(f)
    errors = check(current, baseline)
    for e in errors:
        print(f"check_bench: ERROR: {e}", file=sys.stderr)
    if not errors:
        print("check_bench: all gates pass")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
