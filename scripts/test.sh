#!/usr/bin/env bash
# Tier-1 test entry point with a deterministic host configuration:
#   - 8 fake host-platform devices so the fleet engine's shard_map path and
#     the fleet_smoke-marked tests exercise a real (emulated) mesh in CI;
#   - x64 opt-in via JAX_ENABLE_X64=1 (useful for LP/capacity comparisons;
#     NOT the default because the simulator's float32 scan carries — and the
#     kernels' dtype assertions — are written for the f32 world and ~40 seed
#     tests fail under forced f64);
#   - src on PYTHONPATH (the repo is also pip-installable: pip install -e .[dev]);
#   - a lint gate (ruff check + ruff format --check, config in
#     pyproject.toml) — skipped with a notice when ruff is not installed
#     (the CI workflow installs it via the dev extras);
#   - a docs gate (scripts/check_docs.py): dangling DESIGN.md/README.md
#     section references fail CI, and the README cookbook snippets run
#     under doctest;
#   (lint + docs together are the "fast gates"; CI runs them in a
#   dedicated ~1 min lint job and sets CI_FAST_GATES_DONE=1 so the long
#   test job doesn't repeat them — locally they always run);
#   - a one-job regulated fleet smoke: pi3_reg under Gilbert–Elliott fading
#     must run end-to-end and deliver useful packets;
#   - a frontier smoke: find_lambda_max (early-stopped adaptive bisection,
#     DESIGN.md §8) must bracket the paper grid's exact LP bound from
#     below, launch-only after the first probe, and save slots;
#   - the Pallas parity stanza: the fused slot-kernel suite (marker
#     `pallas`) re-run under JAX_PLATFORMS=cpu interpret mode, plus the
#     kernel micro-bench gate (BENCH_kernels.json vs the committed
#     BENCH_kernels_baseline.json, DESIGN.md §7);
#   - a serving smoke: bursty queries through the admission gate
#     (DESIGN.md §9) must deliver >= 0.9x the exact LP bound with the
#     gate open and nothing shed;
#   - a resume smoke: a fault-injected SIGTERM mid-atlas, then a resume
#     from CKPT_resume_smoke/ that must be bit-exact (identical rows and
#     launch accounting, zero extra step compiles) with a validating
#     spliced stream (scripts/check_stream.py --resumed, DESIGN.md §12);
#   - the bench gate: benchmarks/bench_fleet.py --preset smoke emits
#     BENCH_fleet.json (incl. the xla-vs-pallas backend section and the
#     frontier lam_max section) and scripts/check_bench.py fails on >25%
#     us/sim regression vs the committed BENCH_baseline.json, any
#     efficiency gate breach (DESIGN.md §6), any xla/pallas parity diff,
#     a frontier ratio outside [0.90, 1.0], <30% early-stop savings
#     (DESIGN.md §8), or >5% checkpoint-on us/sim overhead in the
#     resilience section (DESIGN.md §12);
#   - the serving bench gate: benchmarks/bench_serving.py emits
#     BENCH_serving.json + SERVING_stream.jsonl and scripts/check_bench.py
#     --mode serving gates delivered-QPS/bound, shedding, p99 sojourn,
#     overload behavior, and serving-path xla/pallas parity against the
#     committed baseline's "serving" section (DESIGN.md §9);
#   - an atlas smoke + bench gate: the batched fleet-of-bisections
#     (DESIGN.md §10) must advance the registry grid in <= 2 compiled
#     programs (and, re-run with 2 shape buckets, in <= 2 programs per
#     (policy x bucket) with a consistent per-bucket launch ledger,
#     DESIGN.md §13) and surface UNDECIDED at a too-short horizon, and
#     benchmarks/bench_atlas.py --preset ci emits BENCH_atlas_new.json —
#     lambda_max bisections vs their exact LP bounds, in shape buckets
#     with adaptive re-queues, subsampled from the committed full
#     preset's >= 500 (scenario x topo_seed) cells x 3 seeds — gated by
#     scripts/check_bench.py --mode atlas against the committed
#     BENCH_atlas.json (ratio medians + seed-band widths, launch budgets
#     total and per bucket, single-compile per program, preset-scaled
#     floors);
#   - the stream schema gate (scripts/check_stream.py): every
#     *_stream.jsonl the benches emitted (DESIGN.md §11) must validate
#     against the versioned repro.obs.schema — blessed digest, exact
#     key/type tables, monotone per-(kind, group) clocks.
#
# Every bench gate honors the same soft-skip convention as the lint
# gate: when its committed baseline JSON is missing (a pruned checkout
# or a fresh fork that hasn't blessed baselines yet), the stanza prints
# a notice and moves on instead of hard-failing.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_ENABLE_X64="${JAX_ENABLE_X64:-0}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Fast gates (lint + docs).  CI's split lint job runs exactly these and
# sets CI_FAST_GATES_DONE=1 in the long test job so they aren't repeated;
# locally the variable is unset and they always run.
if [[ "${CI_FAST_GATES_DONE:-0}" != "1" ]]; then
    # Lint gate: hard-fail on violations when ruff is available, soft-skip
    # otherwise (hermetic containers without the dev extras).
    if python -m ruff --version >/dev/null 2>&1; then
        python -m ruff check .
        python -m ruff format --check .
    elif command -v ruff >/dev/null 2>&1; then
        ruff check .
        ruff format --check .
    else
        echo "test.sh: ruff not installed; skipping lint gate (pip install -e .[dev])"
    fi

    python scripts/check_docs.py
else
    echo "test.sh: CI_FAST_GATES_DONE=1; lint + docs gates ran in the lint job"
fi

# The pallas parity suite is excluded here and run once in its dedicated
# JAX_PLATFORMS=cpu stanza below (same tests, explicit platform pin).
python -m pytest -x -q -m "not pallas" "$@"

# fleet_smoke: one regulated job under Markov (Gilbert–Elliott) link fading
# through the full sharded engine path.
python - <<'PY'
from repro.fleet import FleetJob, run_fleet

res = run_fleet([FleetJob(scenario="ge_grid", policy="pi3_reg", lam=4.0,
                          eps_b=0.05, seed=0)], T=512, chunk=128)
m = res.metrics[0]
assert res.n_programs == 1
assert m["delivered_useful"] > 0.0, m
assert m["useful_rate"] >= 0.0 and abs(m["eps_b"] - 0.05) < 1e-6, m
print(f"fleet_smoke: pi3_reg/ge_grid useful_rate={m['useful_rate']:.3f} "
      f"dummy={m['delivered_dummy']:.1f} ok")
PY

# frontier_smoke: adaptive lam_max search (early-stopped bisection,
# DESIGN.md §8) end-to-end on the paper grid — must stay below the exact
# LP bound, reuse one compiled chunk-step program across probes, and
# actually save slots.  (The strict [0.90, 1.0] ratio band is gated on
# the longer-horizon bench section below.)
python - <<'PY2'
from repro.fleet import find_lambda_max

r = find_lambda_max("paper_grid", "pi3", eps_b=0.05, seeds=(0,),
                    T=2048, chunk=256, rel_tol=0.05)
assert 0.0 < r.lam_max <= r.bound_exact * (1 + 1e-9), (r.lam_max,
                                                       r.bound_exact)
assert r.n_step_compiles == 1, r.n_step_compiles
assert r.slots_saved > 0 and r.launch_slots_saved > 0, r
print(f"frontier_smoke: lam_max={r.lam_max:.2f} / bound={r.bound_exact:.2f}"
      f" (ratio {r.ratio:.3f}, {r.n_calls} probes, "
      f"{100 * r.slots_saved_frac:.0f}% slots saved) ok")
PY2

# atlas_smoke: the batched fleet-of-bisections scheduler (DESIGN.md §10)
# end-to-end across the registry grid at a tiny horizon: 18 (scenario x
# topo_seed) cells advance in <= 2 compiled programs (wireless_grid forks
# the second), one step compile each, far fewer launches than per-cell
# searches.  T=512 cannot latch any verdict (burn-in + 2 windows > T),
# so every cell must surface UNDECIDED — collapsed bracket, no certain
# instability — rather than a false UNSTABLE (DESIGN.md §8/§10).
python - <<'PY4'
from repro.fleet import registry_cells, sweep_lambda_max

cells = registry_cells(
    ("paper_grid", "random_geometric", "ring", "tree", "expander",
     "fat_tree", "wireless_grid", "ge_grid", "ge_comp_grid"),
    topo_seeds=(0, 1), eps_b=0.05)
res = sweep_lambda_max(cells, seeds=(0,), T=512, chunk=256,
                       rel_tol=0.1, max_calls=6)
assert len(res.rows) == res.n_cells == len(cells) == 18
assert res.n_programs <= 2 and res.n_step_compiles == res.n_programs, res
assert res.launch_speedup > 1.0, res.launch_speedup
assert all(r.undecided and r.hi_certain is None and r.lam_max == 0.0
           for r in res.rows), "short horizon must read UNDECIDED"
print(f"atlas_smoke: {res.n_cells} cells in {res.n_launches} launches "
      f"(seq {res.seq_launches}, x{res.launch_speedup:.1f}) "
      f"programs={res.n_programs} all-UNDECIDED ok")

# Bucketed re-run (DESIGN.md §13): 2 shape buckets must stay <= 2
# compiled programs per (policy group x bucket), with a per-bucket launch
# ledger that sums to the total.  n_step_compiles reads the absolute jit
# cache size (so resume bit-equality holds, test_resilience), and the
# single-bucket run above already warmed the hull-shape traces — the
# no-retrace invariant here is the *delta*: at most one new trace per
# (group x bucket) program.
res2 = sweep_lambda_max(cells, seeds=(0,), T=512, chunk=256,
                        rel_tol=0.1, max_calls=6, n_buckets=2)
assert res2.n_buckets == 2, res2.n_buckets
assert res2.n_programs <= 2 * res2.n_buckets, res2.n_programs
assert res2.n_step_compiles - res.n_step_compiles <= res2.n_programs, \
    (res.n_step_compiles, res2.n_step_compiles, res2.n_programs)
assert sum(res2.bucket_launches.values()) == res2.n_launches, res2
assert all(r.undecided and r.lam_max == 0.0 for r in res2.rows)
print(f"atlas_smoke: bucketed {res2.n_buckets} buckets "
      f"launches={dict(sorted(res2.bucket_launches.items()))} "
      f"programs={res2.n_programs} (<= {2 * res2.n_buckets}) ok")
PY4

# serving_smoke: bursty query traffic through the admission gate into the
# backpressure network (DESIGN.md §9) — at 0.95x the exact LP bound the
# gate must stay open (no shedding, no flips) and deliver >= 0.9x bound.
python - <<'PY3'
from repro.fleet import policy_bound_exact
from repro.serving import ServingJob, run_serving

bound = policy_bound_exact("paper_grid", "pi3_reg", 0.05)
jobs = [ServingJob(trace="bursty", lam=0.95 * bound, seed=s)
        for s in (0, 1)]
res = run_serving(jobs, T=2048, chunk=256)
for m in res.metrics:
    assert m["shed_frac"] == 0.0 and m["gate_flips"] == 0.0, m
    assert m["delivered_qps"] >= 0.9 * bound, m
    assert 0.0 < m["p99_sojourn"] <= 512.0, m
qps = [m["delivered_qps"] for m in res.metrics]
print(f"serving_smoke: pi3_reg/bursty qps={min(qps):.2f}..{max(qps):.2f} "
      f"vs bound={bound:.1f} (gate open, 0 shed) ok")
PY3

# resume_smoke: the preemption-safety contract (DESIGN.md §12) end-to-end
# in one process — a mid-atlas SIGTERM (FaultPlane.preempt_after) lands a
# durable snapshot, and the resumed sweep must reproduce the uninterrupted
# run bit-for-bit: identical rows (brackets, verdicts, λ_max), identical
# launch accounting, and ZERO extra step compiles (the memoized launch
# builders hand the resume its already-compiled programs).  The spliced
# stream must carry the resume seam and still validate with it stripped.
rm -rf CKPT_resume_smoke RESUME_stream.jsonl
python - <<'PY5'
from repro.fleet import registry_cells, sweep_lambda_max
from repro.runtime.fault import FaultPlane, Preempted
from repro.runtime.resilience import ResilienceConfig

cells = registry_cells(("paper_grid", "ring"), topo_seeds=(0,), eps_b=0.05)
kw = dict(seeds=(0, 1), T=512, chunk=256, rel_tol=0.1, max_calls=4)
base = sweep_lambda_max(cells, **kw)

kill = ResilienceConfig(checkpoint_dir="CKPT_resume_smoke",
                        fault_plane=FaultPlane.preempt_after(3))
try:
    sweep_lambda_max(cells, **kw, resilience=kill,
                     stream_path="RESUME_stream.jsonl")
    raise SystemExit("resume_smoke: expected Preempted")
except Preempted:
    pass

res = sweep_lambda_max(cells, **kw, stream_path="RESUME_stream.jsonl",
                       resilience=ResilienceConfig(
                           checkpoint_dir="CKPT_resume_smoke"))
assert res.resumed_from == 3, res.resumed_from
assert res.rows == base.rows, "resume is not bit-exact"
assert res.n_launches == base.n_launches, (res.n_launches, base.n_launches)
assert res.n_step_compiles == base.n_step_compiles, \
    (res.n_step_compiles, base.n_step_compiles)
print(f"resume_smoke: killed at launch 3/{base.n_launches}, resumed "
      f"bit-exact ({res.n_cells} cells, {res.n_step_compiles} step "
      f"compiles, 0 extra) ok")
PY5
python scripts/check_stream.py --resumed RESUME_stream.jsonl

# Pallas parity suite, re-run under an explicit CPU platform pin: the
# fused slot kernels (DESIGN.md §7) must be bit-identical to the XLA
# oracle in interpret mode — the exact configuration CI runs them in.
JAX_PLATFORMS=cpu python -m pytest -q -m pallas tests/

# Kernel micro-bench gate: fused bp_slot decide vs reference at fleet pad
# dims -> BENCH_kernels.json, regression-checked against the committed
# baseline.  Micro-kernel timings vary more across hosts than the fleet
# sweep, so the kernel gate gets a 2x allowance (exact-match assertions
# inside the bench are unconditional).
if [[ -f BENCH_kernels_baseline.json ]]; then
    python benchmarks/bench_kernels.py --out BENCH_kernels.json
    CHECK_BENCH_MAX_REGRESSION="${CHECK_BENCH_MAX_REGRESSION:-2.0}" \
        python scripts/check_bench.py BENCH_kernels.json BENCH_kernels_baseline.json
else
    echo "test.sh: BENCH_kernels_baseline.json missing; skipping kernel bench gate"
fi

# Bench gate: smoke sweep -> BENCH_fleet.json (incl. the xla-vs-pallas
# backend comparison section) + FLEET_stream.jsonl chunk-boundary
# telemetry, regression-checked against the committed baseline.
if [[ -f BENCH_baseline.json ]]; then
    python benchmarks/bench_fleet.py --preset smoke --out BENCH_fleet.json \
        --stream-out FLEET_stream.jsonl
    python scripts/check_bench.py --mode fleet BENCH_fleet.json BENCH_baseline.json
else
    echo "test.sh: BENCH_baseline.json missing; skipping fleet bench gate"
fi

# Serving bench gate: trace-driven admission-control smoke (DESIGN.md §9)
# -> BENCH_serving.json + per-chunk stream records, gated against the
# committed baseline's "serving" section.
if [[ -f BENCH_baseline.json ]]; then
    python benchmarks/bench_serving.py --out BENCH_serving.json \
        --stream-out SERVING_stream.jsonl
    python scripts/check_bench.py --mode serving BENCH_serving.json BENCH_baseline.json
else
    echo "test.sh: BENCH_baseline.json missing; skipping serving bench gate"
fi

# Atlas bench gate: the registry-wide capacity surface (DESIGN.md §10/§13)
# at the ci preset — the same families, horizon, shape buckets, adaptive
# re-queue rung and seed-band math as the committed 504-cell full preset,
# subsampled to 12 topo_seeds x 2 seeds so the live re-run fits the CI
# budget -> BENCH_atlas_new.json + ATLAS_stream.jsonl launch-clock
# telemetry, gated by check_bench --mode atlas (unfaded-family ratio
# medians in [0.90, 1.0], band widths <= 0.2, one step compile per
# (policy group x bucket) program, per-bucket launch ledger + budgets,
# batching speedup; preset-scaled floors from bench_atlas.ATLAS_GATES).
# The committed BENCH_atlas.json stays full-preset — regenerate it with
# `python benchmarks/bench_atlas.py --preset full --out BENCH_atlas.json`
# (~35 CPU-min).
if [[ -f BENCH_atlas.json ]]; then
    python benchmarks/bench_atlas.py --preset ci --out BENCH_atlas_new.json \
        --stream-out ATLAS_stream.jsonl
    python scripts/check_bench.py --mode atlas BENCH_atlas_new.json BENCH_atlas.json
else
    echo "test.sh: BENCH_atlas.json missing; skipping atlas bench gate"
fi

# Stream schema gate (DESIGN.md §11): whatever *_stream.jsonl files the
# bench stanzas above emitted must validate against the versioned
# repro.obs.schema — no args means glob-and-soft-pass, so skipped
# benches don't turn into missing-file failures here.
python scripts/check_stream.py
