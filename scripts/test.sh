#!/usr/bin/env bash
# Tier-1 test entry point with a deterministic host configuration:
#   - 8 fake host-platform devices so the fleet engine's shard_map path and
#     the fleet_smoke-marked tests exercise a real (emulated) mesh in CI;
#   - x64 opt-in via JAX_ENABLE_X64=1 (useful for LP/capacity comparisons;
#     NOT the default because the simulator's float32 scan carries — and the
#     kernels' dtype assertions — are written for the f32 world and ~40 seed
#     tests fail under forced f64);
#   - src on PYTHONPATH (the repo is also pip-installable: pip install -e .[dev]);
#   - a docs gate (scripts/check_docs.py): dangling DESIGN.md/README.md
#     section references fail CI, and the README cookbook snippets run
#     under doctest;
#   - a one-job regulated fleet smoke: pi3_reg under Gilbert–Elliott fading
#     must run end-to-end and deliver useful packets.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_ENABLE_X64="${JAX_ENABLE_X64:-0}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# One documented pre-existing seed failure (ROADMAP "Open items") is
# deselected so -x doesn't abort the run before later modules collect;
# remove the deselect once that test is fixed.  (The former
# test_sharding.py PartitionSpec deselect was fixed in the regulated-fleet
# PR: spec_for now preserves the rules table's tuple-vs-scalar form.)
python -m pytest -x -q \
    --deselect "tests/test_router.py::test_plain_router_collapses_backpressure_balances" \
    "$@"

python scripts/check_docs.py

# fleet_smoke: one regulated job under Markov (Gilbert–Elliott) link fading
# through the full sharded engine path.
python - <<'PY'
from repro.fleet import FleetJob, run_fleet

res = run_fleet([FleetJob(scenario="ge_grid", policy="pi3_reg", lam=4.0,
                          eps_b=0.05, seed=0)], T=512, chunk=128)
m = res.metrics[0]
assert res.n_programs == 1
assert m["delivered_useful"] > 0.0, m
assert m["useful_rate"] >= 0.0 and abs(m["eps_b"] - 0.05) < 1e-6, m
print(f"fleet_smoke: pi3_reg/ge_grid useful_rate={m['useful_rate']:.3f} "
      f"dummy={m['delivered_dummy']:.1f} ok")
PY
