#!/usr/bin/env bash
# Tier-1 test entry point with a deterministic host configuration:
#   - 8 fake host-platform devices so the fleet engine's shard_map path and
#     the fleet_smoke-marked tests exercise a real (emulated) mesh in CI;
#   - x64 opt-in via JAX_ENABLE_X64=1 (useful for LP/capacity comparisons;
#     NOT the default because the simulator's float32 scan carries — and the
#     kernels' dtype assertions — are written for the f32 world and ~40 seed
#     tests fail under forced f64);
#   - src on PYTHONPATH (the repo is also pip-installable: pip install -e .[dev]).
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_ENABLE_X64="${JAX_ENABLE_X64:-0}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# The two documented pre-existing seed failures (ROADMAP "Open items") are
# deselected so -x doesn't abort the run before later modules collect;
# remove the deselects once those tests are fixed.
python -m pytest -x -q \
    --deselect "tests/test_router.py::test_plain_router_collapses_backpressure_balances" \
    --deselect "tests/test_sharding.py::TestSpecFor::test_basic_mapping" \
    "$@"
