"""`capacity_report --follow`: a live view over ``*_stream.jsonl`` telemetry.

Tails the stream files the engines append to (`StreamSink(path=...)`
flushes per record), renders per-(kind, group) rolling medians for fleet
and serving streams and per-family bisection-bracket progress for
in-flight atlas runs, and repeats every ``--interval`` seconds.  The
reader side of the DESIGN.md §11 contract: records are validated lazily
(bad lines render as a warning, not a crash) and a truncated final line —
a writer mid-append — is simply ignored until the next tick.

The rolling-median window is the HomebrewNLP wandblog idiom: a bounded
deque per metric, re-aggregated with a median every render, so one noisy
chunk cannot spike the displayed rate.  An *empty* window is NaN, never
0.0 — ``drift_med 0.0`` is the stability boundary, so rendering it
before the first record arrives would paint an alert-adjacent number out
of thin air; empty windows render as ``—`` and the alert checks
(``drift_med`` crossing 0, ``shed_frac`` spikes) skip them entirely.

Console entry point: ``capacity_report`` (pyproject ``[project.scripts]``)
or ``python -m repro.obs.follow``.
"""
from __future__ import annotations

import argparse
import glob
import math
import sys
import time
from collections import deque
from statistics import median
from typing import Dict, Iterable, List, Sequence

from . import schema

#: shed_frac level below which a spike is never alerted (noise floor).
SHED_SPIKE_FLOOR = 0.05
#: spike = latest shed_frac_med > SHED_SPIKE_RATIO × rolling median.
SHED_SPIKE_RATIO = 2.0


class RollingMedian:
    """Median over a bounded trailing window of pushed values.

    An empty window is **NaN**, not 0.0: the old zero default rendered a
    `drift_med 0.0` — the exact stability boundary — before any record
    arrived, indistinguishable from a genuinely zero-drift stream.  NaN
    propagates through comparisons as False, so alert thresholds skip
    empty windows for free, and the renderer shows ``—``."""

    def __init__(self, window: int = 8):
        self._buf: deque = deque(maxlen=max(int(window), 1))

    def push(self, x: float) -> None:
        self._buf.append(float(x))

    @property
    def value(self) -> float:
        return median(self._buf) if self._buf else math.nan

    def __len__(self) -> int:
        return len(self._buf)


def _roll(records: List[dict], field: str, window: int) -> float:
    rm = RollingMedian(window)
    for rec in records[-window:]:
        if field in rec:
            rm.push(rec[field])
    return rm.value


def _fmt(x: float, spec: str = ".3f") -> str:
    """Format a rolling value; an empty (NaN) window renders as ``—``."""
    return "—" if math.isnan(x) else format(x, spec)


def _fmt_verdicts(counts: dict) -> str:
    return " ".join(f"{k}:{v}" for k, v in sorted(counts.items()))


def _render_fleet(recs: List[dict], window: int) -> str:
    last = recs[-1]
    drift = _roll(recs, "drift_med", window)
    # Alert: a *populated* window whose median drift crosses into >= 0
    # (the paper's instability boundary).  NaN (empty window) compares
    # False, so the alert can never fire off the missing-data default.
    alert = "  !! drift>=0" if drift >= 0.0 else ""
    return (f"fleet   g{last['group']}  chunk {last['chunk']:>4}  "
            f"t={last['t']:>8}  sims={last['n_sims']:>4} | "
            f"useful ~{_fmt(_roll(recs, 'useful_rate_med', window))}  "
            f"backlog ~{_fmt(_roll(recs, 'backlog_med', window), '.1f')}  "
            f"drift ~{_fmt(drift)}  "
            f"max_q {last['max_queue_med']:.1f}  "
            f"decided {last['n_decided']}/{last['n_sims']}  "
            f"[{_fmt_verdicts(last['verdicts'])}]" + alert)


def _render_serving(recs: List[dict], window: int) -> str:
    last = recs[-1]
    shed = _roll(recs, "shed_frac_med", window)
    # Alert: the latest shed fraction spikes to SHED_SPIKE_RATIO × the
    # rolling median, above the noise floor.  Requires a populated window
    # (NaN median → both comparisons False → no alert).
    shed_last = float(last["shed_frac_med"])
    alert = ("  !! shed spike"
             if shed_last > SHED_SPIKE_FLOOR
             and shed_last > SHED_SPIKE_RATIO * shed else "")
    return (f"serving g{last['group']}  chunk {last['chunk']:>4}  "
            f"t={last['t']:>8}  sims={last['n_sims']:>4} | "
            f"qps ~{_fmt(_roll(recs, 'qps_med', window), '.2f')}  "
            f"shed ~{_fmt(shed)}  "
            f"p99 ~{_fmt(_roll(recs, 'p99_med', window), '.0f')}  "
            f"gate {last['gate_open_frac']:.2f}  "
            f"[{_fmt_verdicts(last['verdicts'])}]" + alert)


def _render_atlas(recs: List[dict], window: int) -> List[str]:
    last = recs[-1]
    n_cells = last["n_active_cells"] + last["n_done_cells"]
    requeues = (f"  requeues {last['n_requeues']}"
                if last.get("n_requeues") else "")
    lines = [(f"atlas   g{last['group']}/b{last.get('bucket', 0)}  "
              f"launch {last['chunk']:>4}  "
              f"t={last['t']:>8}  lanes={last['n_sims']:>4} | "
              f"done {last['n_done_cells']}/{n_cells} cells  "
              f"probes {last['n_probes']}  "
              f"bracket ~{_fmt(_roll(recs, 'bracket_rel_width_med', window))} "
              f"of bound" + requeues)]
    for fam, row in sorted(last["families"].items()):
        bar = "#" * int(10 * row["done"] / max(row["cells"], 1))
        lines.append(f"    {fam:<18} {row['done']}/{row['cells']} done "
                     f"[{bar:<10}] bracket {row['lo_med']:.3f}"
                     f"..{row['hi_med']:.3f} of bound")
    return lines


def render(records: Iterable[dict], window: int = 8) -> str:
    """Render one telemetry frame from parsed stream records (pure —
    the unit-testable core of the follow loop)."""
    by_stream: Dict[tuple, List[dict]] = {}
    bad = 0
    for rec in records:
        if schema.validate_record(rec):
            bad += 1
            continue
        by_stream.setdefault((rec["kind"], rec["group"]), []).append(rec)
    lines: List[str] = []
    for (kind, _), recs in sorted(by_stream.items()):
        if kind == "fleet":
            lines.append(_render_fleet(recs, window))
        elif kind == "serving":
            lines.append(_render_serving(recs, window))
        elif kind == "atlas":
            lines.extend(_render_atlas(recs, window))
    if bad:
        lines.append(f"!! {bad} records failed schema validation "
                     f"(schema_version {schema.SCHEMA_VERSION})")
    if not lines:
        lines.append("(no records yet)")
    return "\n".join(lines)


def follow(paths: Sequence[str], interval: float = 2.0, window: int = 8,
           max_ticks: int | None = None, out=print) -> int:
    """Tail the stream files, rendering a frame every ``interval`` seconds
    until interrupted (or ``max_ticks`` frames, for tests).  Returns the
    number of frames rendered."""
    ticks = 0
    try:
        while True:
            frames = []
            for p in paths:
                try:
                    recs = schema.read_stream_jsonl(p)
                except OSError:
                    continue
                frames.append(f"== {p} ==\n" + render(recs, window=window))
            out("\n".join(frames) if frames
                else f"(waiting for {', '.join(paths)})")
            ticks += 1
            if max_ticks is not None and ticks >= max_ticks:
                return ticks
            time.sleep(interval)
    except KeyboardInterrupt:  # pragma: no cover - interactive exit
        return ticks


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="capacity_report",
        description="Render (or --follow) *_stream.jsonl telemetry")
    ap.add_argument("paths", nargs="*",
                    help="stream JSONL files (default: ./*_stream.jsonl)")
    ap.add_argument("--follow", action="store_true",
                    help="keep tailing instead of rendering once")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="seconds between frames with --follow")
    ap.add_argument("--window", type=int, default=8,
                    help="rolling-median window (records)")
    args = ap.parse_args(argv)
    paths = args.paths or sorted(glob.glob("*_stream.jsonl"))
    if not paths:
        print("capacity_report: no *_stream.jsonl files found",
              file=sys.stderr)
        return 1
    follow(paths, interval=args.interval, window=args.window,
           max_ticks=None if args.follow else 1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
