"""Versioned stream-record schema: the one contract every ``*_stream.jsonl``
writer emits against (DESIGN.md §11).

A *stream record* is one flat JSON object per chunk boundary (fleet,
serving) or launch boundary (atlas).  Three invariants make the streams
CI-diffable and safe to tail from another process:

  1. **One schema, versioned.**  Every record carries ``schema_version``
     and ``kind``; the per-kind field tables below are the full key set —
     unknown keys are rejected, so an emitter cannot grow the record
     shape without touching this module.
  2. **Digest-gated evolution.**  `schema_digest()` hashes the field
     tables; `scripts/check_stream.py` compares it against
     ``BLESSED_DIGESTS[SCHEMA_VERSION]``.  Editing a field table without
     bumping ``SCHEMA_VERSION`` (and blessing the new digest) fails CI —
     a consumer can trust that records with equal versions have equal
     shapes.
  3. **Monotone stream clock.**  Within one file, ``t`` (simulated slots
     dispatched) is non-decreasing and ``chunk`` strictly increasing per
     ``(kind, group)`` — the property a `--follow` tail needs to render
     progress without re-sorting.

This module is pure Python (no jax import): the CI gate and the
`capacity_report --follow` viewer load it without touching a device
runtime.
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, Iterable, List

#: Bump when any field table below changes shape, and bless the new
#: digest in BLESSED_DIGESTS (scripts/check_stream.py enforces the pair).
#: v2: added the "resume" record kind (preemption-safe runs, DESIGN.md §12).
#: v3: atlas records gained "bucket" (the PadDims size bucket the launch
#:     unit runs in) and "n_requeues" (adaptive-horizon escalations so
#:     far) — the bucketed-atlas observability contract (DESIGN.md §13).
SCHEMA_VERSION = 3

# Field type tags: "int" (json integer, bools rejected), "num" (integer or
# float), "str", "dict" (nested object; contents are kind-specific and
# deliberately not pinned — counts keyed by verdict name / family name).
_COMMON = {
    "schema_version": "int",
    "kind": "str",
    "group": "int",      # compiled-program group index within the run
    "chunk": "int",      # per-group chunk/launch counter, 0-based
    "t": "int",          # stream clock: simulated slots dispatched per lane
    "n_sims": "int",     # real (non-mesh-replica) sims behind the medians
}

#: Per-kind field tables.  Keys = the exact (and only) keys a record of
#: that kind may carry.
STREAM_KINDS: Dict[str, Dict[str, str]] = {
    # fleet: windowed medians over the group's sims, differenced between
    # consecutive chunk-boundary probes of the donated carry.
    "fleet": {
        **_COMMON,
        "useful_rate_med": "num",   # d(delivered_useful)/d(t) median
        "backlog_med": "num",       # d(sum_queue)/d(t) median (mean backlog)
        "max_queue_med": "num",     # running max backlog median
        "drift_med": "num",         # anchored per-slot drift estimate median
        "n_decided": "int",         # sims with a latched verdict
        "verdicts": "dict",         # {verdict name: count}
    },
    # serving: the PR-6 per-chunk record, now schema-versioned.
    "serving": {
        **_COMMON,
        "qps_med": "num",
        "admitted_qps_med": "num",
        "shed_frac_med": "num",
        "p99_med": "num",
        "gate_open_frac": "num",
        "gate_flips": "int",
        "verdicts": "dict",
    },
    # atlas: host-side bisection progress, one record per group launch.
    "atlas": {
        **_COMMON,
        "bucket": "int",            # PadDims size bucket of this launch unit
        "n_requeues": "int",        # adaptive-horizon re-queues so far
        "n_active_cells": "int",    # cells still bisecting after this launch
        "n_done_cells": "int",      # cells with a finished search
        "n_probes": "int",          # rate probes harvested so far
        "bracket_rel_width_med": "num",  # median (hi-lo)/bound over cells
        "verdicts": "dict",         # {verdict name: lane count} this launch
        "families": "dict",         # {family: {cells, done, lo_med, hi_med}}
    },
    # resume: a preemption-safe engine picked the stream back up from a
    # checkpoint (DESIGN.md §12).  ``chunk``/``t`` are the restored
    # boundary's per-group clock, so a merged feed stays monotone; the
    # sink exempts this kind from duplicate-suppression.
    "resume": {
        **_COMMON,
        "engine": "str",        # fleet | serving | atlas
        "ckpt_step": "int",     # checkpoint step the run restored
        "n_preloaded": "int",   # records already durable from the killed run
    },
}


def schema_digest() -> str:
    """SHA-256 of the canonical field-table structure (version excluded:
    the digest answers "did the shape change", the version answers "was
    the change blessed")."""
    canon = json.dumps(STREAM_KINDS, sort_keys=True)
    return hashlib.sha256(canon.encode()).hexdigest()


#: version -> blessed digest of the field tables at that version.  A shape
#: edit must add/replace an entry *and* bump SCHEMA_VERSION, or
#: scripts/check_stream.py fails ("schema changed without a version bump").
BLESSED_DIGESTS: Dict[int, str] = {
    1: "cf81d7426080f2ac1b8123bcc45435a10196008787131209b3b24dcf181ba29c",
    2: "920d91e8d051be592b6a3478ceb752d7c0dd8cf840d6b5050bec7b820caef97e",
    3: "6b075e07750232b47eab5e3fb39a487ed0d1491a469b7aedcf7ab412e66f2398",
}


def _type_ok(tag: str, v) -> bool:
    if tag == "int":
        return isinstance(v, int) and not isinstance(v, bool)
    if tag == "num":
        return (isinstance(v, (int, float))
                and not isinstance(v, bool))
    if tag == "str":
        return isinstance(v, str)
    if tag == "dict":
        return isinstance(v, dict)
    raise ValueError(f"unknown type tag {tag!r}")


def make_record(kind: str, **fields) -> dict:
    """Assemble + validate one stream record.

    Fills ``schema_version`` and ``kind``; numpy scalars are coerced to
    plain Python so records serialize canonically.  Raises ``ValueError``
    on a field-table mismatch — an emitter drifting from the schema is a
    bug, not a warning.
    """
    table = STREAM_KINDS.get(kind)
    if table is None:
        raise ValueError(f"unknown stream kind {kind!r} "
                         f"(have {sorted(STREAM_KINDS)})")
    rec: dict = {"schema_version": SCHEMA_VERSION, "kind": kind}
    for k, v in fields.items():
        tag = table.get(k)
        if tag == "int":
            v = int(v)
        elif tag == "num":
            v = float(v)
        rec[k] = v
    errs = validate_record(rec)
    if errs:
        raise ValueError(f"bad {kind} record: " + "; ".join(errs))
    return rec


def validate_record(rec: dict, index: int | None = None) -> List[str]:
    """Shape-check one record against its kind's field table.

    Returns a list of error strings (empty = valid).  ``index`` prefixes
    errors with the record's position for stream-level reports.
    """
    where = f"record {index}: " if index is not None else ""
    if not isinstance(rec, dict):
        return [f"{where}not a JSON object"]
    kind = rec.get("kind")
    table = STREAM_KINDS.get(kind)
    if table is None:
        return [f"{where}unknown kind {kind!r}"]
    errs = []
    ver = rec.get("schema_version")
    if ver != SCHEMA_VERSION:
        errs.append(f"{where}schema_version {ver!r} != {SCHEMA_VERSION}")
    for k, tag in table.items():
        if k not in rec:
            errs.append(f"{where}missing key {k!r}")
        elif not _type_ok(tag, rec[k]):
            errs.append(f"{where}key {k!r}: expected {tag}, "
                        f"got {type(rec[k]).__name__}")
    for k in rec:
        if k not in table:
            errs.append(f"{where}unexpected key {k!r} for kind {kind!r} "
                        "(schema change? bump SCHEMA_VERSION)")
    return errs


def validate_stream(records: Iterable[dict]) -> List[str]:
    """Validate a whole stream: per-record shape plus the monotone stream
    clock — ``t`` non-decreasing and ``chunk`` strictly increasing per
    ``(kind, group)``.  ``resume`` records mark the seam of a restarted
    run, so their chunk clock is only required non-decreasing (a run
    killed twice at the same boundary resumes there twice)."""
    errs: List[str] = []
    last: Dict[tuple, tuple] = {}
    for i, rec in enumerate(records):
        rec_errs = validate_record(rec, index=i)
        errs.extend(rec_errs)
        if rec_errs:
            continue
        key = (rec["kind"], rec["group"])
        t, chunk = rec["t"], rec["chunk"]
        if key in last:
            pt, pc = last[key]
            if t < pt:
                errs.append(f"record {i}: t went backwards for {key}: "
                            f"{pt} -> {t}")
            strict = rec["kind"] != "resume"
            if chunk < pc or (strict and chunk == pc):
                errs.append(f"record {i}: chunk not increasing for {key}: "
                            f"{pc} -> {chunk}")
        last[key] = (t, chunk)
    return errs


def jsonl_line(record: dict) -> str:
    """One stream record as a canonical JSONL line (sorted keys, so CI
    diffs are order-stable)."""
    return json.dumps(record, sort_keys=True)


def write_stream_jsonl(result_or_records, path: str) -> int:
    """Write a run's stream records as JSONL; returns the count."""
    records = getattr(result_or_records, "stream_records",
                      result_or_records)
    with open(path, "w") as f:
        for rec in records:
            f.write(jsonl_line(rec) + "\n")
    return len(records)


def read_stream_jsonl(path: str) -> List[dict]:
    """Parse a stream JSONL file.  A truncated final line (a writer
    mid-append) is ignored — the tailing reader's contract."""
    records: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                break
    return records
