"""Streaming telemetry plane (DESIGN.md §11).

`obs.schema` is the versioned stream-record contract every
``*_stream.jsonl`` writer emits against; `obs.emitter` is the
io_callback chunk-boundary transport the engines dispatch through; and
`obs.follow` is the `capacity_report --follow` live view over the
emitted files.  The schema and follow modules are pure Python — the CI
gate (`scripts/check_stream.py`) and the viewer never import jax.
"""
from .schema import (BLESSED_DIGESTS, SCHEMA_VERSION, STREAM_KINDS,
                     jsonl_line, make_record, read_stream_jsonl,
                     schema_digest, validate_record, validate_stream,
                     write_stream_jsonl)

__all__ = [
    "BLESSED_DIGESTS",
    "SCHEMA_VERSION",
    "STREAM_KINDS",
    "jsonl_line",
    "make_record",
    "read_stream_jsonl",
    "schema_digest",
    "validate_record",
    "validate_stream",
    "write_stream_jsonl",
]
