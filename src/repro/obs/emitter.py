"""io_callback chunk-boundary telemetry emitter (DESIGN.md §11).

The engines' chunk loops are Python-level drivers around donated
`jit(shard_map(vmap(chunk_step)))` launches, so the natural place to tap
telemetry is *between* launches — where the carry is a real pytree of
device arrays — not inside the scan, where a per-slot callback would
serialize the whole program behind host round-trips and fork the compiled
chunk step.

The transport is one tiny jitted program per mesh (`_emit_fn`): it takes
an integer *handle* plus the probe leaves, replicates the leaves (so XLA
does not warn about gathering sharded operands into the host callback),
and hands them to `jax.experimental.io_callback(..., ordered=True)`.
Three properties follow:

  * **No program fork.**  The chunk-step program never changes — the tap
    is pure pytree indexing on the carry plus a *separate* program, so
    telemetry-on and telemetry-off runs execute byte-identical step
    programs (asserted by `tests/test_obs.py` via the step jit cache).
  * **Off the hot path.**  `emit()` only *dispatches*; the host never
    blocks on the probe values.  Callbacks drain on JAX's background
    callback thread; `jax.effects_barrier()` (inside `close()`) is the
    flush point before results are read.
  * **Donation-safe by copy.**  The emit program snapshots every probe
    leaf (`jnp.copy` after replication) before handing it to the
    callback, so the io_callback operand is a fresh buffer that no later
    donating launch can alias.  Relying on per-device in-order execution
    alone (the pre-fix behavior) is unsafe on GPU runtimes, where the
    async callback read can race the next launch's donated overwrite of
    the same carry buffer; the copy makes the tap correct on every
    backend while staying off the hot path (it is dispatched, never
    awaited).  `tests/test_obs.py` asserts telemetry-on runs stay
    bit-identical with the copy in place.

Handle routing keeps the program count at one per (mesh, leaf structure):
every live `ChunkEmitter` registers its record-assembly callback in the
module-level `_SINKS` table under a fresh handle, and the traced program
only ever sees the integer.
"""
from __future__ import annotations

import functools
import itertools
import os
import threading
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.queues import VERDICT_NAMES, VERDICT_UNDECIDED
from . import schema

#: handle -> per-emitter probe consumer (np leaves dict -> None).
_SINKS: Dict[int, Callable] = {}
_HANDLES = itertools.count(1)


def _route(handle, leaves) -> None:
    """The host side of the emit program: dispatch on the traced handle.

    A missing handle is a closed emitter whose last callbacks were still
    in flight — dropping them is correct (close() barriers first, so this
    only happens on interpreter-teardown races)."""
    sink = _SINKS.get(int(handle))
    if sink is not None:
        sink(leaves)


@functools.lru_cache(maxsize=64)
def _emit_fn(mesh: Mesh):
    """The per-mesh emit program: replicate + *copy* leaves, hand them to
    the ordered io_callback.  Replication (`with_sharding_constraint` to
    `P()`) is what lets the callback consume mesh-sharded probe leaves
    without XLA's involuntary-rematerialization warning; the `jnp.copy`
    decouples the callback operand from the donated carry buffers so the
    async host read cannot race the next launch's aliased overwrite
    (GPU-unsafe otherwise — module docstring); `ordered=True` keeps
    records in dispatch order, which is what makes the consecutive probe
    *differencing* in the record assemblers correct."""
    rep = NamedSharding(mesh, P())

    @jax.jit
    def emit(handle, leaves):
        leaves = jax.tree_util.tree_map(
            lambda v: jnp.copy(jax.lax.with_sharding_constraint(v, rep)),
            leaves)
        io_callback(_route, None, handle, leaves, ordered=True)

    return emit


class StreamSink:
    """Fan-out for finished records: accumulate, optionally append JSONL
    to ``path`` (flushed per record, so a `--follow` tail sees them live),
    optionally call ``log``.  Thread-safe: records arrive on the callback
    thread.

    ``append=True`` is the resumed-run mode (DESIGN.md §12): an existing
    file is *preloaded* (its records seed ``self.records``, truncated to
    the parseable prefix — a killed writer's torn last line is dropped)
    and subsequent writes are deduplicated against the per-(kind, group)
    monotone chunk clock.  A resumed engine replays the launches after
    its snapshot, so any record the killed run already made durable is
    re-emitted bit-identically — suppressing ``chunk <= last_seen``
    leaves exactly the uninterrupted stream, with no duplicate and no
    time-traveling record.  ``resume``-kind records are exempt (they mark
    the seam itself)."""

    def __init__(self, path: str | None = None,
                 log: Callable[[dict], None] | None = None,
                 append: bool = False):
        self.records: List[dict] = []
        self._log = log
        self._lock = threading.Lock()
        self._clock: Dict[tuple, int] = {}   # (kind, group) -> last chunk
        self._dedupe = False
        self.n_preloaded = 0
        if path and append and os.path.exists(path):
            existing = schema.read_stream_jsonl(path)
            with open(path, "w") as f:        # drop any torn trailing line
                for rec in existing:
                    f.write(schema.jsonl_line(rec) + "\n")
            self.records.extend(existing)
            self.n_preloaded = len(existing)
            for rec in existing:
                if rec.get("kind") != "resume":
                    key = (rec.get("kind"), rec.get("group"))
                    c = self._clock.get(key)
                    if c is None or rec.get("chunk", 0) > c:
                        self._clock[key] = rec.get("chunk", 0)
            self._dedupe = True
            self._f = open(path, "a")
        else:
            self._f = open(path, "w") if path else None

    def write(self, rec: dict) -> None:
        with self._lock:
            if self._dedupe and rec.get("kind") != "resume":
                key = (rec["kind"], rec["group"])
                c = self._clock.get(key)
                if c is not None and rec["chunk"] <= c:
                    return           # already durable from the killed run
                self._clock[key] = rec["chunk"]
            self.records.append(rec)
            if self._f is not None:
                self._f.write(schema.jsonl_line(rec) + "\n")
                self._f.flush()
        if self._log is not None:
            self._log(rec)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class ChunkEmitter:
    """One group's chunk-boundary telemetry: dispatch probe leaves per
    chunk, difference consecutive probes into schema records on the
    callback thread, hand them to a `StreamSink`.

    ``kind`` picks the record assembler ("fleet" or "serving"); ``runner``
    supplies chunk length and (for serving) the latency-histogram shape;
    ``n_real`` slices mesh-padding replicas off before medians.
    """

    def __init__(self, kind: str, group: int, n_real: int, runner,
                 mesh: Mesh, sink: StreamSink):
        self._assemble = {"fleet": _fleet_record,
                          "serving": _serving_record}[kind]
        self._group = group
        self._n_real = n_real
        self._runner = runner
        self._sink = sink
        self._prev: dict | None = None
        self._chunk_idx = 0
        self._emit = _emit_fn(mesh)
        self._handle = next(_HANDLES)
        _SINKS[self._handle] = self._consume
        self._handle_arr = jax.device_put(jnp.int32(self._handle),
                                          NamedSharding(mesh, P()))

    def restore_clock(self, chunk_idx: int, prev: dict | None) -> None:
        """Resume support (DESIGN.md §12): pin the differencing clock to a
        restored chunk boundary.  ``prev`` is the probe of the restored
        carry — exactly the probe the killed run last consumed — so the
        first post-resume record differences against the same baseline an
        uninterrupted run would have used."""
        self._chunk_idx = int(chunk_idx)
        self._prev = (None if prev is None
                      else {k: np.asarray(v) for k, v in prev.items()})

    def emit(self, leaves: Dict[str, jax.Array]) -> None:
        """Dispatch one chunk-boundary probe (non-blocking).  Must be
        called before the next donating launch consumes the carry the
        leaves alias — i.e. immediately after the step launch returns."""
        self._emit(self._handle_arr, leaves)

    def _consume(self, leaves) -> None:
        probe = {k: np.asarray(v) for k, v in leaves.items()}
        rec = self._assemble(self._group, self._chunk_idx, self._runner,
                             probe, self._prev, self._n_real)
        self._prev = probe
        self._chunk_idx += 1
        self._sink.write(rec)

    def close(self) -> None:
        """Flush in-flight callbacks, then unregister the handle."""
        jax.effects_barrier()
        _SINKS.pop(self._handle, None)


def _r4(x) -> float:
    return round(float(x), 4)


def _verdict_counts(verdict: np.ndarray) -> dict:
    v = verdict.astype(int)
    return {VERDICT_NAMES[k]: int((v == k).sum())
            for k in sorted(set(v.tolist()))}


def _fleet_record(group: int, chunk_idx: int, runner, probe: dict,
                  prev: dict | None, n_real: int) -> dict:
    """Difference two consecutive fleet probes into one windowed record.

    Rates are per-sim deltas over the sim's *own* slot delta (a frozen
    sim advances 0 slots; its last anchored rate/drift still reports), so
    early-stopped groups stream honest numbers."""
    def cur(name):
        return probe[name][:n_real].astype(np.float64)

    def delta(name):
        if prev is None:
            return cur(name)
        return cur(name) - prev[name][:n_real].astype(np.float64)

    dt = np.maximum(delta("t"), 1.0)
    verdict = probe["verdict"][:n_real]
    return schema.make_record(
        "fleet",
        group=group, chunk=chunk_idx,
        t=int(probe["t"][:n_real].max()), n_sims=n_real,
        useful_rate_med=_r4(np.median(delta("delivered_useful") / dt)),
        backlog_med=_r4(np.median(delta("sum_queue") / dt)),
        max_queue_med=_r4(np.median(cur("max_queue"))),
        drift_med=_r4(np.median(cur("last_drift"))),
        n_decided=int((verdict != VERDICT_UNDECIDED).sum()),
        verdicts=_verdict_counts(verdict))


def _hist_quantile(hist: np.ndarray, q: float, horizon: int,
                   n_bins: int) -> np.ndarray:
    """Host-side `core.latency.latency_quantiles` on [B, NB+1] numpy data."""
    total = hist.sum(axis=-1, keepdims=True)
    cum = np.cumsum(hist, axis=-1)
    bin_w = max(horizon // n_bins, 1)
    b = np.sum(cum < q * total, axis=-1)
    edge = np.minimum((b + 1) * bin_w, horizon).astype(np.float64)
    return np.where(total[..., 0] > 0, edge, 0.0)


def _serving_record(group: int, chunk_idx: int, runner, probe: dict,
                    prev: dict | None, n_real: int) -> dict:
    """The PR-6 serving record, emitted against the shared schema.

    Medians are across the group's *real* sims (mesh-padding replicas are
    sliced off); all values rounded so records diff cleanly in CI.
    """
    def delta(name):
        cur = probe[name][:n_real].astype(np.float64)
        if prev is None:
            return cur
        return cur - prev[name][:n_real].astype(np.float64)

    ddlv = delta("delivered_useful")
    dadm = delta("admitted_total")
    dshed = delta("shed_total")
    doff = np.maximum(dadm + dshed, 1e-9)
    dhist = delta("hist")
    p99 = _hist_quantile(dhist, 0.99, runner.lat_horizon, runner.lat_bins)
    return schema.make_record(
        "serving",
        group=group, chunk=chunk_idx,
        t=int(probe["t"][:n_real].max()), n_sims=n_real,
        qps_med=_r4(np.median(ddlv) / runner.chunk),
        admitted_qps_med=_r4(np.median(dadm) / runner.chunk),
        shed_frac_med=_r4(np.median(dshed / doff)),
        p99_med=_r4(np.median(p99)),
        gate_open_frac=_r4(np.mean(probe["gate"][:n_real])),
        gate_flips=int(probe["gate_flips"][:n_real].sum()),
        verdicts=_verdict_counts(probe["verdict"][:n_real]))
