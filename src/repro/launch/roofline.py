"""Roofline-term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOPs)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

cost_analysis() of the (SPMD, per-device) executable supplies FLOPs/bytes
per chip, so `per_device / peak` == `global / (chips * peak)`.  Collective
bytes are parsed from the optimized HLO text: we sum result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute with the factors below (per-device wire bytes,
bidirectional-ring model):

  all-gather       result bytes            (each chip receives V-V/n ~ V)
  all-reduce       2 x result bytes        (reduce-scatter + all-gather)
  reduce-scatter   result bytes x group    (operand leaves the chip once)
  all-to-all       result bytes
  collective-permute  result bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e-class constants (per chip) — from the assignment.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:                      # iota format [num_groups,group_size]
        return int(m.group(2))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, from optimized HLO."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(4)
        if m.group(1) is not None:      # tuple result: sum elements
            rb = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(m.group(1)))
        else:
            rb = _shape_bytes(m.group(2), m.group(3))
        if op == "all-reduce":
            rb *= 2
        elif op == "reduce-scatter":
            rb *= _group_size(line)
        out[op] += rb
        counts[op] += 1
    out.update({f"n_{k}": counts[k] for k in counts})
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, float]

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def summary(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
        }


def from_compiled(compiled, hlo_text: str | None = None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):            # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    total_coll = sum(v for k, v in coll.items() if not k.startswith("n_"))
    return Roofline(flops_per_device=flops, bytes_per_device=byts,
                    coll_bytes_per_device=total_coll, coll_breakdown=coll)


def model_flops(cfg, shape, n_params: int, active_params: int) -> float:
    """6*N*D (train) / 2*N*D (inference) with D = tokens in the step."""
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * active_params * D
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * active_params * D
    D = shape.global_batch                      # decode: one token per seq
    return 2.0 * active_params * D
