import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place that forces 512
# placeholder devices — tests and benches see the real single CPU device.
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Results are cached as JSON under results/dryrun/ (one file per cell x mesh x
tag); reruns skip cached cells unless --force.  Failures (sharding mismatch,
OOM at compile, unsupported collective) are bugs in the system — they are
recorded with status=error and the sweep continues.

(No `from __future__ import annotations` here: the XLA_FLAGS lines must be
the first statements in the file.)
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, RunConfig, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl
from repro.models import get_model
from repro.runtime import flags, sharding as shd
from repro.runtime.step import (init_train_state, make_prefill_step,
                                make_serve_step, make_train_step)

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _count_params(params) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params)))


def _active_params(cfg, params_tree) -> int:
    """Total params minus inactive expert fraction (MoE)."""
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(params_tree)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        keys = "/".join(str(getattr(p, 'key', p)) for p in path)
        if cfg.n_experts and "moe/" in keys and not keys.endswith("router"):
            n = int(n * cfg.top_k / cfg.n_experts)
        total += n
    return total


def _mem_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {k: int(getattr(ma, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes") if hasattr(ma, k)}
    except Exception as e:              # CPU backend may not support it
        return {"error": str(e)}


def probe_configs(cfg):
    """(cfg_small, cfg_large, n_units): homogeneous-unit depth probes for
    exact per-layer cost extrapolation (XLA cost analysis counts while-loop
    bodies once; probes compile UNROLLED at 1 and 2 units).

    gemma's 2-layer local tail is folded into fractional units (62/6) — a
    <2%% approximation, noted in EXPERIMENTS.md.
    """
    if cfg.family == "encdec":
        small = dataclasses.replace(cfg, enc_layers=1, dec_layers=1, n_layers=2)
        large = dataclasses.replace(cfg, enc_layers=2, dec_layers=2, n_layers=4)
        return small, large, cfg.enc_layers
    if cfg.local_global:
        unit = cfg.local_global + 1
    elif cfg.attn_every:
        unit = cfg.attn_every
    elif cfg.slstm_every:
        unit = cfg.slstm_every
    else:
        unit = 1
    small = dataclasses.replace(cfg, n_layers=unit)
    large = dataclasses.replace(cfg, n_layers=2 * unit)
    return small, large, cfg.n_layers / unit


def _slstm_correction(cfg, shape, chips: int) -> dict:
    """Analytic correction for the sLSTM *time* recurrence (sequential scan;
    body counted once by cost analysis, runs S-1 more times).  FLOPs are
    exact; bytes assume gate weights stay VMEM-resident (4*d^2*4B = 16 MB at
    d=1024 fits) so only activations stream."""
    if cfg.family != "ssm" or not cfg.slstm_every or shape.kind == "decode":
        return {}
    d = cfg.d_model
    hd = d // cfg.n_heads
    n_slstm = cfg.n_layers // cfg.slstm_every
    S = shape.seq_len - (1 if shape.kind == "train" else 0)
    dp = min(shape.global_batch, 32)
    b_loc = max(shape.global_batch // dp, 1)
    step_flops = 2.0 * b_loc * 4.0 * (d * d + d * hd)
    step_bytes = 10.0 * b_loc * d * 4.0
    return {"slstm_extra_flops": n_slstm * (S - 1) * step_flops,
            "slstm_extra_bytes": n_slstm * (S - 1) * step_bytes}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               rcfg_overrides: dict | None = None, cfg=None):
    """Build mesh + shardings and lower the cell's step. Returns
    (lowered, meta)."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    rcfg = RunConfig(model=cfg, shape=shape, multi_pod=multi_pod,
                     **(rcfg_overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    model_size = mesh.shape.get("model", 1)
    kv_seq_model = (rcfg.kv_seq_tp == "auto"
                    and cfg.n_kv_heads % model_size != 0
                    and shape.kind == "decode")
    rules = shd.make_rules(mesh, fsdp=rcfg.fsdp,
                           expert_parallel=rcfg.expert_parallel,
                           seq_shard_decode=rcfg.seq_shard_decode,
                           kv_seq_model=kv_seq_model)
    api = get_model(cfg)
    adt = jnp.bfloat16

    with shd.use_rules(rules), flags.attention_impl(rcfg.attn_impl), \
            flags.context_parallel(rcfg.ctx_par):
        if shape.kind == "train":
            state, axes = init_train_state(rcfg, abstract=True)
            state_sh = shd.tree_shardings(state, axes, rules)
            specs, b_axes = api.batch_specs(shape, activ_dtype=adt)
            batch_sh = shd.tree_shardings(specs, b_axes, rules)
            step = make_train_step(rcfg)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, specs)
            n_par = _count_params(state.params)
            act_par = _active_params(cfg, state.params)
        elif shape.kind == "prefill":
            state, axes = init_train_state(rcfg, abstract=True)
            p_sh = shd.tree_shardings(state.params, axes.params, rules)
            specs, b_axes = api.batch_specs(shape, activ_dtype=adt)
            batch_sh = shd.tree_shardings(specs, b_axes, rules)
            H = state.router_H
            H_sh = (shd.tree_shardings(H, axes.router_H, rules)
                    if H is not None else None)
            step = make_prefill_step(rcfg)
            jitted = jax.jit(step, in_shardings=(p_sh, batch_sh, H_sh),
                             out_shardings=None)
            lowered = jitted.lower(state.params, specs, H)
            n_par = _count_params(state.params)
            act_par = _active_params(cfg, state.params)
        else:                            # decode
            state, axes = init_train_state(rcfg, abstract=True)
            p_sh = shd.tree_shardings(state.params, axes.params, rules)
            caches = api.init_decode(shape.global_batch, shape.seq_len, adt,
                                     abstract=True)
            c_axes = api.cache_axes(caches)
            c_sh = shd.tree_shardings(caches, c_axes, rules)
            specs, b_axes = api.batch_specs(shape, activ_dtype=adt)
            batch_sh = shd.tree_shardings(specs, b_axes, rules)
            H = state.router_H
            H_sh = (shd.tree_shardings(H, axes.router_H, rules)
                    if H is not None else None)
            step = make_serve_step(rcfg)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, batch_sh, H_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(state.params, caches, specs, H)
            n_par = _count_params(state.params)
            act_par = _active_params(cfg, state.params)

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "chips": int(np.prod(mesh.devices.shape)),
            "n_params": n_par, "active_params": act_par,
            "rcfg": {k: v for k, v in dataclasses.asdict(rcfg).items()
                     if k not in ("model", "shape")}}
    return lowered, meta, shape, cfg


def _probe_roofline(arch, shape_name, multi_pod, rcfg_overrides, cfg, shape,
                    chips):
    """Depth-probe extrapolation: compile 1-unit and 2-unit UNROLLED models,
    per-unit cost = large - small, total = small + per_unit*(units-1)."""
    from repro.runtime import flags
    small_cfg, large_cfg, n_units = probe_configs(cfg)
    roofs = []
    for pc in (small_cfg, large_cfg):
        with flags.unrolled_scans():
            lowered, _, _, _ = lower_cell(arch, shape_name,
                                          multi_pod=multi_pod,
                                          rcfg_overrides=rcfg_overrides,
                                          cfg=pc)
            compiled = lowered.compile()
        roofs.append(rl.from_compiled(compiled))
    r1, r2 = roofs

    def extrap(a, b):
        # per-unit delta clamped at 0: XLA occasionally optimizes the larger
        # probe harder, and a negative per-layer cost is nonphysical
        return a + max(b - a, 0.0) * (n_units - 1.0)

    coll = {k: extrap(r1.coll_breakdown.get(k, 0.0),
                      r2.coll_breakdown.get(k, 0.0))
            for k in r1.coll_breakdown}
    corr = _slstm_correction(cfg, shape, chips)
    flops = extrap(r1.flops_per_device, r2.flops_per_device) \
        + corr.get("slstm_extra_flops", 0.0)
    byts = extrap(r1.bytes_per_device, r2.bytes_per_device) \
        + corr.get("slstm_extra_bytes", 0.0)
    total_coll = sum(v for k, v in coll.items() if not k.startswith("n_"))
    roof = rl.Roofline(flops_per_device=flops, bytes_per_device=byts,
                       coll_bytes_per_device=total_coll, coll_breakdown=coll)
    return roof, {"n_units": n_units, **corr}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rcfg_overrides: dict | None = None, tag: str = "base",
             probe: bool = True, model_overrides: dict | None = None) -> dict:
    t0 = time.time()
    cfg0 = get_config(arch)
    if model_overrides:
        cfg0 = dataclasses.replace(cfg0, **model_overrides)
    lowered, meta, shape, cfg = lower_cell(
        arch, shape_name, multi_pod=multi_pod, rcfg_overrides=rcfg_overrides,
        cfg=cfg0)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    roof_raw = rl.from_compiled(compiled, hlo)
    mf = rl.model_flops(cfg, shape, meta["n_params"], meta["active_params"])
    chips = meta["chips"]
    rec = {
        **meta, "tag": tag, "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _mem_analysis(compiled),
        "roofline_scanned": roof_raw.summary(),
        "model_flops": mf,
        "hlo_bytes": len(hlo),
    }
    if probe:
        t0 = time.time()
        roof, probe_meta = _probe_roofline(arch, shape_name, multi_pod,
                                           rcfg_overrides, cfg, shape, chips)
        rec["probe_s"] = round(time.time() - t0, 2)
        rec["probe"] = probe_meta
        rec["roofline"] = roof.summary()
    else:
        roof = roof_raw
        rec["roofline"] = roof_raw.summary()
    hlo_flops_global = roof.flops_per_device * chips
    rec["useful_flops_ratio"] = (mf / hlo_flops_global
                                 if hlo_flops_global else None)
    return rec


def cell_path(arch, shape_name, mesh_name, tag="base") -> Path:
    return RESULTS / f"{arch}__{shape_name}__{mesh_name}__{tag}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="base")
    ap.add_argument("--set", nargs="*", default=[],
                    help="RunConfig overrides, e.g. fsdp=false remat=dots")
    ap.add_argument("--set-model", nargs="*", default=[],
                    help="ModelConfig overrides, e.g. capacity_factor=1.0")
    args = ap.parse_args()

    def parse(pairs):
        out = {}
        for kv in pairs:
            k, v = kv.split("=")
            if v.lower() in ("true", "false"):
                out[k] = v.lower() == "true"
            elif v.isdigit():
                out[k] = int(v)
            else:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
        return out

    overrides = parse(args.set)
    model_overrides = parse(args.set_model)

    from repro.configs import cells
    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    RESULTS.mkdir(parents=True, exist_ok=True)
    n_ok = n_err = n_skip = 0
    for arch, shape_name in todo:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            out = cell_path(arch, shape_name, mesh_name, args.tag)
            if out.exists() and not args.force:
                n_skip += 1
                continue
            print(f"=== {arch} x {shape_name} x {mesh_name} [{args.tag}]",
                  flush=True)
            try:
                rec = run_cell(arch, shape_name, multi_pod=mp,
                               rcfg_overrides=overrides, tag=args.tag,
                               model_overrides=model_overrides)
                r = rec["roofline"]
                print(f"    ok: compile={rec['compile_s']}s "
                      f"compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"coll={r['collective_s']:.4f}s "
                      f"dominant={r['dominant']} "
                      f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'], 3)}",
                      flush=True)
                n_ok += 1
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "tag": args.tag, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"    ERROR: {type(e).__name__}: {e}", flush=True)
                n_err += 1
            out.write_text(json.dumps(rec, indent=1))
    print(f"done: ok={n_ok} err={n_err} skipped={n_skip}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
