"""Production mesh construction.

Defined as a FUNCTION (not a module-level constant) so importing this module
never touches jax device state; the dry-run sets
--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data","model"); multi-pod: 2 pods of
    256 = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 1):
    """Elastic helper: best-effort (data, model) mesh over whatever devices
    are currently alive (used by the fault-recovery path)."""
    assert n_devices % model_parallel == 0
    return jax.make_mesh((n_devices // model_parallel, model_parallel),
                         ("data", "model"))
