"""Serving driver: batched continuous-batching engine with backpressure
admission (dummy-slot padding = the paper's regulator).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 8 --slots 4 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import get_model, split_tree
from repro.serving import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    api = get_model(cfg)
    params, _ = split_tree(api.init(key=jax.random.key(args.seed)))
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, 16))
        eng.submit(list(rng.integers(0, cfg.vocab, plen)), args.max_new)

    t0 = time.time()
    finished = eng.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in finished.values())
    print(f"served {len(finished)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s on CPU)")
    for rid in sorted(finished)[:4]:
        print(f"  req {rid}: out={finished[rid].out[:8]}...")
    assert len(finished) == args.requests
    return finished


if __name__ == "__main__":
    main()
