"""LLM serving driver: batched continuous-batching engine with backpressure
admission (dummy-slot padding = the paper's regulator made literal — XLA
needs static shapes, so empty slots run as dummy packets and are ignored on
output).

This is the *model-serving* demo over `repro.models`; the paper's
network-computation serving subsystem lives in `repro.serving` (trace ->
admission -> bp_slot -> latency scoring, DESIGN.md §9).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 8 --slots 4 --max-new 12
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import get_model, split_tree


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Continuous batching over fixed decode slots with dummy-slot padding.

    Drives any arch through the uniform ModelAPI: submit prompts, `step()`
    prefills newly admitted requests (one at a time, cache-filling decode
    of the prompt) and decodes one token for every active slot.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.caches = self.api.init_decode(slots, max_len, jnp.float32)
        self.router_H = self.api.init_state().router_H
        self.slot_req: List[Optional[ServeRequest]] = [None] * slots
        self.pending: List[ServeRequest] = []
        self.finished: Dict[int, ServeRequest] = {}
        self._last_tok = np.zeros((slots,), np.int32)

        def step_fn(params, caches, tokens, H):
            return self.api.decode_step(params, caches, {"tokens": tokens},
                                        activ_dtype=jnp.float32, router_H=H)
        self._step = jax.jit(step_fn)

    # ------------------------------------------------------------------

    def submit(self, prompt: List[int], max_new: int = 16) -> int:
        rid = len(self.finished) + len(self.pending) + sum(
            r is not None for r in self.slot_req)
        self.pending.append(ServeRequest(rid, list(prompt), max_new))
        return rid

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.pending:
                req = self.pending.pop(0)
                self.slot_req[s] = req
                # prefill by decoding the prompt into this slot's cache:
                # tokens of OTHER slots are dummy packets (last token echo).
                for tok in req.prompt[:-1]:
                    toks = self._last_tok.copy()
                    toks[s] = tok
                    _, self.caches = self._step(self.params, self.caches,
                                                jnp.asarray(toks),
                                                self.router_H)
                    self._last_tok = np.asarray(toks)
                self._last_tok[s] = req.prompt[-1]

    def step(self) -> int:
        """One decode tick over all slots; returns #active real slots."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        logits, self.caches = self._step(self.params, self.caches,
                                         jnp.asarray(self._last_tok),
                                         self.router_H)
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = jax.random.categorical(sub, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        nxt = np.asarray(nxt, np.int32)
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt[s]))
            self._last_tok[s] = nxt[s]
            if len(req.out) >= req.max_new:
                req.done = True
                self.finished[req.rid] = req
                self.slot_req[s] = None
        return len(active)

    def run_until_done(self, max_ticks: int = 10_000) -> Dict[int, ServeRequest]:
        for _ in range(max_ticks):
            if not self.pending and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.finished


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    api = get_model(cfg)
    params, _ = split_tree(api.init(key=jax.random.key(args.seed)))
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, 16))
        eng.submit(list(rng.integers(0, cfg.vocab, plen)), args.max_new)

    t0 = time.time()
    finished = eng.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in finished.values())
    print(f"served {len(finished)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s on CPU)")
    for rid in sorted(finished)[:4]:
        print(f"  req {rid}: out={finished[rid].out[:8]}...")
    assert len(finished) == args.requests
    return finished


if __name__ == "__main__":
    main()
