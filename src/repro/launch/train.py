"""End-to-end training driver.

Runs any registered arch (full or --reduced) on the available devices with
the full substrate: sharded data pipeline, AdamW + schedule, optional
gradient compression / accumulation, atomic checkpointing with keep-k,
straggler detection hooks, and restart-from-checkpoint (--resume).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 100

On a real TPU fleet the same driver runs under `jax.distributed.initialize`
with the production mesh (launch/mesh.py); on this container it runs on one
CPU device with a (1, 1) mesh — same code path, smaller mesh (elastic by
construction).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, ShapeConfig, get_config, reduced
from repro.checkpoint import Checkpointer
from repro.data import DataConfig, TokenStream
from repro.optim import AdamW, warmup_cosine
from repro.runtime.fault import StragglerDetector
from repro.runtime.step import init_train_state, make_train_step


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("custom", args.seq, args.batch, "train")
    rcfg = RunConfig(model=cfg, shape=shape, fsdp=args.fsdp,
                     remat=args.remat, activ_dtype="float32",
                     grad_accum=args.grad_accum,
                     grad_compression=args.compression)
    return cfg, rcfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8_ef", "topk_ef"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="simulate a failure at this step (fault-tol demo)")
    args = ap.parse_args(argv)

    cfg, rcfg = build(args)
    opt = AdamW(lr=warmup_cosine(args.lr, warmup=20, total=args.steps))
    state, axes = init_train_state(rcfg, key=jax.random.key(args.seed),
                                   optimizer=opt)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M devices={jax.device_count()}")

    step_fn = jax.jit(make_train_step(rcfg, optimizer=opt),
                      donate_argnums=(0,))
    data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start = int(state.step)
        print(f"resumed from step {start}")

    det = StragglerDetector(["host0"])
    losses = []
    t_last = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            batch = {"tokens": batch["tokens"],
                     "frames": jnp.ones((args.batch, args.seq, cfg.d_model),
                                        jnp.float32) * 0.02}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        now = time.time()
        det.record("host0", now - t_last)
        t_last = now
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"({(now - t_last) * 1e3:.0f}ms)", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(int(state.step), state, blocking=False)
        if args.crash_at == step:
            ckpt and ckpt.wait()
            raise SystemExit(f"simulated crash at step {step}")
    if ckpt:
        ckpt.save(int(state.step), state, blocking=True)
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first10 {np.mean(losses[:10]):.4f})")
    return losses


if __name__ == "__main__":
    main()
