"""Roofline report generator: results/dryrun/*.json -> markdown tables for
EXPERIMENTS.md (§Dry-run, §Roofline) and hillclimb target selection.

  PYTHONPATH=src python -m repro.launch.report [--tag base] [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def load(tag: str = "base"):
    recs = []
    for p in sorted(RESULTS.glob(f"*__{tag}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}"
    return f"{x*1e3:.1f}m" if x >= 1e-3 else f"{x*1e6:.0f}u"


def roofline_table(recs, mesh: str = "single") -> str:
    rows = ["| arch | shape | chips | compute_s | memory_s | coll_s | "
            "dominant | bound_s | 6ND/HLO | peak_frac |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        peak_frac = rf["compute_s"] / bound if bound else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | {rf['dominant']} "
            f"| {fmt_s(bound)} "
            f"| {r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)} "
            f"| {peak_frac:.3f} |")
    return "\n".join(rows)


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | chips | status | compile_s | "
            "temp_bytes/dev | arg_bytes/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        mem = r.get("memory", {})
        tmp = mem.get("temp_size_in_bytes")
        arg = mem.get("argument_size_in_bytes")
        gb = lambda v: f"{v/2**30:.2f}G" if isinstance(v, int) else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('chips','-')} "
            f"| {r['status']} | {r.get('compile_s','-')} | {gb(tmp)} | {gb(arg)} |")
    return "\n".join(rows)


def pick_hillclimb_targets(recs) -> list:
    """worst peak-fraction, most collective-bound, most paper-representative
    (the MoE arch whose router IS the paper's technique)."""
    ok = [r for r in recs if r.get("status") == "ok"
          and r.get("mesh") == "single"]

    def peak_frac(r):
        rf = r["roofline"]
        b = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        return rf["compute_s"] / b if b else 0.0

    # decode cells are inherently bandwidth-bound (peak_frac ~ 0 is not a
    # bug) — pick the worst *throughput* cell among train/prefill, and the
    # most collective-dominated cell overall.
    heavy = [r for r in ok if r["shape"] in ("train_4k", "prefill_32k")]
    worst = max(heavy, key=lambda r: max(r["roofline"]["memory_s"],
                                         r["roofline"]["collective_s"]))
    coll = max(ok, key=lambda r: (r["roofline"]["collective_s"] /
                                  max(r["roofline"]["compute_s"], 1e-12)))
    moe = [r for r in ok if r["arch"].startswith(("moonshot", "granite"))
           and r["shape"] == "train_4k"]
    rep = moe[0] if moe else ok[0]
    return [(worst["arch"], worst["shape"], "worst peak fraction"),
            (coll["arch"], coll["shape"], "most collective-bound"),
            (rep["arch"], rep["shape"], "paper technique (BP MoE router)")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="base")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--targets", action="store_true")
    args = ap.parse_args()
    recs = load(args.tag)
    print(f"### Dry-run ({len(recs)} records, tag={args.tag})\n")
    print(dryrun_table(recs))
    print(f"\n### Roofline ({args.mesh}-pod, tag={args.tag})\n")
    print(roofline_table(recs, args.mesh))
    if args.targets:
        print("\n### Hillclimb targets\n")
        for a, s, why in pick_hillclimb_targets(recs):
            print(f"- {a} x {s} — {why}")


if __name__ == "__main__":
    main()
