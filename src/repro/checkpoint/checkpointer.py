"""Fault-tolerant checkpointing: atomic manifests, keep-last-k, background
save thread, restore-with-resharding.

Layout:  <dir>/step_<N>/ {manifest.json, arr_<i>.npy ...}
Writes go to a tmp dir, fsync'd, then os.replace()'d into place — a crash
mid-save never corrupts the latest checkpoint.  Arrays are saved as FULL
(unsharded) numpy, so a restore may re-shard onto ANY mesh — this is the
elastic-scaling path: lose a host, rebuild a smaller mesh, restore, resume.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ---- save -------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        """Snapshot to host memory synchronously; write to disk (optionally
        in the background so the train loop keeps stepping)."""
        flat, treedef = jax.tree_util.tree_flatten(state)
        host = [np.asarray(x) for x in flat]      # device -> host snapshot
        if self._thread is not None:
            self._thread.join()                   # one in-flight save max
            self._thread = None
        if blocking:
            self._write(step, host, treedef)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, treedef), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: list, treedef) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "n_arrays": len(host),
                    "treedef": str(treedef), "time": time.time(),
                    "dtypes": [str(a.dtype) for a in host],
                    "shapes": [list(a.shape) for a in host]}
        for i, a in enumerate(host):
            np.save(tmp / f"arr_{i}.npy", a)
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                    # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---- restore ----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `like`; if `shardings` is given,
        arrays are placed with those NamedShardings (re-sharding onto the
        current — possibly different — mesh)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints in {self.dir}"
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        assert manifest["n_arrays"] == len(flat_like), "structure mismatch"
        arrays = [np.load(d / f"arr_{i}.npy") for i in range(len(flat_like))]
        for a, l in zip(arrays, flat_like):
            assert tuple(a.shape) == tuple(l.shape), (a.shape, l.shape)
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, flat_sh)]
        return jax.tree_util.tree_unflatten(treedef, arrays)
