"""Fault-tolerant checkpointing: atomic manifests, keep-last-k, background
save thread, restore-with-resharding, per-leaf integrity checksums.

Layout:  <dir>/step_<N>/ {manifest.json, arr_<i>.npy ...}
Writes go to a tmp dir (manifest fsync'd), then os.replace()'d into place
— a crash mid-save never corrupts the latest checkpoint.  The manifest
carries a sha256 per array, verified on restore, so a torn write (power
loss after the rename was queued but before data blocks hit disk) is
*detected* rather than silently resumed from; `restore(..., fallback=True)`
then walks back to the newest intact step instead of crashing.  Arrays are
saved as FULL (unsharded) numpy, so a restore may re-shard onto ANY mesh
— this is the elastic-scaling path: lose a host, rebuild a smaller mesh,
restore, resume.

Beyond the array pytree, a checkpoint can carry an ``extra`` JSON payload
(host-side scheduler state: bisection machines, emitter clocks, finished
metrics — see `runtime/resilience.py`, DESIGN.md §12); it lives inside
the manifest, so it is covered by the same atomic-publish guarantee.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class CheckpointCorruption(RuntimeError):
    """A checkpoint step exists on disk but fails integrity verification
    (missing arrays, checksum mismatch, unreadable manifest)."""


def _sha256(a: np.ndarray) -> str:
    # Hash dtype+shape+bytes: a reinterpreted or reshaped array must not
    # collide with the original.
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ---- save -------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = True,
             extra: dict | None = None) -> None:
        """Snapshot to host memory synchronously; write to disk (optionally
        in the background so the train loop keeps stepping).  ``extra`` is
        an arbitrary JSON-serializable payload published atomically with
        the arrays (inside the manifest)."""
        flat, treedef = jax.tree_util.tree_flatten(state)
        host = [np.asarray(x) for x in flat]      # device -> host snapshot
        if self._thread is not None:
            self._thread.join()                   # one in-flight save max
            self._thread = None
        if blocking:
            self._write(step, host, treedef, extra)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, treedef, extra),
                daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: list, treedef,
               extra: dict | None = None) -> None:
        """Atomic publish: arrays + manifest land in a tmp dir, then one
        `os.replace` renames the whole step into place — a reader never
        observes a partially-written step directory, and a crash mid-write
        leaves only a `.tmp_*` dir the next save removes.  Only the
        manifest is fsync'd: per-array fsync would cost ~ms per leaf per
        boundary, and the checkpoint contract doesn't need it — process
        preemption (the fault model of DESIGN.md §12) can't tear
        page-cache writes, and a literal power loss that does tear array
        data is *detected* by the per-array sha256 on restore, which then
        falls back to the newest intact step (at most one snapshot
        interval lost, never the run)."""
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "n_arrays": len(host),
                    "treedef": str(treedef), "time": time.time(),
                    "dtypes": [str(a.dtype) for a in host],
                    "shapes": [list(a.shape) for a in host],
                    "sha256": [_sha256(a) for a in host],
                    "extra": extra}
        for i, a in enumerate(host):
            with open(tmp / f"arr_{i}.npy", "wb") as f:
                np.save(f, a)
                f.flush()
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                    # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---- restore ----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_verified(self, step: int) -> tuple[dict, list[np.ndarray]]:
        """Read one step's manifest + arrays, verifying per-leaf sha256.

        Raises `CheckpointCorruption` on any integrity failure so callers
        can fall back to an older step."""
        d = self.dir / f"step_{step:08d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruption(f"{d}: unreadable manifest ({e})")
        arrays: list[np.ndarray] = []
        sums = manifest.get("sha256")
        for i in range(manifest["n_arrays"]):
            p = d / f"arr_{i}.npy"
            try:
                a = np.load(p)
            except (OSError, ValueError) as e:
                raise CheckpointCorruption(f"{p}: unreadable array ({e})")
            if sums is not None:        # pre-checksum checkpoints: skip
                if _sha256(a) != sums[i]:
                    raise CheckpointCorruption(
                        f"{p}: sha256 mismatch (torn write / bit rot)")
            arrays.append(a)
        return manifest, arrays

    def extra(self, step: Optional[int] = None) -> dict | None:
        """The ``extra`` JSON payload of a step (default: latest)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        return manifest.get("extra")

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None, fallback: bool = False) -> Any:
        """Restore into the structure of `like`; if `shardings` is given,
        arrays are placed with those NamedShardings (re-sharding onto the
        current — possibly different — mesh).

        Every array's sha256 is verified against the manifest.  With
        ``fallback=True`` a corrupt or partial step is skipped and the
        next-newest intact step is restored instead (the preemption-safe
        contract of DESIGN.md §12: a crash mid-publish must cost at most
        one snapshot interval, never the run); without it, corruption
        raises `CheckpointCorruption`."""
        steps = ([step] if step is not None
                 else sorted(self.all_steps(), reverse=True))
        assert steps, f"no checkpoints in {self.dir}"
        last_err: Exception | None = None
        for s in steps:
            try:
                manifest, arrays = self._load_verified(s)
                break
            except CheckpointCorruption as e:
                last_err = e
                if not fallback:
                    raise
        else:
            raise CheckpointCorruption(
                f"no intact checkpoint in {self.dir}: {last_err}")
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        assert manifest["n_arrays"] == len(flat_like), "structure mismatch"
        for a, l in zip(arrays, flat_like):
            assert tuple(a.shape) == tuple(l.shape), (a.shape, l.shape)
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, flat_sh)]
        return jax.tree_util.tree_unflatten(treedef, arrays)

    def restored_step(self, step: Optional[int] = None,
                      fallback: bool = False) -> Optional[int]:
        """The step `restore` would actually load: ``step`` (or the
        latest) unless fallback walks past corruption.  None if nothing
        intact exists."""
        steps = ([step] if step is not None
                 else sorted(self.all_steps(), reverse=True))
        for s in steps:
            try:
                self._load_verified(s)
                return s
            except CheckpointCorruption:
                if not fallback:
                    raise
        return None
