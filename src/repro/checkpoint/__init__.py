from .checkpointer import CheckpointCorruption, Checkpointer

__all__ = ["CheckpointCorruption", "Checkpointer"]
