"""Queue state for the backpressure network-computation system (paper §II-C).

All quantities are JAX arrays so the whole network steps inside `lax.scan`.
Class index convention: i=0 processed, i=1 raw from s1, i=2 raw from s2.
Queues are *fluid* (float) — see DESIGN.md §1.

State components (paper notation):
  Q[k, i, n]   : data queue at node k, class (i, n)    (Q_k^{(i,n)})
  Ddum[k, n]   : dummy-packet content of Q[k, 0, n]    (regulator tracking)
  X[n, i]      : raw packets of source i+1 at computation node n (X_n^{(i)})
  Y[n]         : regulator queue of computed results   (Y_n)
  H[n]         : virtual admission queue               (H_n)
  cum_arr[n,i] : cumulative raw arrivals into X[n, i]  (for FIFO pairing)
  cum_comb[n]  : cumulative pairs combined at n
  delivered / delivered_useful : cumulative processed packets at d

The delivery counters are *compensated* (Kahan) float32 sums: `delivered`
carries the running total and `delivered_c` the rounding residue, so
per-slot increments survive far past the naive float32 saturation point
(~2^24 ≈ 1.7e7 packets, where `big + 1.0 == big`).  Read them through
`state.delivered`; update them only through `state.credit_delivery`
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import ComputeProblem


def kahan_add(s: jax.Array, c: jax.Array, x: jax.Array):
    """One compensated-summation step: returns (new_sum, new_compensation).

    Keeps float32 running sums exact to ~1 ulp of the *increments* instead
    of 1 ulp of the total — the difference between losing every packet past
    ~10^7 delivered and losing none (ROADMAP numerics note)."""
    y = x - c
    t = s + y
    return t, (t - s) - y


# ---------------------------------------------------------------------------
# Streaming stability verdict: windowed backlog-drift accumulators
# (DESIGN.md §8).  Pure scalar state + one update per slot, so the whole
# verdict machinery rides the fleet engine's donated scan carry.
# ---------------------------------------------------------------------------

VERDICT_UNDECIDED, VERDICT_STABLE, VERDICT_UNSTABLE = 0, 1, 2
VERDICT_NAMES = ("UNDECIDED", "STABLE", "UNSTABLE")


class DriftStats(NamedTuple):
    """Per-sim drift statistics for the streaming stability verdict.

    ``q_mark``/``useful_mark`` anchor the backlog and delivery counters at
    the end of the burn-in; every verdict-window boundary after it scores
    the *anchored* per-slot backlog slope ``(Q(t) - q_mark)/(t - anchor)``
    (a Lyapunov drift estimate whose noise shrinks as the horizon grows)
    and the anchored useful delivery rate against the offered rate.
    Consecutive boundaries of agreeing evidence latch ``verdict`` to
    STABLE/UNSTABLE at ``decided_at`` (DESIGN.md §8).  All fields are
    scalars — the accumulator is O(1) per sim.
    """

    q_mark: jax.Array        # [] total backlog at the burn-in anchor
    useful_mark: jax.Array   # [] delivered_useful at the burn-in anchor
    last_drift: jax.Array    # [] anchored per-slot drift at the last boundary
    last_rate: jax.Array     # [] anchored useful rate at the last boundary
    stable_run: jax.Array    # [] int32: consecutive stable-evidence windows
    unstable_run: jax.Array  # [] int32: consecutive unstable-evidence windows
    verdict: jax.Array       # [] int32: VERDICT_UNDECIDED/STABLE/UNSTABLE
    decided_at: jax.Array    # [] int32: slot count at which verdict latched

    @staticmethod
    def zero() -> "DriftStats":
        z = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        return DriftStats(z, z, z, z, zi, zi, zi, zi)


def drift_verdict_update(d: DriftStats, t: jax.Array, total_q: jax.Array,
                         delivered_useful: jax.Array, lam: jax.Array, *,
                         window: int, burn_in: int, k_stable: int,
                         k_unstable: int, drift_tol: float,
                         gap_tol: float) -> DriftStats:
    """One slot of the streaming stability verdict (DESIGN.md §8).

    Called with the *post-slot* backlog and cumulative useful deliveries of
    slot ``t``.  The burn-in end (``t + 1 == burn_in``) anchors the
    counters, discarding the fill-up transient; at every later window
    boundary (``(t+1) % window == 0``) two tests are scored against the
    offered rate ``lam``, both with thresholds scaled by ``max(lam, 1)``
    so one tolerance spans the rate sweep:

      * Lyapunov-style drift test — the anchored per-slot backlog slope
        ``drift_a`` at most ``drift_tol`` (stable evidence) / at least it
        (unstable evidence);
      * delivered-vs-offered gap check — ``lam - rate_a`` within
        ``gap_tol`` of zero (stable) / at least the full ``gap_tol``
        (unstable: a genuinely diverging queue loses throughput *and*
        grows, so rates just above capacity — drift without much gap —
        stay UNDECIDED).

    ``k_stable``/``k_unstable`` consecutive boundaries of agreeing
    evidence latch the verdict; a latched verdict never changes
    (``decide`` requires ``verdict == UNDECIDED``), which is what makes
    per-sim freezing safe.
    """
    boundary = (t + 1) % window == 0
    anchor = (t + 1) == burn_in
    # Evidence only counts once the anchored horizon spans >= 2 windows:
    # the first post-anchor boundary estimates the slope from `window`
    # slots, where one unlucky anchor instant dominates the statistic.
    counted = boundary & (t + 1 >= burn_in + 2 * window)
    scale = jnp.maximum(lam, 1.0)
    elapsed = jnp.maximum((t + 1 - burn_in).astype(jnp.float32), 1.0)
    drift_a = (total_q - d.q_mark) / elapsed
    rate_a = (delivered_useful - d.useful_mark) / elapsed
    gap_a = lam - rate_a
    stable_ev = (drift_a <= drift_tol * scale) & (gap_a <= gap_tol * scale)
    # Instability must clear a *wider* bar than stability loses: 2x the
    # drift tolerance and the full gap tolerance, so boundary noise that
    # merely breaks a stable streak cannot latch UNSTABLE — the region
    # in between stays UNDECIDED (conservative for the frontier search).
    unstable_ev = (drift_a >= 2.0 * drift_tol * scale) & \
        (gap_a >= gap_tol * scale)
    s_run = jnp.where(counted,
                      jnp.where(stable_ev, d.stable_run + 1, 0),
                      d.stable_run)
    u_run = jnp.where(counted,
                      jnp.where(unstable_ev, d.unstable_run + 1, 0),
                      d.unstable_run)
    newly = jnp.where(s_run >= k_stable, VERDICT_STABLE,
                      jnp.where(u_run >= k_unstable, VERDICT_UNSTABLE,
                                VERDICT_UNDECIDED)).astype(jnp.int32)
    decide = counted & (d.verdict == VERDICT_UNDECIDED) & \
        (newly != VERDICT_UNDECIDED)
    return DriftStats(
        q_mark=jnp.where(anchor, total_q, d.q_mark),
        useful_mark=jnp.where(anchor, delivered_useful, d.useful_mark),
        last_drift=jnp.where(counted, drift_a, d.last_drift),
        last_rate=jnp.where(counted, rate_a, d.last_rate),
        stable_run=s_run, unstable_run=u_run,
        verdict=jnp.where(decide, newly, d.verdict),
        decided_at=jnp.where(decide, (t + 1).astype(jnp.int32),
                             d.decided_at),
    )


class NetState(NamedTuple):
    Q: jax.Array            # [N, 3, NC]
    Ddum: jax.Array         # [N, NC]
    X: jax.Array            # [NC, 2]
    Y: jax.Array            # [NC]
    H: jax.Array            # [NC]
    cum_arr: jax.Array      # [NC, 2]
    cum_comb: jax.Array     # [NC]
    delivered: jax.Array    # [] total processed packets (incl. dummies) at d
    delivered_useful: jax.Array  # []
    delivered_c: jax.Array       # [] Kahan compensation for `delivered`
    delivered_useful_c: jax.Array  # [] ... and for `delivered_useful`

    def total_queue(self) -> jax.Array:
        """Total backlog tracked for stability (paper §II-D)."""
        return (self.Q.sum() + self.X.sum() + self.Y.sum())

    def credit_delivery(self, dlv: jax.Array,
                        dlv_useful: jax.Array) -> "NetState":
        """Compensated update of the cumulative delivery counters."""
        d, dc = kahan_add(self.delivered, self.delivered_c, dlv)
        du, duc = kahan_add(self.delivered_useful, self.delivered_useful_c,
                            dlv_useful)
        return self._replace(delivered=d, delivered_c=dc,
                             delivered_useful=du, delivered_useful_c=duc)


@dataclasses.dataclass(frozen=True)
class StaticProblem:
    """Device-ready constant arrays describing a ComputeProblem.

    `edge_mask` / `comp_mask` support *padded* instances (fleet batching,
    DESIGN: src/repro/fleet/batching.py): entries with mask 0.0 are inert —
    masked edges carry no traffic and masked computation nodes are never
    selected by load balancing and never combine pairs.  `None` (the seed
    default) means every edge/comp node is active.
    """

    n_nodes: int
    n_comp: int
    edges: np.ndarray          # [E,2] int32
    edge_cap: np.ndarray       # [E] float32
    s1: int
    s2: int
    dest: int
    comp_nodes: np.ndarray     # [NC] int32
    comp_caps: np.ndarray      # [NC] float32
    # sink mask: sink[k, i, n] == True when Q_k^{(i,n)} is 0 by convention
    sink: np.ndarray           # [N, 3, NC] bool
    edge_mask: np.ndarray | None = None   # [E] float32, 1.0 = active
    comp_mask: np.ndarray | None = None   # [NC] float32, 1.0 = active

    @staticmethod
    def build(problem: ComputeProblem) -> "StaticProblem":
        N = problem.graph.n_nodes
        NC = problem.n_comp
        sink = np.zeros((N, 3, NC), dtype=bool)
        for j, n in enumerate(problem.comp_nodes):
            sink[n, 1, j] = True          # raw packets terminate at their comp node
            sink[n, 2, j] = True
            sink[problem.dest, 0, j] = True   # processed packets terminate at d
        return StaticProblem(
            n_nodes=N,
            n_comp=NC,
            edges=problem.graph.edges.astype(np.int32),
            edge_cap=problem.graph.capacity.astype(np.float32),
            s1=problem.s1,
            s2=problem.s2,
            dest=problem.dest,
            comp_nodes=np.asarray(problem.comp_nodes, dtype=np.int32),
            comp_caps=np.asarray(problem.comp_caps, dtype=np.float32),
            sink=sink,
        )


def init_state(sp: StaticProblem) -> NetState:
    N, NC = sp.n_nodes, sp.n_comp
    z = jnp.zeros
    return NetState(
        Q=z((N, 3, NC), jnp.float32),
        Ddum=z((N, NC), jnp.float32),
        X=z((NC, 2), jnp.float32),
        Y=z((NC,), jnp.float32),
        H=z((NC,), jnp.float32),
        cum_arr=z((NC, 2), jnp.float32),
        cum_comb=z((NC,), jnp.float32),
        delivered=jnp.zeros((), jnp.float32),
        delivered_useful=jnp.zeros((), jnp.float32),
        delivered_c=jnp.zeros((), jnp.float32),
        delivered_useful_c=jnp.zeros((), jnp.float32),
    )
