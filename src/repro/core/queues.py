"""Queue state for the backpressure network-computation system (paper §II-C).

All quantities are JAX arrays so the whole network steps inside `lax.scan`.
Class index convention: i=0 processed, i=1 raw from s1, i=2 raw from s2.
Queues are *fluid* (float) — see DESIGN.md §1.

State components (paper notation):
  Q[k, i, n]   : data queue at node k, class (i, n)    (Q_k^{(i,n)})
  Ddum[k, n]   : dummy-packet content of Q[k, 0, n]    (regulator tracking)
  X[n, i]      : raw packets of source i+1 at computation node n (X_n^{(i)})
  Y[n]         : regulator queue of computed results   (Y_n)
  H[n]         : virtual admission queue               (H_n)
  cum_arr[n,i] : cumulative raw arrivals into X[n, i]  (for FIFO pairing)
  cum_comb[n]  : cumulative pairs combined at n
  delivered / delivered_useful : cumulative processed packets at d

The delivery counters are *compensated* (Kahan) float32 sums: `delivered`
carries the running total and `delivered_c` the rounding residue, so
per-slot increments survive far past the naive float32 saturation point
(~2^24 ≈ 1.7e7 packets, where `big + 1.0 == big`).  Read them through
`state.delivered`; update them only through `state.credit_delivery`
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import ComputeProblem


def kahan_add(s: jax.Array, c: jax.Array, x: jax.Array):
    """One compensated-summation step: returns (new_sum, new_compensation).

    Keeps float32 running sums exact to ~1 ulp of the *increments* instead
    of 1 ulp of the total — the difference between losing every packet past
    ~10^7 delivered and losing none (ROADMAP numerics note)."""
    y = x - c
    t = s + y
    return t, (t - s) - y


class NetState(NamedTuple):
    Q: jax.Array            # [N, 3, NC]
    Ddum: jax.Array         # [N, NC]
    X: jax.Array            # [NC, 2]
    Y: jax.Array            # [NC]
    H: jax.Array            # [NC]
    cum_arr: jax.Array      # [NC, 2]
    cum_comb: jax.Array     # [NC]
    delivered: jax.Array    # [] total processed packets (incl. dummies) at d
    delivered_useful: jax.Array  # []
    delivered_c: jax.Array       # [] Kahan compensation for `delivered`
    delivered_useful_c: jax.Array  # [] ... and for `delivered_useful`

    def total_queue(self) -> jax.Array:
        """Total backlog tracked for stability (paper §II-D)."""
        return (self.Q.sum() + self.X.sum() + self.Y.sum())

    def credit_delivery(self, dlv: jax.Array,
                        dlv_useful: jax.Array) -> "NetState":
        """Compensated update of the cumulative delivery counters."""
        d, dc = kahan_add(self.delivered, self.delivered_c, dlv)
        du, duc = kahan_add(self.delivered_useful, self.delivered_useful_c,
                            dlv_useful)
        return self._replace(delivered=d, delivered_c=dc,
                             delivered_useful=du, delivered_useful_c=duc)


@dataclasses.dataclass(frozen=True)
class StaticProblem:
    """Device-ready constant arrays describing a ComputeProblem.

    `edge_mask` / `comp_mask` support *padded* instances (fleet batching,
    DESIGN: src/repro/fleet/batching.py): entries with mask 0.0 are inert —
    masked edges carry no traffic and masked computation nodes are never
    selected by load balancing and never combine pairs.  `None` (the seed
    default) means every edge/comp node is active.
    """

    n_nodes: int
    n_comp: int
    edges: np.ndarray          # [E,2] int32
    edge_cap: np.ndarray       # [E] float32
    s1: int
    s2: int
    dest: int
    comp_nodes: np.ndarray     # [NC] int32
    comp_caps: np.ndarray      # [NC] float32
    # sink mask: sink[k, i, n] == True when Q_k^{(i,n)} is 0 by convention
    sink: np.ndarray           # [N, 3, NC] bool
    edge_mask: np.ndarray | None = None   # [E] float32, 1.0 = active
    comp_mask: np.ndarray | None = None   # [NC] float32, 1.0 = active

    @staticmethod
    def build(problem: ComputeProblem) -> "StaticProblem":
        N = problem.graph.n_nodes
        NC = problem.n_comp
        sink = np.zeros((N, 3, NC), dtype=bool)
        for j, n in enumerate(problem.comp_nodes):
            sink[n, 1, j] = True          # raw packets terminate at their comp node
            sink[n, 2, j] = True
            sink[problem.dest, 0, j] = True   # processed packets terminate at d
        return StaticProblem(
            n_nodes=N,
            n_comp=NC,
            edges=problem.graph.edges.astype(np.int32),
            edge_cap=problem.graph.capacity.astype(np.float32),
            s1=problem.s1,
            s2=problem.s2,
            dest=problem.dest,
            comp_nodes=np.asarray(problem.comp_nodes, dtype=np.int32),
            comp_caps=np.asarray(problem.comp_caps, dtype=np.float32),
            sink=sink,
        )


def init_state(sp: StaticProblem) -> NetState:
    N, NC = sp.n_nodes, sp.n_comp
    z = jnp.zeros
    return NetState(
        Q=z((N, 3, NC), jnp.float32),
        Ddum=z((N, NC), jnp.float32),
        X=z((NC, 2), jnp.float32),
        Y=z((NC,), jnp.float32),
        H=z((NC,), jnp.float32),
        cum_arr=z((NC, 2), jnp.float32),
        cum_comb=z((NC,), jnp.float32),
        delivered=jnp.zeros((), jnp.float32),
        delivered_useful=jnp.zeros((), jnp.float32),
        delivered_c=jnp.zeros((), jnp.float32),
        delivered_useful_c=jnp.zeros((), jnp.float32),
    )
