"""Core library: the paper's contribution (backpressure network computation).

Public API:
  graph:     Graph, ComputeProblem, grid_graph, triangle_graph, paper_grid_problem
  capacity:  capacity_upper_bound, single_node_capacity  (Theorems 1/4)
  queues:    NetState, StaticProblem, init_state
  policies:  PolicyConfig, slot_step, bp_route_slot, computation_slot
  router:    RouterConfig, RouterState, route  (backpressure MoE routing)
  regulator: regulator_push  (dummy-packet randomization)
"""
from .graph import (Graph, ComputeProblem, grid_graph, line_graph,
                    triangle_graph, paper_grid_problem)
from .capacity import (capacity_upper_bound, single_node_capacity,
                       multi_stream_capacity, CapacityResult,
                       MultiStreamResult)
from .queues import NetState, StaticProblem, init_state
from .policies import PolicyConfig, slot_step, bp_route_slot, computation_slot
from .router import RouterConfig, RouterState, RouterOut, init_router_state, route
from .regulator import regulator_push

__all__ = [
    "Graph", "ComputeProblem", "grid_graph", "line_graph", "triangle_graph",
    "paper_grid_problem", "capacity_upper_bound", "single_node_capacity",
    "CapacityResult", "multi_stream_capacity", "MultiStreamResult", "NetState", "StaticProblem", "init_state",
    "PolicyConfig", "slot_step", "bp_route_slot", "computation_slot",
    "RouterConfig", "RouterState", "RouterOut", "init_router_state", "route",
    "regulator_push",
]
