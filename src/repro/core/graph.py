"""Network graph abstraction for in-network computation.

The paper models a capacitated undirected graph G=(V,E) with two data sources
s1, s2, one destination d, and a set of computation nodes N_C with per-node
computation capacities C_n (results/slot).  Edges carry R_ml packets/slot,
shared by both directions and all packet classes (paper eq. (1)).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Static undirected network graph."""

    n_nodes: int
    edges: np.ndarray        # [E, 2] int, undirected node pairs (m, l)
    capacity: np.ndarray     # [E] float, R_ml packets/slot (shared by directions)

    def __post_init__(self):
        object.__setattr__(self, "edges", np.asarray(self.edges, dtype=np.int32))
        object.__setattr__(self, "capacity", np.asarray(self.capacity, dtype=np.float64))
        assert self.edges.ndim == 2 and self.edges.shape[1] == 2
        assert self.capacity.shape == (self.edges.shape[0],)
        assert (self.edges >= 0).all() and (self.edges < self.n_nodes).all()
        assert (self.edges[:, 0] != self.edges[:, 1]).all(), "no self loops"

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    def directed_edges(self) -> np.ndarray:
        """[2E, 2] — both orientations of every undirected edge."""
        fwd = self.edges
        bwd = self.edges[:, ::-1]
        return np.concatenate([fwd, bwd], axis=0)

    def neighbors(self, node: int) -> list[int]:
        out = []
        for m, l in self.edges:
            if m == node:
                out.append(int(l))
            elif l == node:
                out.append(int(m))
        return sorted(set(out))


def grid_graph(rows: int, cols: int, capacity: float) -> Graph:
    """rows x cols grid; node id = r*cols + c. All edges share `capacity`."""
    edges = []
    for r in range(rows):
        for c in range(cols):
            u = r * cols + c
            if c + 1 < cols:
                edges.append((u, u + 1))
            if r + 1 < rows:
                edges.append((u, u + cols))
    edges = np.array(edges, dtype=np.int32)
    return Graph(rows * cols, edges, np.full(len(edges), capacity))


def line_graph(n: int, capacity: float) -> Graph:
    edges = np.array([(i, i + 1) for i in range(n - 1)], dtype=np.int32)
    return Graph(n, edges, np.full(len(edges), capacity))


def triangle_graph(capacity: float | Sequence[float] = 1.0) -> Graph:
    """The motivating example of the paper: nodes {0,1,2} fully connected."""
    edges = np.array([(0, 1), (0, 2), (1, 2)], dtype=np.int32)
    cap = np.full(3, capacity) if np.isscalar(capacity) else np.asarray(capacity)
    return Graph(3, edges, cap)


@dataclasses.dataclass(frozen=True)
class ComputeProblem:
    """A query-stream computation problem instance (paper §II)."""

    graph: Graph
    s1: int
    s2: int
    dest: int
    comp_nodes: tuple[int, ...]          # N_C
    comp_caps: tuple[float, ...]         # C_n, results/slot

    def __post_init__(self):
        object.__setattr__(self, "comp_nodes", tuple(int(n) for n in self.comp_nodes))
        object.__setattr__(self, "comp_caps", tuple(float(c) for c in self.comp_caps))
        assert len(self.comp_nodes) == len(self.comp_caps)
        for n in (self.s1, self.s2, self.dest, *self.comp_nodes):
            assert 0 <= n < self.graph.n_nodes

    @property
    def n_comp(self) -> int:
        return len(self.comp_nodes)


def paper_grid_problem(C: float = 2.0, R: float = 5.0) -> ComputeProblem:
    """The 4x4 grid instance of paper §V (Fig. 5a).

    The figure raster is unavailable in the text dump; placement below is
    calibrated so the Theorem-4 LP reproduces the paper's reported capacities
    (lambda* = 8 for C=2, ~9.8 for C=3).  See DESIGN.md §1.
    """
    g = grid_graph(4, 4, R)
    return ComputeProblem(
        graph=g, s1=0, s2=3, dest=15,
        comp_nodes=(5, 6, 9, 10), comp_caps=(C,) * 4,
    )
