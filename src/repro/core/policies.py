"""The paper's control policies as pure JAX slot-step functions.

Implemented policies (paper §III-IV):
  pi1    — single comp node, BP routing, combine all available pairs.
  pi1p   — pi1 with the proof-device computation threshold X̄ (Lemma 1).
  pi2    — pi1 + regulator/dummy randomization (overlapping networks, Thm 3).
  pi3    — multiple comp nodes: join-shortest-sum-of-queues load balancing
           (eq. 9), H_n virtual queues (eq. 10), BP routing over 3·N_C
           classes, all-possible computation, regulator randomization.
  pi3bar — pi3 without the regulator (the conjectured-optimal variant of §V).

Every step is `slot_step(sp, cfg, state, arrivals, key) -> (state, metrics)`
and is jit/scan/vmap friendly.  Constants from `StaticProblem` are closed
over as numpy arrays (become XLA constants).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bp_slot.kernel import (comp_balance_decide,
                                          slot_route_decide)
from repro.kernels.bp_slot.ref import (balance_score, combine_amount,
                                       pair_count, slot_route_ref)

from .queues import NetState, StaticProblem
from .regulator import regulator_push


#: Policies that route computation output through the dummy-packet regulator.
#: ``pi2_reg``/``pi3_reg`` are the fleet-facing names for the regulated
#: variants: identical slot dynamics to pi2/pi3, but kept distinct so one
#: sweep can carry both a plain and an explicitly-regulated entry and the
#: report layer scores the ``_reg`` rows against the rho0-adjusted bound
#: lam*/(1+eps_B) (DESIGN.md §2).
REGULATED_POLICIES = ("pi2", "pi2_reg", "pi3", "pi3_reg")

#: Every implemented policy name.  `PolicyConfig` rejects anything else at
#: construction: the behavior flags below are exact-string membership
#: tests, so a typo ("pi3reg") would otherwise silently run unregulated
#: pi1-like dynamics and be scored against the wrong bound.
KNOWN_POLICIES = ("pi1", "pi1p", "pi2", "pi2_reg", "pi3", "pi3_reg",
                  "pi3bar")

#: Decision backends for the per-slot hot loop (DESIGN.md §7): "xla" runs
#: the pure-jnp oracle (`repro.kernels.bp_slot.ref`), "pallas" the fused
#: tiled kernels (`repro.kernels.bp_slot.kernel`) — bit-identical by
#: construction, selected via `PolicyConfig.backend`.
KNOWN_BACKENDS = ("xla", "pallas")


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    name: str = "pi3"            # pi1 | pi1p | pi2[_reg] | pi3[_reg] | pi3bar
    eps_b: float = 0.01          # regulator Bernoulli parameter
    pairing: str = "fifo"        # fifo | bound   (DESIGN.md §1)
    threshold: float = 0.0       # X̄ for the primed (proof-device) variants
    fixed_node: int = 0          # comp-node index used by pi1/pi1p/pi2
    wireless: bool = False       # §IV-C: node-exclusive interference; links
                                 # activated by greedy maximal matching
                                 # weighted by differential backlog [17,18]
    backend: str = "xla"         # "xla" | "pallas" — slot-decision kernels
                                 # (DESIGN.md §7); bit-identical outputs
    interpret: bool = True       # Pallas interpreter mode (CPU CI); pass
                                 # False on TPU for compiled kernels

    def __post_init__(self):
        if self.name not in KNOWN_POLICIES:
            raise ValueError(
                f"unknown policy {self.name!r}; known: {KNOWN_POLICIES}")
        if self.backend not in KNOWN_BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; known: {KNOWN_BACKENDS}")

    @property
    def use_regulator(self) -> bool:
        return self.name in REGULATED_POLICIES

    @property
    def load_balance(self) -> bool:
        return self.name in ("pi3", "pi3_reg", "pi3bar")

    @property
    def thresholded(self) -> bool:
        return self.name == "pi1p"

    @property
    def rho0(self) -> float:
        """Output-rate inflation rho0 = 1 + eps_B (paper eq. (8), Thm 3/5).

        The operative throughput bound of a regulated policy is
        lam*/rho0; unregulated policies are bounded by lam* itself.
        """
        return 1.0 + self.eps_b if self.use_regulator else 1.0


# ---------------------------------------------------------------------------
# Backpressure routing (paper's BP box + constraint (1) conventions)
# ---------------------------------------------------------------------------

def greedy_maximal_matching(edges: jnp.ndarray, weights: jnp.ndarray,
                            n_nodes: int) -> jnp.ndarray:
    """Greedy maximal matching under the node-exclusive interference model
    (paper refs [17, 18]): visit links in decreasing weight order, activate
    a link iff neither endpoint is already busy.  Returns a [E] bool mask.
    """
    E = edges.shape[0]
    order = jnp.argsort(-weights)

    def body(t, carry):
        used, sel = carry
        e = order[t]
        m, l = edges[e, 0], edges[e, 1]
        ok = (~used[m]) & (~used[l]) & (weights[e] > 0)
        used = used.at[m].set(used[m] | ok).at[l].set(used[l] | ok)
        sel = sel.at[e].set(ok)
        return used, sel

    used0 = jnp.zeros((n_nodes,), bool)
    sel0 = jnp.zeros((E,), bool)
    _, sel = jax.lax.fori_loop(0, E, body, (used0, sel0))
    return sel


def bp_route_slot(sp: StaticProblem, state: NetState,
                  wireless: bool = False, backend: str = "xla",
                  interpret: bool = True) -> Tuple[NetState, Dict]:
    """One slot of max-differential-backlog routing over every link.

    Per undirected link, the class (i, n) maximizing |Q_m - Q_k| gets the full
    link rate R in the decreasing direction; fluid outflows from a queue are
    capped at its content and split proportionally across links (the paper's
    "zero packets" convention in expectation).

    wireless=True (paper §IV-C): links interfere node-exclusively; only a
    greedy maximal matching weighted by |differential backlog| transmits.

    backend="pallas" computes the (class, comp, direction) decision with the
    fused tiled kernel `repro.kernels.bp_slot.slot_route_decide` — the
    [E, 3*NC] differential tensor is streamed through VMEM instead of
    materialized — bit-identical to the "xla" oracle (DESIGN.md §7).
    """
    Q, Ddum, X = state.Q, state.Ddum, state.X
    m_idx = jnp.asarray(sp.edges[:, 0])
    l_idx = jnp.asarray(sp.edges[:, 1])
    cap = jnp.asarray(sp.edge_cap)
    NC = sp.n_comp

    Qf = Q.reshape(Q.shape[0], -1)                 # [N, 3*NC] (i-major)
    if backend == "pallas":
        best, dmax = slot_route_decide(Qf, m_idx, l_idx, interpret=interpret)
    else:
        best, dmax = slot_route_ref(Qf, m_idx, l_idx)
    best_i = best // NC
    best_n = best % NC

    alloc = cap * (jnp.abs(dmax) > 0)
    # A link that cannot carry traffic this slot — padded (edge_mask 0) or
    # with zero current capacity (event-model outage) — must not occupy a
    # matching slot in the wireless interference model either.
    weight = jnp.abs(dmax) * (cap > 0)
    if sp.edge_mask is not None:
        emask = jnp.asarray(sp.edge_mask, jnp.float32)
        alloc = alloc * emask
        weight = weight * emask
    if wireless:
        active = greedy_maximal_matching(jnp.asarray(sp.edges),
                                         weight, sp.n_nodes)
        alloc = alloc * active
    src = jnp.where(dmax > 0, m_idx, l_idx)
    dst = jnp.where(dmax > 0, l_idx, m_idx)

    # Cap total outflow of each (node, class) at its queue content.
    total_out = jnp.zeros_like(Q).at[src, best_i, best_n].add(alloc)
    scale = jnp.where(total_out > Q, Q / jnp.maximum(total_out, 1e-20), 1.0)
    actual = alloc * scale[src, best_i, best_n]    # [E]

    # Dummy share of moved processed packets (proportional composition).
    q0_src = Q[src, 0, best_n]
    frac_dummy = jnp.where(q0_src > 0, Ddum[src, best_n] / jnp.maximum(q0_src, 1e-20), 0.0)
    moved_dummy = actual * frac_dummy * (best_i == 0)

    # Departures.
    Q = Q.at[src, best_i, best_n].add(-actual)
    Ddum = Ddum.at[src, best_n].add(-moved_dummy)

    # Arrivals: sinks absorb (raw -> X at its comp node; processed -> d).
    is_sink = jnp.asarray(sp.sink)[dst, best_i, best_n]          # [E]
    to_net = actual * (~is_sink)
    Q = Q.at[dst, best_i, best_n].add(to_net)
    Ddum = Ddum.at[dst, best_n].add(moved_dummy * (~is_sink))

    raw_sink = is_sink & (best_i >= 1)
    to_X = actual * raw_sink
    X = X.at[best_n, jnp.maximum(best_i - 1, 0)].add(to_X)
    cum_arr = state.cum_arr.at[best_n, jnp.maximum(best_i - 1, 0)].add(to_X)

    proc_sink = is_sink & (best_i == 0)
    dlv = jnp.sum(actual * proc_sink)
    dlv_useful = jnp.sum((actual - moved_dummy) * proc_sink)

    new = state._replace(Q=Q, Ddum=Ddum, X=X, cum_arr=cum_arr)
    new = new.credit_delivery(dlv, dlv_useful)
    return new, {"routed": jnp.sum(actual)}


# ---------------------------------------------------------------------------
# Pairing / computation (constraint (3) handling — DESIGN.md §1)
# ---------------------------------------------------------------------------

def _x_net(state: NetState, pairing: str) -> jax.Array | None:
    """Raw packets in flight (paper eq. (7)) — only the "bound" pairing
    model consumes it; None keeps the fifo path free of the [N] reduction."""
    if pairing != "bound":
        return None
    return state.Q[:, 1, :].sum(axis=0) + state.Q[:, 2, :].sum(axis=0)  # [NC]


def available_pairs(sp: StaticProblem, state: NetState, pairing: str) -> jax.Array:
    """P_n(t): pairs of same-tag raw packets present at each comp node.

    Delegates to `repro.kernels.bp_slot.ref.pair_count` — the same algebra
    the fused Pallas kernel evaluates in-tile (DESIGN.md §7)."""
    return pair_count(state.X[:, 0], state.X[:, 1],
                      state.cum_arr[:, 0], state.cum_arr[:, 1],
                      state.cum_comb, _x_net(state, pairing), pairing)


def _comp_balance_kernel_call(sp: StaticProblem, cfg: PolicyConfig,
                              state: NetState, eps: jax.Array):
    """Invoke the fused comp/balance Pallas kernel on this state snapshot.

    Returns (Z [NC], n_star []) — `load_balance_slot` consumes n_star (on
    the pre-route state) and `computation_slot` consumes Z (post-route);
    the fused kernel computes both in one tiled pass either way
    (DESIGN.md §7)."""
    comp = jnp.asarray(sp.comp_nodes)
    nidx = jnp.arange(sp.n_comp)
    mask = (jnp.ones((sp.n_comp,), state.Q.dtype) if sp.comp_mask is None
            else jnp.asarray(sp.comp_mask))
    x_net = _x_net(state, cfg.pairing)
    if x_net is None:
        x_net = jnp.zeros((sp.n_comp,), state.X.dtype)
    return comp_balance_decide(
        jnp.asarray(eps, state.Q.dtype),
        state.Q[comp, 0, nidx], state.Q[sp.s1, 1, :], state.Q[sp.s2, 2, :],
        state.H, jnp.asarray(sp.comp_caps), mask,
        state.X[:, 0], state.X[:, 1],
        state.cum_arr[:, 0], state.cum_arr[:, 1], state.cum_comb, x_net,
        pairing=cfg.pairing, thresholded=cfg.thresholded,
        threshold=cfg.threshold, interpret=cfg.interpret)


def _inject_processed(sp: StaticProblem, state: NetState, amount: jax.Array,
                      dummy: jax.Array) -> NetState:
    """Push per-comp-node processed packets into Q_n^{(0,n)} (or deliver if n==d)."""
    comp = jnp.asarray(sp.comp_nodes)
    at_dest = comp == sp.dest                          # [NC]
    to_net = amount * (~at_dest)
    nidx = jnp.arange(sp.n_comp)
    Q = state.Q.at[comp, 0, nidx].add(to_net)
    Ddum = state.Ddum.at[comp, nidx].add(dummy * (~at_dest))
    dlv = jnp.sum(amount * at_dest)
    dlv_useful = jnp.sum((amount - dummy) * at_dest)
    return state._replace(Q=Q, Ddum=Ddum).credit_delivery(dlv, dlv_useful)


def computation_slot(sp: StaticProblem, cfg: PolicyConfig, state: NetState,
                     assigned: jax.Array, key: jax.Array,
                     eps_b: jax.Array | None = None) -> Tuple[NetState, Dict]:
    """Combine pairs at every computation node; route output via the
    regulator (pi2/pi3 and their ``_reg`` aliases) or directly (pi1/pi3bar).

    `eps_b` optionally overrides `cfg.eps_b` with a *traced* value: the fleet
    engine passes it per job so sweeping the regulator parameter does not
    fork compiled programs (only `cfg.use_regulator` changes control flow).
    """
    if cfg.backend == "pallas":
        # Fused pairs + threshold + combine (the argmin half of the kernel's
        # output is the load-balance side; unused on this snapshot).
        eps = cfg.eps_b if eps_b is None else eps_b
        Z, _ = _comp_balance_kernel_call(sp, cfg, state, eps)
    else:
        caps = jnp.asarray(sp.comp_caps)
        if sp.comp_mask is not None:
            caps = caps * jnp.asarray(sp.comp_mask, jnp.float32)
        P = available_pairs(sp, state, cfg.pairing)
        # pi1' (thresholded): combine C_n only when X1+X2 >= 2 C_n + X̄
        # (still physically capped by the pairs actually present).
        Z = combine_amount(P, caps, state.X.sum(axis=1), cfg.thresholded,
                           cfg.threshold)
    # (masked comp nodes have caps forced to 0 above, so Z == 0 there: P is
    # clipped non-negative in available_pairs)

    X = state.X - Z[:, None]
    cum_comb = state.cum_comb + Z
    state = state._replace(X=X, cum_comb=cum_comb)

    if cfg.use_regulator:
        eps = cfg.eps_b if eps_b is None else eps_b
        Y = state.Y + Z
        Y, F, dummy = regulator_push(Y, assigned, key, eps)
        state = state._replace(Y=Y)
        state = _inject_processed(sp, state, F, dummy)
    else:
        zeros = jnp.zeros_like(Z)
        state = _inject_processed(sp, state, Z, zeros)
    return state, {"computed": jnp.sum(Z)}


# ---------------------------------------------------------------------------
# Load balancing (eq. 9/10) and arrival injection
# ---------------------------------------------------------------------------

def load_balance_slot(sp: StaticProblem, cfg: PolicyConfig, state: NetState,
                      arrivals: jax.Array,
                      eps_b: jax.Array | None = None
                      ) -> Tuple[NetState, jax.Array, Dict]:
    """Assign this slot's A(t) queries to a computation node and inject the
    corresponding raw packets at the sources.  `eps_b` optionally overrides
    `cfg.eps_b` with a traced per-job value (see `computation_slot`)."""
    if cfg.load_balance:
        eps = cfg.eps_b if eps_b is None else eps_b
        if cfg.backend == "pallas":
            # Fused kernel on the pre-route snapshot; its Z half is unused
            # here — computation_slot re-invokes it post-route.
            _, n_star = _comp_balance_kernel_call(sp, cfg, state, eps)
        else:
            score = balance_score(                                 # eq. (9)
                eps,
                state.Q[jnp.asarray(sp.comp_nodes), 0,
                        jnp.arange(sp.n_comp)],
                state.Q[sp.s1, 1, :], state.Q[sp.s2, 2, :], state.H,
                # Masked-out (padded/failed) comp nodes never win the argmin.
                None if sp.comp_mask is None else jnp.asarray(sp.comp_mask))
            n_star = jnp.argmin(score)
    else:
        n_star = jnp.asarray(cfg.fixed_node, dtype=jnp.int32)

    assigned = jnp.zeros(sp.n_comp).at[n_star].set(arrivals)       # eq. (10)

    # Inject raw packets; a source that *is* the chosen comp node feeds X
    # directly (the sink convention).
    comp = jnp.asarray(sp.comp_nodes)
    caps = jnp.asarray(sp.comp_caps)
    Q, X, cum_arr = state.Q, state.X, state.cum_arr
    for i, s in ((0, sp.s1), (1, sp.s2)):
        direct = comp[n_star] == s
        Q = Q.at[s, i + 1, n_star].add(jnp.where(direct, 0.0, arrivals))
        X = X.at[n_star, i].add(jnp.where(direct, arrivals, 0.0))
        cum_arr = cum_arr.at[n_star, i].add(jnp.where(direct, arrivals, 0.0))

    H = jnp.maximum(state.H + assigned - caps, 0.0)                # H_n update
    state = state._replace(Q=Q, X=X, cum_arr=cum_arr, H=H)
    return state, assigned, {"n_star": n_star}


# ---------------------------------------------------------------------------
# Full slot step
# ---------------------------------------------------------------------------

def slot_step(sp: StaticProblem, cfg: PolicyConfig, state: NetState,
              arrivals: jax.Array, key: jax.Array,
              eps_b: jax.Array | None = None) -> Tuple[NetState, Dict]:
    """One slot: (i) admit+load-balance, (ii) BP routing, (iii) computation
    (+ regulator push).  `eps_b=None` uses the static `cfg.eps_b`; a traced
    array makes the regulator parameter per-job data (fleet sweeps).

    `cfg.backend` selects the decision implementation — "xla" (the pure-jnp
    oracle in `repro.kernels.bp_slot.ref`) or "pallas" (the fused tiled
    kernels, bit-identical; DESIGN.md §7)."""
    state, assigned, m1 = load_balance_slot(sp, cfg, state, arrivals, eps_b)
    state, m2 = bp_route_slot(sp, state, wireless=cfg.wireless,
                              backend=cfg.backend, interpret=cfg.interpret)
    state, m3 = computation_slot(sp, cfg, state, assigned, key, eps_b)
    metrics = {
        "total_queue": state.total_queue(),
        "delivered": state.delivered,
        "delivered_useful": state.delivered_useful,
        **m1, **m2, **m3,
    }
    return state, metrics
