"""Theorem 1 / Theorem 4 capacity upper bound via the multicommodity-flow LP.

For computation nodes N_C we build 3*N_C unicast commodities:
  (1,n): s1 -> n   rate lam_n      (raw data of source 1)
  (2,n): s2 -> n   rate lam_n      (raw data of source 2)
  (0,n): n  -> d   rate lam_n      (processed results)
subject to per-edge shared capacity (paper eq. (1)/(5)), flow conservation
(4), positivity and no-outflow-at-destination (6), and lam_n <= C_n.
lambda* = max sum_n lam_n.  Solved exactly with scipy/HiGHS.

The LP also supports an output-rate multiplier `rho0` on commodity (0,n)
(rate rho0*lam_n) which models the dummy-packet overhead (1+eps_B) of
policies pi2/pi3 (Theorem 3/5).
"""
from __future__ import annotations

import dataclasses

import numpy as np
from scipy.optimize import linprog

from .graph import ComputeProblem


@dataclasses.dataclass(frozen=True)
class CapacityResult:
    lam_star: float                 # max total query rate
    lam_per_node: np.ndarray        # [N_C] optimal per-node shares
    flows: np.ndarray               # [2E, 3*N_C] optimal directed flows
    status: str

    def time_share(self) -> np.ndarray:
        tot = self.lam_per_node.sum()
        return self.lam_per_node / max(tot, 1e-12)


def _commodity_endpoints(problem: ComputeProblem) -> list[tuple[int, int]]:
    """(src, dst) per commodity; order: for each n: (1,n), (2,n), (0,n)."""
    eps = []
    for n in problem.comp_nodes:
        eps.append((problem.s1, n))
        eps.append((problem.s2, n))
        eps.append((n, problem.dest))
    return eps


def capacity_upper_bound(problem: ComputeProblem, rho0: float = 1.0) -> CapacityResult:
    g = problem.graph
    NC = problem.n_comp
    E = g.n_edges
    de = g.directed_edges()           # [2E, 2]
    n_comm = 3 * NC
    nf = 2 * E * n_comm               # flow variables, layout f[dir_edge, comm]
    nv = nf + NC                      # + lam_n variables

    def fidx(dir_e: int, c: int) -> int:
        return dir_e * n_comm + c

    endpoints = _commodity_endpoints(problem)
    # rate multiplier per commodity (raw commodities 1, processed rho0)
    rate_mult = np.array([1.0, 1.0, rho0] * NC)

    # --- equality: flow conservation at every node, per commodity, except at
    # the commodity destination (conservation there is implied / slack-free
    # because we also force zero outflow at the destination).
    A_eq_rows, b_eq = [], []
    for c, (src, dst) in enumerate(endpoints):
        n_of_c = c // 3
        for m in range(g.n_nodes):
            if m == dst:
                continue
            row = np.zeros(nv)
            for e_id, (a, b) in enumerate(de):
                if a == m:
                    row[fidx(e_id, c)] += 1.0    # outgoing
                elif b == m:
                    row[fidx(e_id, c)] -= 1.0    # incoming
            if m == src:
                row[nf + n_of_c] = -rate_mult[c]
            A_eq_rows.append(row)
            b_eq.append(0.0)
    A_eq = np.array(A_eq_rows)
    b_eq = np.array(b_eq)

    # --- inequality: shared undirected edge capacity over all commodities+dirs
    A_ub_rows, b_ub = [], []
    for e in range(E):
        row = np.zeros(nv)
        for c in range(n_comm):
            row[fidx(e, c)] = 1.0
            row[fidx(e + E, c)] = 1.0
        A_ub_rows.append(row)
        b_ub.append(g.capacity[e])
    A_ub = np.array(A_ub_rows)
    b_ub = np.array(b_ub)

    # --- bounds: f >= 0; zero outflow at each commodity's destination (6);
    # 0 <= lam_n <= C_n.
    bounds = [(0.0, None)] * nv
    for c, (_, dst) in enumerate(endpoints):
        for e_id, (a, _) in enumerate(de):
            if a == dst:
                bounds[fidx(e_id, c)] = (0.0, 0.0)
    for i, cap in enumerate(problem.comp_caps):
        bounds[nf + i] = (0.0, float(cap))

    cobj = np.zeros(nv)
    cobj[nf:] = -1.0                 # maximize sum lam_n
    res = linprog(cobj, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
                  bounds=bounds, method="highs")
    if not res.success:
        return CapacityResult(0.0, np.zeros(NC), np.zeros((2 * E, n_comm)),
                              status=res.message)
    lam_per_node = res.x[nf:]
    flows = res.x[:nf].reshape(2 * E, n_comm)
    return CapacityResult(float(lam_per_node.sum()), lam_per_node, flows, "optimal")


def single_node_capacity(problem: ComputeProblem, node_index: int,
                         rho0: float = 1.0) -> CapacityResult:
    """Theorem 1: capacity when computation is pinned to one node."""
    sub = dataclasses.replace(
        problem,
        comp_nodes=(problem.comp_nodes[node_index],),
        comp_caps=(problem.comp_caps[node_index],),
    )
    return capacity_upper_bound(sub, rho0=rho0)


# ---------------------------------------------------------------------------
# Multi-stream (multiclass) extension — the generalization the paper names
# in §II-B/§VI: multiple query streams, each with its own sources and
# destination, sharing links AND computation-node capacity.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultiStreamResult:
    lam_star: float                 # max total rate at the given mix
    lam_per_stream: np.ndarray      # [n_streams]
    lam_per_node: np.ndarray        # [n_streams, N_C]
    status: str


def multi_stream_capacity(problems: list[ComputeProblem],
                          weights: list[float] | None = None,
                          rho0: float = 1.0) -> MultiStreamResult:
    """Max-weighted-throughput LP for several query streams on one graph.

    Streams sigma share (i) every edge capacity and (ii) the computation
    capacity C_n of every node that appears in more than one stream's N_C.
    With `weights` w (sum 1), we maximize lambda s.t. stream sigma gets
    rate w_sigma * lambda — the boundary point of the multiclass capacity
    region along direction w (paper's time-share view, eq. after Thm 4).
    """
    g = problems[0].graph
    for p in problems[1:]:
        assert p.graph.n_nodes == g.n_nodes and \
            (p.graph.edges == g.edges).all(), "streams must share the graph"
    NS = len(problems)
    weights = np.full(NS, 1.0 / NS) if weights is None else \
        np.asarray(weights, dtype=np.float64)
    assert abs(weights.sum() - 1.0) < 1e-9 and (weights > 0).all()

    E = g.n_edges
    de = g.directed_edges()
    # commodity layout: for each stream sigma, for each of its comp nodes:
    # (1,n), (2,n), (0,n); plus lam^sigma_n variables and one global lam.
    comm_of = []                      # (stream, endpoints, rate_var_index)
    lam_var_of = []                   # [(stream, node_idx)]
    for s_i, p in enumerate(problems):
        for n_i, n in enumerate(p.comp_nodes):
            lam_var_of.append((s_i, n_i))
    n_lam = len(lam_var_of)
    lam_index = {sn: i for i, sn in enumerate(lam_var_of)}

    rate_mult = []
    for s_i, p in enumerate(problems):
        for n_i, n in enumerate(p.comp_nodes):
            li = lam_index[(s_i, n_i)]
            comm_of.append((s_i, (p.s1, n), li, 1.0))
            comm_of.append((s_i, (p.s2, n), li, 1.0))
            comm_of.append((s_i, (n, p.dest), li, rho0))
    n_comm = len(comm_of)
    nf = 2 * E * n_comm
    nv = nf + n_lam + 1               # + global lam (last)

    def fidx(dir_e, c):
        return dir_e * n_comm + c

    A_eq_rows, b_eq = [], []
    for c, (s_i, (src, dst), li, mult) in enumerate(comm_of):
        for m in range(g.n_nodes):
            if m == dst:
                continue
            row = np.zeros(nv)
            for e_id, (a, b) in enumerate(de):
                if a == m:
                    row[fidx(e_id, c)] += 1.0
                elif b == m:
                    row[fidx(e_id, c)] -= 1.0
            if m == src:
                row[nf + li] = -mult
            A_eq_rows.append(row)
            b_eq.append(0.0)
    # per-stream total: sum_n lam^sigma_n = w_sigma * lam
    for s_i, p in enumerate(problems):
        row = np.zeros(nv)
        for n_i in range(p.n_comp):
            row[nf + lam_index[(s_i, n_i)]] = 1.0
        row[-1] = -weights[s_i]
        A_eq_rows.append(row)
        b_eq.append(0.0)

    A_ub_rows, b_ub = [], []
    for e in range(E):                # shared edge capacity
        row = np.zeros(nv)
        for c in range(n_comm):
            row[fidx(e, c)] = 1.0
            row[fidx(e + E, c)] = 1.0
        A_ub_rows.append(row)
        b_ub.append(g.capacity[e])
    # shared computation capacity: sum over streams using node n
    node_caps = {}
    for s_i, p in enumerate(problems):
        for n_i, n in enumerate(p.comp_nodes):
            node_caps.setdefault(n, (p.comp_caps[n_i], []))[1].append(
                lam_index[(s_i, n_i)])
    for n, (cap, lis) in node_caps.items():
        row = np.zeros(nv)
        for li in lis:
            row[nf + li] = 1.0
        A_ub_rows.append(row)
        b_ub.append(cap)

    bounds = [(0.0, None)] * nv
    for c, (s_i, (src, dst), li, mult) in enumerate(comm_of):
        for e_id, (a, _) in enumerate(de):
            if a == dst:
                bounds[fidx(e_id, c)] = (0.0, 0.0)

    cobj = np.zeros(nv)
    cobj[-1] = -1.0
    res = linprog(cobj, A_ub=np.array(A_ub_rows), b_ub=np.array(b_ub),
                  A_eq=np.array(A_eq_rows), b_eq=np.array(b_eq),
                  bounds=bounds, method="highs")
    if not res.success:
        return MultiStreamResult(0.0, np.zeros(NS),
                                 np.zeros((NS, 1)), res.message)
    lam = float(res.x[-1])
    per_stream = weights * lam
    per_node = np.zeros((NS, max(p.n_comp for p in problems)))
    for (s_i, n_i), li in lam_index.items():
        per_node[s_i, n_i] = res.x[nf + li]
    return MultiStreamResult(lam, per_stream, per_node, "optimal")
