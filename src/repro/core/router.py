"""Backpressure MoE routing — the paper's technique as a first-class
framework feature (DESIGN.md §2).

Mapping: experts = computation nodes with capacity C_e (tokens/step at
perfect balance); incoming tokens = the query stream; the paper's virtual
admission queues H_n (eq. 10) become per-expert backlog counters, and the
join-the-shortest-sum-of-queues rule (eq. 9) becomes a *selection bias*
subtracted from the gate affinity.  No auxiliary loss touches the gradient:
balance is enforced by queue dynamics alone (loss-free), exactly as the
paper balances computation load without solving an optimization.

State update per step (identical in form to the paper's H_n):
    H_e <- [H_e + assigned_e - capacity_e]^+
Selection per token:
    topk_e( gate_prob_e - beta * H_e / capacity_e )
Combine weights use the *unbiased* gate probabilities of the selected
experts (the bias steers placement, not the function value) — the same
separation the paper makes between routing decisions and packet contents.

The H update runs over micro-batches of the step's tokens (a short
`lax.scan`), not once per full batch.  Updating H only between full
batches makes the controller bang-bang: one idle step changes the bias by
beta * capacity / capacity = beta — the entire gate-probability scale —
so a hot expert flips between "takes every token" and "blocked for
several steps", and the time-averaged load stays visibly imbalanced.
With `micro_batches` sub-updates the bias moves in steps of
beta / micro_batches and a hot expert settles at a *partial* share within
a single routing call (the paper's per-slot H_n dynamics, where arrivals
per slot are comparable to capacity, not T times it).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class RouterState(NamedTuple):
    H: jax.Array          # [E] virtual admission queues (float)
    steps: jax.Array      # [] int32


def init_router_state(n_experts: int) -> RouterState:
    return RouterState(H=jnp.zeros((n_experts,), jnp.float32),
                       steps=jnp.zeros((), jnp.int32))


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    n_experts: int
    k: int                      # experts per token
    mode: str = "backpressure"  # backpressure | aux | plain
    beta: float = 1.0           # backpressure bias strength
    aux_coef: float = 0.01      # Switch-style aux loss coefficient (mode=aux)
    capacity_factor: float = 1.25
    micro_batches: int = 8      # H sub-updates per routing call (see module
                                # docstring); the largest divisor of T that
                                # is <= this is used, so any T works


class RouterOut(NamedTuple):
    expert_idx: jax.Array       # [T, k] int32
    combine_w: jax.Array        # [T, k] float, renormalized gate probs
    aux_loss: jax.Array         # [] differentiable aux loss (0 unless mode=aux)
    new_state: RouterState
    load: jax.Array             # [E] fraction of assignments per expert


def route(cfg: RouterConfig, state: RouterState, logits: jax.Array) -> RouterOut:
    """Route T tokens to k-of-E experts.  logits: [T, E]."""
    T, E = logits.shape
    assert E == cfg.n_experts
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    capacity = jnp.asarray(T * cfg.k / E, jnp.float32)   # C_e per step
    M = max(d for d in range(1, min(cfg.micro_batches, T) + 1) if T % d == 0)
    cap_micro = capacity / M

    def micro(H, p):                                     # p: [T/M, E]
        if cfg.mode == "backpressure":
            bias = cfg.beta * H / jnp.maximum(capacity, 1.0)
            sel_score = p - jax.lax.stop_gradient(bias)[None, :]
        else:
            sel_score = p
        _, idx = jax.lax.top_k(sel_score, cfg.k)         # [T/M, k]
        gathered = jnp.take_along_axis(p, idx, axis=1)
        w = gathered / jnp.maximum(gathered.sum(axis=1, keepdims=True), 1e-9)
        asg = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=(0, 1))
        H = jnp.maximum(H + jax.lax.stop_gradient(asg) - cap_micro, 0.0)
        return H, (idx, w, asg)

    H_new, (idx, w, asg) = jax.lax.scan(micro, state.H,
                                        probs.reshape(M, T // M, E))
    expert_idx = idx.reshape(T, cfg.k)
    combine_w = w.reshape(T, cfg.k)
    assigned = asg.sum(axis=0)                           # [E] tokens per expert
    new_state = RouterState(H=H_new, steps=state.steps + 1)

    if cfg.mode == "aux":
        # Switch-Transformer load balancing loss: E * sum_e f_e * p_e.
        f = assigned / jnp.maximum(assigned.sum(), 1.0)
        p = probs.mean(axis=0)
        aux = cfg.aux_coef * E * jnp.sum(jax.lax.stop_gradient(f) * p)
    else:
        aux = jnp.zeros((), jnp.float32)

    load = assigned / jnp.maximum(assigned.sum(), 1.0)
    return RouterOut(expert_idx=expert_idx, combine_w=combine_w, aux_loss=aux,
                     new_state=new_state, load=load)


def load_violation(load: jax.Array) -> jax.Array:
    """max_e load_e / mean load — 1.0 is perfect balance."""
    return load.max() / jnp.maximum(load.mean(), 1e-9)
