"""Dummy-packet regulator (paper §III-C, eq. (8)).

The computation output is re-randomized: each slot, F(t) = A(t)*(1+B(t))
packets are pushed downstream from the regulator queue Y, where B(t) is
Bernoulli(eps_B) independent of everything in the network.  If Y holds fewer
than F(t) useful results, the difference is made up with *dummy* packets that
the network routes exactly like real ones.  This decouples the processed-data
queues from the raw-data queues, which is the key analytical device of
Theorems 3/5 — and, on TPUs, is precisely static-shape batch padding
(DESIGN.md §2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def regulator_push(Y: jax.Array, assigned: jax.Array, key: jax.Array,
                   eps_b: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One slot of the regulator for a vector of computation nodes.

    Args:
      Y: [NC] regulator queue lengths (useful computed results waiting).
      assigned: [NC] queries assigned to each node this slot (Ã^(n)(t)).
      key: PRNG key.
      eps_b: Bernoulli success probability (the ``arbitrarily small'' control).
        May be a Python float *or* a traced scalar — the fleet engine passes
        it as per-job data so sweeping eps_B reuses one compiled program.

    Returns:
      (Y_new, F, dummy): new queues, packets pushed downstream per node,
      and how many of them are dummies.
    """
    B = jax.random.bernoulli(key, eps_b, shape=assigned.shape).astype(Y.dtype)
    F = assigned * (1.0 + B)          # eq. (8): F^(n)(t) = (1+B^(n)(t)) Ã^(n)(t)
    useful = jnp.minimum(Y, F)
    dummy = F - useful
    return Y - useful, F, dummy
