"""Streaming sojourn-latency accumulators that ride the scan carry.

The serving subsystem scores *latency*, not just throughput, and it has to
do so under the fleet engine's O(1)-memory contract: no [T]-shaped arrays,
no per-query timestamps (queries are fluid — there is no object to stamp).
The stamps therefore live in the carry as two fixed-size structures:

  * a ring buffer of the cumulative-admitted curve A(s) over the last
    `horizon` slots, and
  * a delivered-weighted histogram of sojourn delays.

Under FIFO fluid service the sojourn of flow departing at slot t is the
horizontal distance between the cumulative curves: the smallest w with
A(t - w) <= D(t).  With A's recent history in the ring that distance is
one vectorized comparison, `sum(ring > D(t))` — every ring entry newer
than the crossing point exceeds D(t) and each contributes one slot of
delay.  Slots older than the ring report the cap (`horizon`), which makes
the estimate conservative rather than silently wrong, and slots before
the run started compare as A = 0 <= D, i.e. they contribute nothing.

Each slot's delivered mass lands in a `horizon/n_bins`-slot-wide histogram
bin of its delay; quantiles read the histogram's running-sum crossing and
report the bin's *upper* edge (again conservative).  The delay sum for the
mean is Kahan-compensated like every other long-horizon counter
(DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .queues import kahan_add


class LatencyStats(NamedTuple):
    """O(horizon + n_bins) latency state carried through the scan.

    ``ring[s]`` holds the cumulative admitted mass A at the end of slot s
    (mod `horizon`); ``hist[b]`` the delivered mass whose sojourn fell in
    bin b, with bin ``n_bins`` collecting everything at or past the cap.
    """

    ring: jax.Array        # [horizon] float32, cumulative-admitted curve
    hist: jax.Array        # [n_bins + 1] float32, delivered mass per bin
    sum_delay: jax.Array   # [] delivered-weighted delay sum (slots * mass)
    c_delay: jax.Array     # [] Kahan compensation for sum_delay

    @staticmethod
    def zero(horizon: int, n_bins: int) -> "LatencyStats":
        return LatencyStats(
            ring=jnp.zeros((horizon,), jnp.float32),
            hist=jnp.zeros((n_bins + 1,), jnp.float32),
            sum_delay=jnp.zeros((), jnp.float32),
            c_delay=jnp.zeros((), jnp.float32),
        )


def latency_update(lat: LatencyStats, t: jax.Array, cum_admitted: jax.Array,
                   cum_delivered: jax.Array, delivered_slot: jax.Array, *,
                   horizon: int, n_bins: int) -> LatencyStats:
    """One slot of the latency accumulator (post-slot cumulative counters).

    The FIFO virtual sojourn of the mass delivered this slot is the count
    of recent slots whose admitted curve still exceeds today's delivered
    curve, capped at `horizon`.  A strict `>` makes an empty system (A == D)
    report zero delay.
    """
    ring = lat.ring.at[t % horizon].set(cum_admitted)
    delay = jnp.sum(ring > cum_delivered).astype(jnp.float32)
    bin_w = max(horizon // n_bins, 1)
    b = jnp.minimum(delay / bin_w, n_bins).astype(jnp.int32)
    s, c = kahan_add(lat.sum_delay, lat.c_delay, delay * delivered_slot)
    return LatencyStats(ring=ring, hist=lat.hist.at[b].add(delivered_slot),
                        sum_delay=s, c_delay=c)


def latency_quantiles(hist: jax.Array, qs: Sequence[float], *,
                      horizon: int, n_bins: int) -> jax.Array:
    """Histogram quantiles in slots, as bin upper edges (conservative).

    Works on any delivered-weighted histogram with the `LatencyStats.hist`
    layout — the full-run accumulator or a per-window difference of two
    snapshots.  An all-zero histogram (nothing delivered) reports 0.
    """
    hist = hist.astype(jnp.float32)
    total = hist.sum(axis=-1, keepdims=True)
    cum = jnp.cumsum(hist, axis=-1)
    bin_w = max(horizon // n_bins, 1)
    out = []
    for q in qs:
        b = jnp.sum(cum < q * total, axis=-1)          # first bin crossing q
        edge = jnp.minimum((b + 1) * bin_w, horizon).astype(jnp.float32)
        out.append(jnp.where(total[..., 0] > 0, edge, 0.0))
    return jnp.stack(out, axis=-1)


def latency_mean(lat: LatencyStats) -> jax.Array:
    """Delivered-weighted mean sojourn in slots (0 if nothing delivered)."""
    total = lat.hist.sum()
    return jnp.where(total > 0, lat.sum_delay / jnp.maximum(total, 1e-9), 0.0)
