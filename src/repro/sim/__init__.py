from .simulator import (SimResult, simulate, sweep_rates, build_step,
                        make_step, make_trace_runner)
from .workload import poisson_arrivals, bernoulli_batch_arrivals, constant_arrivals

__all__ = ["SimResult", "simulate", "sweep_rates", "build_step",
           "make_step", "make_trace_runner",
           "poisson_arrivals", "bernoulli_batch_arrivals", "constant_arrivals"]
