from .simulator import SimResult, simulate, sweep_rates, build_step
from .workload import poisson_arrivals, bernoulli_batch_arrivals, constant_arrivals

__all__ = ["SimResult", "simulate", "sweep_rates", "build_step",
           "poisson_arrivals", "bernoulli_batch_arrivals", "constant_arrivals"]
