"""Slot-level simulator: the whole queue network as one `lax.scan` program.

The simulator is a single jit'd XLA program; sweeps over query rates run as
`vmap` over lambda, so a full Fig.-5b curve is one device launch.  The scan
body is shared between `simulate`, `sweep_rates`, and the fleet engine
(`repro.fleet.engine`), which swaps the O(T) trace outputs for online
accumulators.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import ComputeProblem
from repro.core.policies import PolicyConfig, slot_step
from repro.core.queues import NetState, StaticProblem, init_state
from .workload import poisson_arrivals


class SimResult(NamedTuple):
    final_state: NetState
    total_queue: jax.Array        # [T] backlog trajectory
    delivered: jax.Array          # [T] cumulative processed packets at d
    delivered_useful: jax.Array   # [T]
    computed: jax.Array           # [T] per-slot computations (sum over nodes)
    n_star: jax.Array             # [T] chosen comp node index (-1 if N/A)

    @property
    def avg_queue(self) -> jax.Array:
        """Time-average total backlog (the paper's stability metric)."""
        return self.total_queue.mean()

    def useful_rate(self, window: int | None = None) -> jax.Array:
        """Delivered-useful throughput over the trailing `window` slots.

        The baseline is the cumulative count at the last slot *before* the
        window begins (positive index T-1-window); for `window >= T` the
        implicit pre-trace baseline is 0, i.e. the full-trace average.  The
        explicit positive index replaces the seed's equivalent negative-index
        form `d[-window - 1]`, which sat exactly on the `-T` boundary at
        `window == T - 1` and would wrap for any larger un-guarded window.
        Regression-pinned in tests/test_fleet.py::TestUsefulRate.
        """
        d = self.delivered_useful
        T = d.shape[0]
        if window is None or window >= T:
            return d[-1] / T
        start = T - 1 - window        # last slot before the window begins
        return (d[-1] - d[start]) / window


def make_step(sp: StaticProblem, cfg: PolicyConfig) -> Callable:
    """The shared `lax.scan` body: one slot, emitting the metric tuple.

    Works for both a seed `StaticProblem` (numpy constants) and a fleet
    `PaddedProblem` (traced pytree leaves with edge/comp masks).
    """

    def step(state: NetState, inputs):
        arrivals, key = inputs
        state, metrics = slot_step(sp, cfg, state, arrivals, key)
        out = (metrics["total_queue"], metrics["delivered"],
               metrics["delivered_useful"], metrics["computed"],
               metrics["n_star"])
        return state, out

    return step


def make_trace_runner(sp: StaticProblem, cfg: PolicyConfig) -> Callable:
    """One jitted runner `(arrivals [T], key) -> SimResult` shared by
    `simulate` and (under vmap) `sweep_rates`."""
    step = make_step(sp, cfg)

    @jax.jit
    def run(arrivals: jax.Array, key: jax.Array) -> SimResult:
        T = arrivals.shape[0]
        keys = jax.random.split(key, T)
        state = init_state(sp)
        final, (tq, dlv, dlvu, comp, nstar) = jax.lax.scan(
            step, state, (arrivals, keys))
        return SimResult(final, tq, dlv, dlvu, comp, nstar)

    return run


def build_step(problem: ComputeProblem, cfg: PolicyConfig):
    """Backwards-compatible helper: (StaticProblem, scan body)."""
    sp = StaticProblem.build(problem)
    return sp, make_step(sp, cfg)


def simulate(problem: ComputeProblem, cfg: PolicyConfig, lam: float, T: int,
             seed: int = 0, arrivals: jax.Array | None = None) -> SimResult:
    """Run T slots with Poisson(lam) arrivals (or a supplied arrival trace)."""
    key = jax.random.key(seed)
    akey, skey = jax.random.split(key)
    if arrivals is None:
        arrivals = poisson_arrivals(akey, lam, T)
    elif arrivals.shape[0] != T:
        raise ValueError(
            f"arrivals trace has {arrivals.shape[0]} slots but T={T}")
    run = make_trace_runner(StaticProblem.build(problem), cfg)
    return run(arrivals, skey)


def sweep_rates(problem: ComputeProblem, cfg: PolicyConfig, lams, T: int,
                seed: int = 0) -> SimResult:
    """vmap the full simulation over a vector of query rates (Fig. 5b)."""
    lams = jnp.asarray(lams, jnp.float32)
    key = jax.random.key(seed)
    akey, skey = jax.random.split(key)
    arr = jax.vmap(lambda l, k: poisson_arrivals(k, l, T))(
        lams, jax.random.split(akey, lams.shape[0]))

    run = make_trace_runner(StaticProblem.build(problem), cfg)
    return jax.vmap(run)(arr, jax.random.split(skey, lams.shape[0]))
