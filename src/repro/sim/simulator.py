"""Slot-level simulator: the whole queue network as one `lax.scan` program.

The simulator is a single jit'd XLA program; sweeps over query rates run as
`vmap` over lambda, so a full Fig.-5b curve is one device launch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import ComputeProblem
from repro.core.policies import PolicyConfig, slot_step
from repro.core.queues import NetState, StaticProblem, init_state
from .workload import poisson_arrivals


class SimResult(NamedTuple):
    final_state: NetState
    total_queue: jax.Array        # [T] backlog trajectory
    delivered: jax.Array          # [T] cumulative processed packets at d
    delivered_useful: jax.Array   # [T]
    computed: jax.Array           # [T] per-slot computations (sum over nodes)
    n_star: jax.Array             # [T] chosen comp node index (-1 if N/A)

    @property
    def avg_queue(self) -> jax.Array:
        """Time-average total backlog (the paper's stability metric)."""
        return self.total_queue.mean()

    def useful_rate(self, window: int | None = None) -> jax.Array:
        """Delivered-useful throughput over the trailing `window` slots."""
        d = self.delivered_useful
        if window is None or window >= d.shape[0]:
            return d[-1] / d.shape[0]
        return (d[-1] - d[-window - 1]) / window


def build_step(problem: ComputeProblem, cfg: PolicyConfig) -> Callable:
    sp = StaticProblem.build(problem)

    def step(state: NetState, inputs):
        arrivals, key = inputs
        state, metrics = slot_step(sp, cfg, state, arrivals, key)
        out = (metrics["total_queue"], metrics["delivered"],
               metrics["delivered_useful"], metrics["computed"],
               metrics["n_star"])
        return state, out

    return sp, step


def simulate(problem: ComputeProblem, cfg: PolicyConfig, lam: float, T: int,
             seed: int = 0, arrivals: jax.Array | None = None) -> SimResult:
    """Run T slots with Poisson(lam) arrivals (or a supplied arrival trace)."""
    key = jax.random.key(seed)
    akey, skey = jax.random.split(key)
    if arrivals is None:
        arrivals = poisson_arrivals(akey, lam, T)
    sp, step = build_step(problem, cfg)

    @jax.jit
    def run(arrivals, key):
        keys = jax.random.split(key, T)
        state = init_state(sp)
        final, (tq, dlv, dlvu, comp, nstar) = jax.lax.scan(
            step, state, (arrivals, keys))
        return SimResult(final, tq, dlv, dlvu, comp, nstar)

    return run(arrivals, skey)


def sweep_rates(problem: ComputeProblem, cfg: PolicyConfig, lams, T: int,
                seed: int = 0) -> SimResult:
    """vmap the full simulation over a vector of query rates (Fig. 5b)."""
    lams = jnp.asarray(lams, jnp.float32)
    key = jax.random.key(seed)
    akey, skey = jax.random.split(key)
    arr = jax.vmap(lambda l, k: poisson_arrivals(k, l, T))(
        lams, jax.random.split(akey, lams.shape[0]))

    sp, step = build_step(problem, cfg)

    @jax.jit
    def run_one(arrivals, key):
        keys = jax.random.split(key, T)
        state = init_state(sp)
        final, (tq, dlv, dlvu, comp, nstar) = jax.lax.scan(
            step, state, (arrivals, keys))
        return SimResult(final, tq, dlv, dlvu, comp, nstar)

    return jax.vmap(run_one)(arr, jax.random.split(skey, lams.shape[0]))
