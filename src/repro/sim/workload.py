"""Query-arrival processes (paper §II-A: A(t) i.i.d., E[A(t)] = lambda)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def poisson_arrivals(key: jax.Array, lam: float | jax.Array, T: int) -> jax.Array:
    """[T] i.i.d. Poisson(lambda) query counts."""
    return jax.random.poisson(key, lam, shape=(T,)).astype(jnp.float32)


def bernoulli_batch_arrivals(key: jax.Array, lam: float | jax.Array, T: int,
                             batch: int = 4) -> jax.Array:
    """[T] arrivals in bursts of `batch` with rate lambda (bursty stress test)."""
    p = jnp.asarray(lam, jnp.float32) / batch
    b = jax.random.bernoulli(key, jnp.minimum(p, 1.0), shape=(T,))
    return b.astype(jnp.float32) * batch


def constant_arrivals(lam: float, T: int) -> jax.Array:
    """[T] deterministic fluid arrivals (useful for exact-capacity checks)."""
    return jnp.full((T,), lam, jnp.float32)
