"""Serving: trace-driven BP admission control on the fleet substrate.

Public API (DESIGN.md §9):
  trace:      QueryClass, TraceSpec, TraceState, TRACES, register_trace,
              get_trace, list_traces, draw_arrivals
  admission:  AdmissionConfig, AdmissionState, DEFAULT_ADMISSION
  scheduler:  make_serving_runner
  engine:     ServingJob, ServingResult, run_serving
  report:     serving_report, jsonl_line, write_stream_jsonl

The LLM continuous-batching demo engine formerly here lives in
`repro.launch.serve` (it serves models, not the paper's network).
"""
from .trace import (QueryClass, TRACES, TraceSpec, TraceState, draw_arrivals,
                    get_trace, list_traces, register_trace)
from .admission import (AdmissionConfig, AdmissionState, DEFAULT_ADMISSION,
                        admission_admit, admission_update)
from .scheduler import LAT_BINS, LAT_HORIZON, make_serving_runner
from .engine import ServingJob, ServingResult, run_serving
from .report import jsonl_line, serving_report, write_stream_jsonl

__all__ = [
    "QueryClass", "TraceSpec", "TraceState", "TRACES", "register_trace",
    "get_trace", "list_traces", "draw_arrivals",
    "AdmissionConfig", "AdmissionState", "DEFAULT_ADMISSION",
    "admission_admit", "admission_update",
    "make_serving_runner", "LAT_HORIZON", "LAT_BINS",
    "ServingJob", "ServingResult", "run_serving",
    "serving_report", "jsonl_line", "write_stream_jsonl",
]
