from .scheduler import Replica, Request, Scheduler, simulate
from .engine import Engine, ServeRequest

__all__ = ["Replica", "Request", "Scheduler", "simulate", "Engine",
           "ServeRequest"]
