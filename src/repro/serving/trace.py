"""Trace-driven workloads: per-class query streams compiled into the carry.

A `TraceSpec` declares the live traffic a serving run faces: a mixture of
query classes, each drawing from one of the registry's arrival models
(`fleet.scenarios.ARRIVAL_MODELS` — poisson, bernoulli_batch, constant,
markov_onoff), optionally modulated by a deterministic diurnal envelope.
Nothing here materializes a [T] trace: the generator is a per-slot
function of (key, t, TraceState) evaluated inside the scan body, so
serving runs ride the same chunked, donated-carry streaming machinery as
the fleet engine (DESIGN.md §9).

`TraceSpec` is a frozen, hashable dataclass for the same reason
`PolicyConfig` is: it keys the serving runner's memo cache, so two runs
over the same trace share one compiled program.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.fleet.scenarios import ARRIVAL_MODELS, ModState


@dataclasses.dataclass(frozen=True)
class QueryClass:
    """One class of the workload mixture.

    ``frac`` is the class's share of the job's offered rate `lam`; shares
    must sum to 1 so capacity sweeps stay comparable across traces.
    """

    name: str
    arrival: str = "poisson"       # ARRIVAL_MODELS key
    frac: float = 1.0

    def __post_init__(self):
        if self.arrival not in ARRIVAL_MODELS:
            raise ValueError(f"unknown arrival model {self.arrival!r}; "
                             f"known: {sorted(ARRIVAL_MODELS)}")
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(f"class frac must be in (0, 1], got {self.frac}")


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """A named workload: query-class mixture + optional diurnal envelope.

    ``diurnal_period`` > 0 modulates every class's rate by a sinusoid of
    that period (slots) and peak deviation ``diurnal_depth``; the envelope
    has mean 1 over a period, so the long-run offered rate is exactly
    `lam` and delivered-QPS stays scoreable against `policy_bound_exact`.
    """

    name: str
    classes: Tuple[QueryClass, ...]
    diurnal_period: int = 0
    diurnal_depth: float = 0.0
    description: str = ""

    def __post_init__(self):
        if not self.classes:
            raise ValueError("a trace needs at least one query class")
        tot = sum(c.frac for c in self.classes)
        if abs(tot - 1.0) > 1e-6:
            raise ValueError(f"class fracs must sum to 1, got {tot}")
        if not 0.0 <= self.diurnal_depth < 1.0:
            raise ValueError("diurnal_depth must be in [0, 1)")

    @property
    def n_classes(self) -> int:
        return len(self.classes)


class TraceState(NamedTuple):
    """Per-class arrival-modulation state carried through the scan.

    Each class owns its own ON/OFF phase so two markov_onoff classes burst
    independently; classes with memoryless arrivals simply never read it.
    """

    burst: jax.Array   # [K] float32, 1.0 = ON

    @staticmethod
    def init(spec: TraceSpec) -> "TraceState":
        return TraceState(jnp.ones((spec.n_classes,), jnp.float32))


def envelope(spec: TraceSpec, t: jax.Array) -> jax.Array:
    """Deterministic diurnal rate multiplier at slot t (mean 1)."""
    if spec.diurnal_period <= 0:
        return jnp.float32(1.0)
    phase = 2.0 * jnp.pi * t.astype(jnp.float32) / spec.diurnal_period
    return (1.0 + spec.diurnal_depth * jnp.sin(phase)).astype(jnp.float32)


def draw_arrivals(spec: TraceSpec, key: jax.Array, lam: jax.Array,
                  t: jax.Array, tr: TraceState, mod: ModState):
    """One slot of per-class query arrivals: ([K] arrivals, TraceState').

    Each class reuses its registry arrival model verbatim — the model sees
    a `ModState` whose scalar `burst` field is that class's own phase, and
    the updated phase is threaded back into `TraceState.burst[k]`.  The
    event-model fields of `mod` (link/comp chains) are never touched here.
    """
    env = envelope(spec, t)
    keys = jax.random.split(key, spec.n_classes)
    arrs, phases = [], []
    for k, qc in enumerate(spec.classes):
        fn = ARRIVAL_MODELS[qc.arrival]
        a, m2 = fn(keys[k], lam * (qc.frac * env), mod._replace(burst=tr.burst[k]))
        arrs.append(a)
        phases.append(m2.burst)
    return jnp.stack(arrs), TraceState(jnp.stack(phases))


# ---------------------------------------------------------------------------
# Trace registry: workloads declared as data, like the scenario registry.
# ---------------------------------------------------------------------------

TRACES: Dict[str, TraceSpec] = {}


def register_trace(spec: TraceSpec) -> TraceSpec:
    if spec.name in TRACES:
        raise ValueError(f"trace {spec.name!r} already registered")
    TRACES[spec.name] = spec
    return spec


def get_trace(name: str) -> TraceSpec:
    try:
        return TRACES[name]
    except KeyError:
        raise KeyError(
            f"unknown trace {name!r}; known: {sorted(TRACES)}") from None


def list_traces() -> list[str]:
    return sorted(TRACES)


register_trace(TraceSpec(
    "steady", (QueryClass("q", "poisson"),),
    description="Single Poisson class — the open-loop fleet workload."))
register_trace(TraceSpec(
    "bursty", (QueryClass("q", "markov_onoff"),),
    description="Single Markov ON-OFF class: correlated bursts, mean rate "
                "exactly lam (the acceptance trace)."))
register_trace(TraceSpec(
    "diurnal_mix", (QueryClass("interactive", "poisson", 0.6),
                    QueryClass("batch", "bernoulli_batch", 0.4)),
    diurnal_period=2000, diurnal_depth=0.3,
    description="Poisson + batch mixture under a mean-1 diurnal envelope."))
register_trace(TraceSpec(
    "bursty_mix", (QueryClass("bursty", "markov_onoff", 0.5),
                   QueryClass("steady", "poisson", 0.5)),
    description="Half bursty, half steady — the fairness stress: shedding "
                "must not starve either class."))
