"""Serving report: delivered QPS and sojourn latency scored against the
exact regulated LP bound, plus the per-chunk JSONL stream writer.

The yardstick is the fleet's (`fleet.report.policy_bound_exact`): the
serving subsystem does not get its own notion of capacity, it is scored
against the same LP the open-loop sweeps use — `delivered_qps / bound` is
the headline number the bench gates (`scripts/check_bench.py --mode
serving`).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.fleet.engine import VerdictConfig
from repro.fleet.report import policy_bound_exact
# Canonical JSONL helpers live in the telemetry plane's schema module
# (DESIGN.md §11); re-exported here so PR-6 call sites keep working.
from repro.obs.schema import jsonl_line, write_stream_jsonl  # noqa: F401
from .admission import AdmissionConfig
from .engine import ServingJob, ServingResult, run_serving


def serving_report(scenario: str, policy: str, trace: str,
                   rate_fracs: Sequence[float], seeds: Sequence[int],
                   T: int, chunk: int = 512, window: int | None = None,
                   eps_b: float = 0.05, topo_seed: int = 0,
                   backend: str = "xla", interpret: bool = True,
                   devices=None, verdict: VerdictConfig | None = None,
                   admission: AdmissionConfig | None = None,
                   stream: bool = False) -> dict:
    """Sweep offered-rate fractions of the exact bound over one trace.

    Returns ``{"bound_exact", "rows": {frac: {...}}, "result"}`` where each
    row aggregates the seeds at that rate: delivered QPS (mean/min over
    seeds) and its ratio to the bound, shed fraction, p50/p99/mean sojourn,
    gate statistics, verdict names.  `result` is the raw `ServingResult`
    (stream records included when ``stream`` is on).
    """
    bound = policy_bound_exact(scenario, policy, eps_b, topo_seed)
    jobs = [ServingJob(scenario=scenario, policy=policy, trace=trace,
                       lam=frac * bound, seed=seed, topo_seed=topo_seed,
                       eps_b=eps_b, backend=backend, interpret=interpret)
            for frac in rate_fracs for seed in seeds]
    res = run_serving(jobs, T, chunk=chunk, window=window, devices=devices,
                      verdict=verdict, admission=admission, stream=stream)

    rows: dict = {}
    per_seed = len(seeds)
    for fi, frac in enumerate(rate_fracs):
        ms = res.metrics[fi * per_seed:(fi + 1) * per_seed]

        def agg(name, red=np.mean):
            return float(red([m[name] for m in ms]))

        rows[f"{frac:g}"] = {
            "offered": float(frac * bound),
            "delivered_qps": agg("delivered_qps"),
            "delivered_qps_min": agg("delivered_qps", np.min),
            "delivered_over_bound": agg("delivered_qps") / bound,
            "admitted_rate": agg("admitted_rate"),
            "shed_frac": agg("shed_frac"),
            "shed_frac_max": agg("shed_frac", np.max),
            "p50_sojourn": agg("p50_sojourn"),
            "p99_sojourn": agg("p99_sojourn"),
            "p99_sojourn_max": agg("p99_sojourn", np.max),
            "mean_sojourn": agg("mean_sojourn"),
            "gate_open_frac": agg("gate_open_frac"),
            "gate_flips": agg("gate_flips", np.sum),
            "verdicts": sorted(set(_verdict_names(ms))),
        }
    return {"scenario": scenario, "policy": policy, "trace": trace,
            "eps_b": eps_b, "bound_exact": float(bound),
            "T": res.T, "n_sims": res.n_sims, "rows": rows, "result": res}


def _verdict_names(metrics) -> list:
    from repro.core.queues import VERDICT_NAMES
    return [VERDICT_NAMES[int(m["verdict"])] for m in metrics]


