"""Backpressure serving scheduler — the paper's π₃ mapped onto multi-replica
LLM inference (DESIGN.md §2).

Replica r = computation node with capacity C_r tokens/tick.  An incoming
request (prompt of p tokens, expected output of g tokens) is the "query";
its pending prefill work is the raw queue X_r, its pending decode work the
processed queue D_r, and H_r is the virtual admission queue (eq. 10):

    dispatch:  r* = argmin_r [ (1+eps_B) * D_r + X_r + H_r ]      (eq. 9)
    per tick:  H_r <- [H_r + admitted_work_r - C_r]^+             (eq. 10)

Replicas are fluid FIFO single-servers (work in token units, service =
speed * C_r per tick) — completion times are exact for FIFO.  Baselines:
round-robin and join-shortest-queue (by active request count).  Replicas
may be heterogeneous and may straggle, the regimes where backlog-aware
dispatch wins.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrival: int                  # tick index
    prompt: int                   # prefill tokens
    gen: int                      # decode tokens (work-weighted)
    replica: int = -1
    done_at: Optional[int] = None

    @property
    def work(self) -> float:
        return float(self.prompt + 4.0 * self.gen)   # decode ~4x cost/token


@dataclasses.dataclass
class Replica:
    cap: float                    # token-work units / tick
    speed: float = 1.0            # straggler multiplier (<1 = slow)

    def __post_init__(self):
        self.served = 0.0         # cumulative work served
        self.enqueued = 0.0       # cumulative work admitted
        self.X = 0.0              # pending prefill work
        self.D = 0.0              # pending decode work
        self.H = 0.0              # admission virtual queue
        self.admitted_tick = 0.0
        self.fifo: List[tuple] = []   # (finish_work_mark, request)

    def backlog(self, eps_b: float) -> float:
        return (1.0 + eps_b) * self.D + self.X + self.H


class Scheduler:
    def __init__(self, replicas: List[Replica], policy: str = "bp",
                 eps_b: float = 0.01):
        self.replicas = replicas
        self.policy = policy
        self.eps_b = eps_b
        self._rr = 0

    def dispatch(self, req: Request) -> int:
        if self.policy == "rr":
            r = self._rr % len(self.replicas)
            self._rr += 1
        elif self.policy == "jsq":
            r = int(np.argmin([len(rep.fifo) for rep in self.replicas]))
        elif self.policy == "bp":
            r = int(np.argmin([rep.backlog(self.eps_b)
                               for rep in self.replicas]))
        else:
            raise ValueError(self.policy)
        rep = self.replicas[r]
        req.replica = r
        rep.enqueued += req.work
        rep.X += req.prompt
        rep.D += 4.0 * req.gen
        rep.admitted_tick += req.work
        rep.fifo.append((rep.enqueued, req))
        return r

    def tick(self, now: int) -> List[Request]:
        finished = []
        for rep in self.replicas:
            rep.H = max(rep.H + rep.admitted_tick - rep.cap, 0.0)   # eq. 10
            rep.admitted_tick = 0.0
            budget = rep.cap * rep.speed
            rep.served += budget
            # drain X first (prefill precedes decode), then D
            dx = min(rep.X, budget)
            rep.X -= dx
            rep.D = max(rep.D - (budget - dx), 0.0)
            while rep.fifo and rep.fifo[0][0] <= rep.served:
                _, req = rep.fifo.pop(0)
                req.done_at = now
                finished.append(req)
        return finished


def simulate(policy: str, *, n_replicas: int = 8, ticks: int = 3000,
             load: float = 0.85, seed: int = 0, straggler: int = -1,
             hetero: bool = False, eps_b: float = 0.01) -> dict:
    """Poisson request trace at target utilization -> latency percentiles."""
    rng = np.random.default_rng(seed)
    caps = np.full(n_replicas, 1000.0)
    if hetero:
        caps = rng.choice([500.0, 1000.0, 2000.0], size=n_replicas)
    reps = [Replica(cap=float(c)) for c in caps]
    if straggler >= 0:
        reps[straggler].speed = 0.3
    eff_cap = sum(r.cap * r.speed for r in reps)
    mean_work = 1088 + 4.0 * 272               # E[prompt] + 4 E[gen]
    rate = load * eff_cap / mean_work          # requests per tick

    sched = Scheduler(reps, policy=policy, eps_b=eps_b)
    done: List[Request] = []
    rid = 0
    for t in range(ticks):
        for _ in range(rng.poisson(rate)):
            req = Request(rid, t, prompt=int(rng.integers(128, 2048)),
                          gen=int(rng.integers(32, 512)))
            sched.dispatch(req)
            rid += 1
        done.extend(sched.tick(t))
    lat = np.array([r.done_at - r.arrival for r in done
                    if r.done_at is not None], dtype=np.float64)
    backlog = sum(rep.X + rep.D for rep in reps)
    return {
        "completed": len(done), "submitted": rid,
        "p50": float(np.percentile(lat, 50)) if len(lat) else float("inf"),
        "p99": float(np.percentile(lat, 99)) if len(lat) else float("inf"),
        "mean": float(lat.mean()) if len(lat) else float("inf"),
        "residual_backlog": float(backlog),
    }
