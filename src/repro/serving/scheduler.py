"""The serving scheduler: trace -> admission -> bp_slot -> latency scoring.

`make_serving_runner` is the serving twin of `fleet.make_stream_runner`:
it compiles one slot program per (policy, trace, admission, shapes) and
exposes the same chunked surface the fleet engine drives with a donated
carry (`init_carry` / `chunk_step` / `finalize`), so serving runs ride
`jit(shard_map(vmap(chunk_step)))` unchanged (`fleet.make_group_launch`
with ``n_step_args=6``).

One slot of serving (DESIGN.md §9):

  1. the trace draws per-class query arrivals (`serving.trace`),
  2. the admission gate sheds or admits them uniformly
     (`serving.admission`),
  3. the event model perturbs capacities (shared `fleet.scenarios` event
     chains in `ModState`),
  4. `slot_step` makes the routing + load-balance + regulator decision —
     the PR-4 `bp_slot` kernel family when ``cfg.backend == "pallas"``,
     bit-identical XLA otherwise (DESIGN.md §7),
  5. the latency accumulator stamps this slot's admitted mass into the
     A-curve ring and bins the delivered mass by FIFO sojourn
     (`core.latency`),
  6. the streaming stats + drift verdict update exactly as in the fleet
     runner, and the admission gate re-evaluates at window boundaries.

The arrival model is *not* a switch code here — the trace mixture is
Python-level structure (classes unrolled in the slot body), which is why
the runner is memoized on the `TraceSpec`.  Event models stay `lax.switch`
codes so heterogeneous scenarios share programs, as in the fleet.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.latency import (LatencyStats, latency_mean, latency_quantiles,
                                latency_update)
from repro.core.policies import PolicyConfig, slot_step
from repro.core.queues import (DriftStats, VERDICT_UNDECIDED,
                               drift_verdict_update, init_state, kahan_add)
from repro.fleet.batching import PaddedProblem
from repro.fleet.engine import (DEFAULT_VERDICT, StreamStats, VerdictConfig)
from repro.fleet.scenarios import EVENT_MODELS, EVENT_MODEL_ORDER, ModState
from .admission import (AdmissionConfig, AdmissionState, DEFAULT_ADMISSION,
                        admission_admit, admission_update)
from .trace import TraceSpec, TraceState, draw_arrivals

# Latency-stamp defaults: a 1024-slot A-curve ring binned 8 slots wide.
# The ring cap must exceed the steady-state sojourn at the gated operating
# point (~p99 < 1024 slots on the paper grid at 0.95 load) or quantiles
# saturate at the cap — conservative, but uninformative.
LAT_HORIZON = 1024
LAT_BINS = 128


def make_serving_runner(cfg: PolicyConfig, trace: TraceSpec, T: int,
                        chunk: int = 512,
                        window: int | None = None,
                        verdict: VerdictConfig | None = None,
                        admission: AdmissionConfig | None = None,
                        lat_horizon: int = LAT_HORIZON,
                        lat_bins: int = LAT_BINS):
    """Build the memoized serving runner for one (policy, trace) program.

    Returned object (duck-compatible with the fleet runner where it
    matters): ``run(pp, lam, eps_b, ekind, key)`` closed program, plus the
    chunked surface ``init_carry(pp)``, ``chunk_step(pp, lam, eps_b,
    ekind, key, carry)``, ``finalize(lam, eps_b, carry)``, ``probe(carry)``
    (small per-sim leaves for between-chunk streaming records), and the
    shape attributes ``T/window/chunk/n_chunks``.
    """
    return _make_serving_runner(cfg, trace, T, chunk, window,
                                verdict or DEFAULT_VERDICT,
                                admission or DEFAULT_ADMISSION,
                                lat_horizon, lat_bins)


@functools.lru_cache(maxsize=64)
def _make_serving_runner(cfg: PolicyConfig, trace: TraceSpec, T: int,
                         chunk: int, window: int | None,
                         verdict: VerdictConfig, admission: AdmissionConfig,
                         lat_horizon: int, lat_bins: int):
    chunk = max(1, min(chunk, T))
    n_chunks = -(-T // chunk)
    T_eff = n_chunks * chunk
    win = T_eff // 2 if window is None else min(window, T_eff)
    win = max(win, 1)
    mark = T_eff - win
    q3_lo, q4_lo = T_eff // 2, (3 * T_eff) // 4
    vcfg = verdict
    vwin = chunk if vcfg.window <= 0 else max(1, min(vcfg.window, T_eff))
    vburn = 2 * vwin if vcfg.burn_in <= 0 else vcfg.burn_in
    acfg = admission
    awin = chunk if acfg.window <= 0 else max(1, min(acfg.window, T_eff))
    aburn = 2 * awin if acfg.burn_in <= 0 else acfg.burn_in
    K = trace.n_classes

    event_branches = tuple(EVENT_MODELS[k] for k in EVENT_MODEL_ORDER)

    def slot(pp, lam, eps_b, ekind, key, carry):
        state, stats, drift, mod, tr, adm, lat, t = carry
        kt = jax.random.fold_in(key, t)
        k_cls, k_ev, k_step = jax.random.split(kt, 3)
        class_arr, tr2 = draw_arrivals(trace, k_cls, lam, t, tr, mod)
        adm2, admitted = admission_admit(adm, class_arr)
        esc, csc, mod2 = jax.lax.switch(ekind, event_branches, pp, t, k_ev,
                                        mod)
        new_state, m = slot_step(pp.with_capacity_scales(esc, csc), cfg,
                                 state, admitted, k_step, eps_b=eps_b)
        tq = m["total_queue"]
        sq, cq = kahan_add(stats.sum_queue, stats.c_queue, tq)
        s3, c3 = kahan_add(stats.sum_queue_q3, stats.c_q3,
                           tq * ((t >= q3_lo) & (t < q4_lo)))
        s4, c4 = kahan_add(stats.sum_queue_q4, stats.c_q4, tq * (t >= q4_lo))
        new_stats = StreamStats(
            sum_queue=sq, c_queue=cq,
            sum_queue_q3=s3, c_q3=c3,
            sum_queue_q4=s4, c_q4=c4,
            max_queue=jnp.maximum(stats.max_queue, tq),
            useful_at_mark=jnp.where(t == mark - 1, m["delivered_useful"],
                                     stats.useful_at_mark),
        )
        new_drift = drift_verdict_update(
            drift, t, tq, m["delivered_useful"], lam,
            window=vwin, burn_in=vburn, k_stable=vcfg.k_stable,
            k_unstable=vcfg.k_unstable, drift_tol=vcfg.drift_tol,
            gap_tol=vcfg.gap_tol)
        # The latency stamps compare the *admitted* cumulative curve (the
        # shed mass never sojourns) against useful deliveries.
        lat2 = latency_update(lat, t, adm2.admitted.sum(),
                              new_state.delivered_useful,
                              m["delivered_useful"] - state.delivered_useful,
                              horizon=lat_horizon, n_bins=lat_bins)
        adm3 = admission_update(acfg, adm2, t, tq, new_state.delivered_useful,
                                lam, new_drift, window=awin, burn_in=aburn)
        return (new_state, new_stats, new_drift, mod2, tr2, adm3, lat2,
                t + 1), None

    def init_carry(pp: PaddedProblem):
        return (init_state(pp), StreamStats.zero(), DriftStats.zero(),
                ModState.init(pp), TraceState.init(trace),
                AdmissionState.zero(K), LatencyStats.zero(lat_horizon,
                                                          lat_bins),
                jnp.int32(0))

    def chunk_step(pp: PaddedProblem, lam, eps_b, ekind, key, carry):
        """Advance one chunk; jitted by the engine with the carry donated
        (`make_group_launch(runner, mesh, n_step_args=6)`)."""
        body = functools.partial(slot, pp, lam, eps_b, ekind, key)
        carry, _ = jax.lax.scan(lambda c, x: body(c), carry, xs=None,
                                length=chunk)
        return carry

    def finalize(lam, eps_b, carry) -> Dict[str, jax.Array]:
        state, stats, drift, _, _, adm, lat, t = carry
        tf = jnp.maximum(t.astype(jnp.float32), 1.0)
        admitted_total = adm.admitted.sum()
        shed_total = adm.shed.sum()
        offered_total = admitted_total + shed_total
        decided = drift.verdict != VERDICT_UNDECIDED
        qtiles = latency_quantiles(lat.hist, (0.5, 0.99),
                                   horizon=lat_horizon, n_bins=lat_bins)
        return {
            "offered": jnp.asarray(lam, jnp.float32),
            "eps_b": jnp.asarray(eps_b, jnp.float32),
            # Delivered QPS: trailing-window useful rate, the fleet metric.
            "delivered_qps": (state.delivered_useful - stats.useful_at_mark)
            / win,
            "delivered_useful": state.delivered_useful,
            "admitted_total": admitted_total,
            "shed_total": shed_total,
            "admitted_rate": admitted_total / tf,
            "shed_frac": shed_total / jnp.maximum(offered_total, 1e-9),
            "p50_sojourn": qtiles[..., 0],
            "p99_sojourn": qtiles[..., 1],
            "mean_sojourn": latency_mean(lat),
            "mean_queue": stats.sum_queue / tf,
            "mean_queue_tail": stats.sum_queue_q4 / max(T_eff - q4_lo, 1),
            "max_queue": stats.max_queue,
            "gate_open_frac": adm.gate_slots / tf,
            "gate": adm.gate,
            "gate_flips": adm.flips.astype(jnp.float32),
            "verdict": drift.verdict.astype(jnp.float32),
            "decided_at_slot": jnp.where(decided, drift.decided_at,
                                         T_eff).astype(jnp.float32),
            # Per-class fairness readout: each class's admitted share of
            # its own offered mass ([K] leaves; engine rows keep the list).
            "class_admitted": adm.admitted,
            "class_shed": adm.shed,
            "class_admit_frac": adm.admitted
            / jnp.maximum(adm.admitted + adm.shed, 1e-9),
        }

    def probe(carry) -> Dict[str, jax.Array]:
        """Small per-sim leaves read back between chunk launches — the
        source of the per-chunk JSONL stream records (cumulative values;
        the engine differences consecutive probes into windowed metrics)."""
        state, _, drift, _, _, adm, lat, t = carry
        return {
            "t": t,
            "delivered_useful": state.delivered_useful,
            "admitted_total": adm.admitted.sum(),
            "shed_total": adm.shed.sum(),
            "gate": adm.gate,
            "gate_flips": adm.flips,
            "verdict": drift.verdict,
            "hist": lat.hist,
        }

    def run(pp: PaddedProblem, lam, eps_b, ekind, key) -> Dict[str, jax.Array]:
        carry = init_carry(pp)

        def chunk_body(c, _):
            return chunk_step(pp, lam, eps_b, ekind, key, c), None
        carry, _ = jax.lax.scan(chunk_body, carry, xs=None, length=n_chunks)
        return finalize(lam, eps_b, carry)

    run.T = T_eff
    run.window = win
    run.chunk = chunk
    run.n_chunks = n_chunks
    run.admission_window = awin
    run.admission_burn_in = aburn
    run.verdict_window = vwin
    run.lat_horizon = lat_horizon
    run.lat_bins = lat_bins
    run.n_classes = K
    run.init_carry = init_carry
    run.chunk_step = chunk_step
    run.finalize = finalize
    run.probe = probe
    run.verdict_of = lambda carry: carry[2].verdict
    return run
