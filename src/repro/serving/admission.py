"""Admission control: shed queries on drift toward instability, re-admit
on recovery.

The gate thresholds on the same Lyapunov-drift evidence the PR-5 streaming
verdict latches on (`DriftStats`, DESIGN.md §8), but where the verdict is a
one-way latch (decide once, freeze), admission must be *reversible*: an
overloaded network sheds, a recovered one re-admits.  So the gate consumes
the `DriftStats` leaf that is itself reversible — ``unstable_run``, the
consecutive-window streak of drift evidence that latches UNSTABLE once it
reaches ``k_unstable`` — as corroborating shed evidence ("the drift slope
is latching toward UNSTABLE"), alongside its own windowed, re-anchored
statistics: backlog growth per slot since the last admission window and
the admitted-vs-delivered throughput gap, both scaled by max(lam, 1) like
the verdict tolerances.  The terminal ``verdict`` latch deliberately does
NOT hold the gate shut: once shedding starts, the network's true offered
rate is the *admitted* rate, not `lam`, so the open-loop verdict (which
keeps scoring `lam`) may latch UNSTABLE during an outage and stay latched
forever — correct as a statement about the open-loop rate, useless as a
re-admission signal.  Recovery is judged by the gate's own windowed
evidence (drain slope), which the latch cannot veto.

Overload evidence is a *conjunction*, exactly like the verdict's two
tests: the backlog must grow (windowed drift slope >= `shed_tol` x
max(lam, 1)) AND delivery must fall behind admission (windowed
admitted-minus-delivered gap >= `gap_tol` x max(lam, 1)).  Either test
alone false-trips under bursty traffic — backlog wanders without losing
throughput — but a genuinely overloaded network fails both at once.

Hysteresis by construction: the gate only moves at admission-window
boundaries after a burn-in, needs `k_shed` consecutive overloaded windows
to close and `k_readmit` consecutive recovered windows to open, and a
flip resets the opposing evidence run.  Two consecutive flips are
therefore always at least `min(k_shed, k_readmit)` windows apart — the
no-flip-flop property `tests/test_serving.py` asserts.  The shed/readmit
tolerances leave a dead band (`readmit_tol < shed_tol`) so slope noise
near the threshold cannot oscillate the gate.

Shedding is class-uniform (one multiplicative gate for every query class):
graceful degradation that cannot starve any class — fairness across the
mixture is inherited rather than tuned.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.queues import kahan_add


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Gate parameters.  Frozen/hashable: keys the serving-runner memo.

    ``window <= 0`` resolves to the runner's chunk length, aligning gate
    decisions with the boundaries the engine's Python loop can observe —
    the same convention as `VerdictConfig.window`.
    """

    window: int = 0           # slots between gate decisions
    burn_in: int = 0          # slots before evidence counts; <= 0 -> 2 windows
                              # (skips the queue fill-up transient)
    shed_tol: float = 0.10    # windowed drift slope that reads as overload,
                              # x max(lam, 1) (5x the verdict drift_tol: the
                              # gate reacts to sharper growth than the latch)
    gap_tol: float = 0.05     # windowed admitted-vs-delivered gap that
                              # corroborates overload, x max(lam, 1)
    readmit_tol: float = 0.02  # slope at or below this reads as recovered
    k_shed: int = 2           # consecutive overloaded windows to close
    k_readmit: int = 2        # consecutive recovered windows to reopen


DEFAULT_ADMISSION = AdmissionConfig()


class AdmissionState(NamedTuple):
    """Per-sim gate state + per-class admitted/shed counters (all O(K)).

    The counters are Kahan-compensated like the delivery counters
    (DESIGN.md §4) — admitted mass is the latency accumulator's A-curve,
    so it must stay exact over long horizons.
    """

    gate: jax.Array        # [] float32, 1.0 = admitting, 0.0 = shedding
    q_mark: jax.Array      # [] backlog at the last admission boundary
    a_mark: jax.Array      # [] admitted_total at the last boundary
    d_mark: jax.Array      # [] delivered_useful at the last boundary
    over_run: jax.Array    # [] int32: consecutive overloaded windows
    under_run: jax.Array   # [] int32: consecutive recovered windows
    flips: jax.Array      # [] int32: gate transitions so far
    last_flip: jax.Array   # [] int32: slot of the last transition (-1: none)
    last_slope: jax.Array  # [] windowed drift slope at the last boundary
    admitted: jax.Array    # [K] per-class admitted mass
    admitted_c: jax.Array  # [K] Kahan compensation
    shed: jax.Array        # [K] per-class shed mass
    shed_c: jax.Array      # [K]
    gate_slots: jax.Array  # [] slots spent with the gate open

    @staticmethod
    def zero(n_classes: int) -> "AdmissionState":
        z = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        zk = jnp.zeros((n_classes,), jnp.float32)
        return AdmissionState(gate=jnp.ones((), jnp.float32), q_mark=z,
                              a_mark=z, d_mark=z,
                              over_run=zi, under_run=zi, flips=zi,
                              last_flip=jnp.full((), -1, jnp.int32),
                              last_slope=z, admitted=zk, admitted_c=zk,
                              shed=zk, shed_c=zk, gate_slots=z)


def admission_admit(adm: AdmissionState, class_arrivals: jax.Array):
    """Apply the current gate to one slot's per-class arrivals.

    Returns ``(state', admitted_total)`` — the scalar admitted mass is what
    actually enters the network this slot.
    """
    admitted_k = class_arrivals * adm.gate
    shed_k = class_arrivals - admitted_k
    a, ac = kahan_add(adm.admitted, adm.admitted_c, admitted_k)
    s, sc = kahan_add(adm.shed, adm.shed_c, shed_k)
    adm2 = adm._replace(admitted=a, admitted_c=ac, shed=s, shed_c=sc,
                        gate_slots=adm.gate_slots + adm.gate)
    return adm2, admitted_k.sum()


def admission_update(cfg: AdmissionConfig, adm: AdmissionState, t: jax.Array,
                     total_q: jax.Array, delivered_useful: jax.Array,
                     lam: jax.Array, drift, *, window: int,
                     burn_in: int) -> AdmissionState:
    """One slot of the gate machinery; the gate only moves at boundaries.

    Called with the post-slot backlog, cumulative useful deliveries, and
    the sim's post-slot `DriftStats` (its ``unstable_run`` streak is shed
    evidence).  `window`/`burn_in` are the resolved admission window and
    burn-in (the config's, or chunk-derived defaults).
    """
    boundary = (t + 1) % window == 0
    counted = boundary & (t + 1 >= burn_in)
    scale = jnp.maximum(lam, 1.0)
    admitted_total = adm.admitted.sum()
    slope = (total_q - adm.q_mark) / window
    gap = (admitted_total - adm.a_mark
           - (delivered_useful - adm.d_mark)) / window
    # The verdict's anchored evidence streak corroborates the FIRST close
    # only (last_flip < 0): while no shedding has happened the anchored
    # statistics measure the true offered rate, but after any intervention
    # they keep scoring `lam` against a history the gate already altered —
    # they never forget the outage deficit, so they must not re-trip the
    # gate after recovery.  Post-flip, the windowed conjunction governs.
    over_ev = ((slope >= cfg.shed_tol * scale)
               & (gap >= cfg.gap_tol * scale)) | \
        ((drift.unstable_run >= 1) & (adm.last_flip < 0))
    under_ev = slope <= cfg.readmit_tol * scale
    over = jnp.where(counted, jnp.where(over_ev, adm.over_run + 1, 0),
                     adm.over_run)
    under = jnp.where(counted, jnp.where(under_ev, adm.under_run + 1, 0),
                      adm.under_run)
    close = counted & (adm.gate > 0.5) & (over >= cfg.k_shed)
    open_ = counted & (adm.gate <= 0.5) & (under >= cfg.k_readmit)
    flip = close | open_
    return adm._replace(
        gate=jnp.where(close, 0.0, jnp.where(open_, 1.0, adm.gate)),
        q_mark=jnp.where(boundary, total_q, adm.q_mark),
        a_mark=jnp.where(boundary, admitted_total, adm.a_mark),
        d_mark=jnp.where(boundary, delivered_useful, adm.d_mark),
        # A flip restarts the opposing evidence run from scratch — the
        # hysteresis that keeps consecutive flips >= k windows apart.
        over_run=jnp.where(open_, 0, over),
        under_run=jnp.where(close, 0, under),
        flips=adm.flips + flip.astype(jnp.int32),
        last_flip=jnp.where(flip, (t + 1).astype(jnp.int32), adm.last_flip),
        last_slope=jnp.where(boundary, slope, adm.last_slope),
    )
