"""Serving engine: batched trace-driven runs on the fleet substrate.

`run_serving` is the serving twin of `fleet.run_fleet`: jobs are grouped
by (semantic policy key, trace) — the axes that change Python-level
control flow — padded to the device mesh, and driven as a Python loop of
`jit(shard_map(vmap(chunk_step)))` launches with the carry donated between
launches (`fleet.make_group_launch` with ``n_step_args=6``).  The
scenario's *event* model (capacity perturbations) is per-job traced data
exactly as in the fleet; the scenario's arrival model is superseded by the
job's `TraceSpec` (live query traffic is what serving is about).

Between chunk launches the engine dispatches a small probe of the carry
(cumulative delivered/admitted/shed, gate, verdict, the latency histogram)
through the telemetry plane's io_callback emitter (`repro.obs.emitter`,
DESIGN.md §11), which differences consecutive probes into *windowed*
per-chunk records — delivered QPS, shed fraction, p99 sojourn, verdict
counts, each a median across the group's sims — validated against the
versioned stream schema (`repro.obs.schema`).  With ``stream=True`` these
land in `ServingResult.stream_records`, one dict per chunk boundary,
ready to be written as JSONL (`serving.report.write_stream_jsonl`);
``stream_path`` appends them live for `capacity_report --follow`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import ComputeProblem
from repro.core.policies import PolicyConfig
from repro.core.queues import VERDICT_NAMES
from repro.fleet.batching import PadDims, pad_problem
from repro.fleet.engine import (VerdictConfig, _policy_group_key,
                                make_group_launch)
from repro.fleet.scenarios import event_code, get_scenario
from .admission import AdmissionConfig
from .scheduler import make_serving_runner
from .trace import get_trace


@dataclasses.dataclass(frozen=True)
class ServingJob:
    """One serving run: a scenario instance facing a live query trace."""

    scenario: str = "paper_grid"
    policy: str = "pi3_reg"
    trace: str = "bursty"
    lam: float = 1.0              # long-run offered QPS across all classes
    seed: int = 0
    topo_seed: int = 0
    eps_b: float = 0.05
    pairing: str = "fifo"
    threshold: float = 0.0
    fixed_node: int = 0
    backend: str = "xla"          # slot-decision backend: "xla" | "pallas"
    interpret: bool = True

    def policy_config(self) -> PolicyConfig:
        return PolicyConfig(
            name=self.policy, eps_b=self.eps_b, pairing=self.pairing,
            threshold=self.threshold, fixed_node=self.fixed_node,
            wireless=get_scenario(self.scenario).wireless,
            backend=self.backend, interpret=self.interpret)


@dataclasses.dataclass
class ServingResult:
    jobs: List[ServingJob]
    metrics: List[Dict[str, float]]   # one dict per job, same order;
                                      # per-class leaves are lists of floats
    n_programs: int
    n_sims: int
    dims: PadDims
    T: int
    window: int
    stream_records: List[dict] = dataclasses.field(default_factory=list)
    resumed_from: int | None = None    # checkpoint step this run restored
                                       # (DESIGN.md §12); None = fresh
    degraded: Dict[int, str] = dataclasses.field(default_factory=dict)
                                  # job index -> reason for jobs whose lanes
                                  # sat on a dropped host — serving lanes
                                  # are not parked (the 8-tuple carry has
                                  # no rewriter), only flagged: their
                                  # metrics are untrustworthy, not silent
    recovery_plan: object | None = None   # runtime.fault.RecoveryPlan
    n_fault_retries: int = 0

    def column(self, name: str) -> np.ndarray:
        return np.array([m[name] for m in self.metrics])

    def verdicts(self) -> List[str]:
        return [VERDICT_NAMES[int(m["verdict"])] for m in self.metrics]


def _group_key(job: ServingJob):
    """Program-forking axes: the fleet's semantic policy key + the trace
    (the class mixture is unrolled Python-level structure in the slot)."""
    return (_policy_group_key(job), job.trace)


@functools.lru_cache(maxsize=64)
def _probe_launch(runner, mesh: Mesh):
    """Jit the between-chunk probe readout (no donation — read-only)."""
    spec = P("fleet")
    return jax.jit(shard_map(jax.vmap(runner.probe), mesh=mesh,
                             in_specs=(spec,), out_specs=spec,
                             check_rep=False))


def run_serving(jobs: Sequence[ServingJob], T: int, chunk: int = 512,
                window: int | None = None, devices=None,
                dims: PadDims | None = None,
                verdict: VerdictConfig | None = None,
                admission: AdmissionConfig | None = None,
                stream: bool = False,
                stream_log: Callable[[dict], None] | None = None,
                stream_path: str | None = None,
                resilience=None) -> ServingResult:
    """Run every serving job, one compiled program set per (policy, trace)
    group, with per-chunk streaming records when ``stream`` is on.

    ``stream_log``/``stream_path`` (each implies ``stream``) mirror
    `fleet.run_fleet`: records are assembled off the hot path on the
    io_callback thread (DESIGN.md §11) — ``stream_log`` is invoked there,
    and ``stream_path`` appends JSONL live for `capacity_report --follow`.

    ``resilience`` mirrors `fleet.run_fleet` (DESIGN.md §12): snapshots of
    the donated carry (AdmissionState, latency histogram and trace cursor
    included — they all ride the carry) at chunk boundaries, bit-exact
    resume, retry-with-backoff on injected launch failures.  Host dropout
    only *flags* the affected jobs (``ServingResult.degraded``) and plans
    recovery — the serving carry has no park rewriter.
    """
    jobs = list(jobs)
    stream = stream or stream_log is not None or stream_path is not None
    devices = list(devices or jax.devices())
    ndev = len(devices)
    mesh = Mesh(np.array(devices), ("fleet",))

    problem_of: Dict[tuple, ComputeProblem] = {}
    for job in jobs:
        k = (job.scenario, job.topo_seed)
        if k not in problem_of:
            problem_of[k] = get_scenario(job.scenario).build(job.topo_seed)
    dims = dims or PadDims.of(list(problem_of.values()))
    padded_of = {k: pad_problem(p, dims) for k, p in problem_of.items()}

    groups: Dict[tuple, List[int]] = {}
    for i, job in enumerate(jobs):
        groups.setdefault(_group_key(job), []).append(i)

    rt = resumed = None
    if resilience is not None:
        from repro.runtime.resilience import (host_lane_mask as
                                              _host_lane_mask,
                                              maybe_resilient)
        rt = maybe_resilient(resilience, "serving", jobs=tuple(jobs), T=T,
                             chunk=chunk, window=window, verdict=verdict,
                             admission=admission, dims=dims, ndev=ndev)
        resumed = rt.resumed

    metrics: List[Dict[str, float] | None] = [None] * len(jobs)
    eff_T = eff_win = 0
    glaunch = 0
    degraded: Dict[int, str] = {}
    recovery = None
    sink = None
    if stream:
        from repro.obs.emitter import StreamSink
        sink = StreamSink(path=stream_path, log=stream_log,
                          append=resumed is not None)
    if resumed is not None:
        from repro.runtime.resilience import metrics_restore, plan_restore
        for i, m in enumerate(metrics_restore(resumed["metrics"])):
            if m is not None:
                metrics[i] = m
        glaunch = resumed["global_launch"]
        degraded = {int(k): v for k, v in resumed["degraded"].items()}
        recovery = plan_restore(resumed["recovery"])
    try:
        for g, (gkey, idxs) in enumerate(groups.items()):
            job0 = jobs[idxs[0]]
            cfg = job0.policy_config()
            runner = make_serving_runner(cfg, get_trace(job0.trace), T,
                                         chunk=chunk, window=window,
                                         verdict=verdict,
                                         admission=admission)
            eff_T, eff_win = runner.T, runner.window
            if resumed is not None and g < resumed["group"]:
                continue

            B = len(idxs)
            Bp = -(-B // ndev) * ndev
            padded_idxs = idxs + [idxs[-1]] * (Bp - B)
            pp = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[padded_of[(jobs[i].scenario, jobs[i].topo_seed)]
                  for i in padded_idxs])
            lam = jnp.array([jobs[i].lam for i in padded_idxs], jnp.float32)
            eps = jnp.array([jobs[i].eps_b for i in padded_idxs],
                            jnp.float32)
            ek = jnp.array([event_code(get_scenario(jobs[i].scenario).events)
                            for i in padded_idxs], jnp.int32)
            keys = jax.vmap(jax.random.PRNGKey)(
                jnp.array([jobs[i].seed for i in padded_idxs], jnp.int32))

            init_fn, step_fn, fin_fn = make_group_launch(runner, mesh,
                                                         n_step_args=6)
            probe_fn = emitter = None
            try:
                if sink is not None:
                    from repro.obs.emitter import ChunkEmitter
                    probe_fn = _probe_launch(runner, mesh)
                    emitter = ChunkEmitter("serving", group=g, n_real=B,
                                           runner=runner, mesh=mesh,
                                           sink=sink)
                launched = 0
                if resumed is not None and g == resumed["group"]:
                    launched = resumed["launched"]
                    if launched > 0:
                        like = jax.eval_shape(init_fn, pp)
                        carry = rt.restore_carry(like, mesh)
                    else:
                        carry = init_fn(pp)
                    if emitter is not None and launched > 0:
                        pf = probe_fn or _probe_launch(runner, mesh)
                        emitter.restore_clock(
                            launched, {k: np.asarray(v) for k, v in
                                       pf(carry).items()})
                    if sink is not None:
                        from repro.obs import schema
                        sink.write(schema.make_record(
                            "resume", group=g, chunk=launched,
                            t=launched * runner.chunk, n_sims=B,
                            engine="serving",
                            ckpt_step=resumed["ckpt_step"],
                            n_preloaded=sink.n_preloaded))
                else:
                    carry = init_fn(pp)
                while launched < runner.n_chunks:
                    if rt is not None:
                        carry = rt.launch(g, glaunch, step_fn, pp, lam, eps,
                                          ek, keys, carry)
                    else:
                        carry = step_fn(pp, lam, eps, ek, keys, carry)
                    launched += 1
                    glaunch += 1
                    if emitter is not None:
                        # The probe launch reduces the carry to small [Bp]
                        # leaves (read-only, no donation); the emitter
                        # dispatches them to the callback thread without
                        # blocking the chunk loop.
                        emitter.emit(probe_fn(carry))
                    if rt is not None:
                        dead = rt.dead_hosts(glaunch)
                        if dead:
                            lane_dead = _host_lane_mask(Bp, ndev, dead)
                            per = Bp // ndev
                            for l in range(B):
                                if lane_dead[l] and idxs[l] not in degraded:
                                    degraded[idxs[l]] = \
                                        f"host_dropout:host{l // per}"
                            from repro.runtime.fault import plan_recovery
                            recovery = plan_recovery(
                                ndev, 1, [f"host{h}" for h in dead], [], 1)
                        if rt.should_snapshot(glaunch):
                            from repro.runtime.resilience import plan_state
                            rt.snapshot(glaunch, carry, {
                                "group": g, "launched": launched,
                                "global_launch": glaunch,
                                "metrics": metrics,
                                "degraded": {str(k): v
                                             for k, v in degraded.items()},
                                "recovery": plan_state(recovery)})
                        rt.maybe_preempt(glaunch)
                out = jax.device_get(fin_fn(lam, eps, carry))
                for j, i in enumerate(idxs):
                    metrics[i] = {
                        k: (float(v[j]) if np.ndim(v[j]) == 0
                            else np.asarray(v[j]).astype(float).tolist())
                        for k, v in out.items()}
            finally:
                if emitter is not None:
                    emitter.close()   # flush in-flight records for this
                                      # group, also on fault/preemption
            if rt is not None:
                from repro.runtime.resilience import plan_state
                rt.snapshot(glaunch, (), {
                    "group": g + 1, "launched": 0, "global_launch": glaunch,
                    "metrics": metrics,
                    "degraded": {str(k): v for k, v in degraded.items()},
                    "recovery": plan_state(recovery)})
    finally:
        if sink is not None:
            sink.close()
    return ServingResult(jobs=jobs, metrics=metrics, n_programs=len(groups),
                         n_sims=len(jobs), dims=dims, T=eff_T, window=eff_win,
                         stream_records=sink.records if sink is not None
                         else [],
                         resumed_from=(resumed["ckpt_step"]
                                       if resumed is not None else None),
                         degraded=degraded, recovery_plan=recovery,
                         n_fault_retries=(rt.n_retries if rt is not None
                                          else 0))
