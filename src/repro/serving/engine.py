"""Batched serving engine: continuous batching over fixed decode slots with
dummy-slot padding (the paper's regulator made literal — XLA needs static
shapes, so empty slots run as dummy packets and are ignored on output).

The engine drives any arch through the uniform ModelAPI: submit prompts,
`step()` prefills newly admitted requests (one at a time, cache-filling
decode of the prompt) and decodes one token for every active slot.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.caches = self.api.init_decode(slots, max_len, jnp.float32)
        self.router_H = self.api.init_state().router_H
        self.slot_req: List[Optional[ServeRequest]] = [None] * slots
        self.pending: List[ServeRequest] = []
        self.finished: Dict[int, ServeRequest] = {}
        self._last_tok = np.zeros((slots,), np.int32)

        def step_fn(params, caches, tokens, H):
            return self.api.decode_step(params, caches, {"tokens": tokens},
                                        activ_dtype=jnp.float32, router_H=H)
        self._step = jax.jit(step_fn)

    # ------------------------------------------------------------------

    def submit(self, prompt: List[int], max_new: int = 16) -> int:
        rid = len(self.finished) + len(self.pending) + sum(
            r is not None for r in self.slot_req)
        self.pending.append(ServeRequest(rid, list(prompt), max_new))
        return rid

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.pending:
                req = self.pending.pop(0)
                self.slot_req[s] = req
                # prefill by decoding the prompt into this slot's cache:
                # tokens of OTHER slots are dummy packets (last token echo).
                for tok in req.prompt[:-1]:
                    toks = self._last_tok.copy()
                    toks[s] = tok
                    _, self.caches = self._step(self.params, self.caches,
                                                jnp.asarray(toks),
                                                self.router_H)
                    self._last_tok = np.asarray(toks)
                self._last_tok[s] = req.prompt[-1]

    def step(self) -> int:
        """One decode tick over all slots; returns #active real slots."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        logits, self.caches = self._step(self.params, self.caches,
                                         jnp.asarray(self._last_tok),
                                         self.router_H)
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = jax.random.categorical(sub, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        nxt = np.asarray(nxt, np.int32)
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt[s]))
            self._last_tok[s] = nxt[s]
            if len(req.out) >= req.max_new:
                req.done = True
                self.finished[req.rid] = req
                self.slot_req[s] = None
        return len(active)

    def run_until_done(self, max_ticks: int = 10_000) -> Dict[int, ServeRequest]:
        for _ in range(max_ticks):
            if not self.pending and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.finished
