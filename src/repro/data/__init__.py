from .pipeline import DataConfig, TokenStream, unigram_entropy

__all__ = ["DataConfig", "TokenStream", "unigram_entropy"]
