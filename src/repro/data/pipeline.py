"""Deterministic sharded data pipeline.

Synthetic-but-structured LM streams (Zipfian n-gram chains so the loss has
signal to minimize), deterministic per (seed, step, host) — each host
materializes only its shard, so the pipeline scales to any number of hosts
and recovery after restart replays the exact stream from the step counter
(no data-loader state in the checkpoint).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "ngram"        # ngram | uniform


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / r ** alpha
    return p / p.sum()


class TokenStream:
    """Markov-chain token stream: next-token distribution depends on the
    previous token's bucket, so cross-entropy is learnable (tests assert the
    loss drops below the unigram entropy)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        base = np.random.default_rng(cfg.seed)
        self._zipf = _zipf_probs(cfg.vocab)
        # bucketized bigram structure: 16 buckets, each with its own
        # permutation of the zipf distribution
        self._n_buckets = 16
        self._perms = np.stack([base.permutation(cfg.vocab)
                                for _ in range(self._n_buckets)])

    def batch(self, step: int) -> dict:
        """Deterministic batch for a global step: {'tokens': [B_local, S+1]}."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + self.host_id)
        B, S = self.local_batch, cfg.seq_len
        if cfg.kind == "uniform":
            toks = rng.integers(0, cfg.vocab, size=(B, S + 1))
            return {"tokens": toks.astype(np.int32)}
        out = np.empty((B, S + 1), dtype=np.int64)
        out[:, 0] = rng.choice(cfg.vocab, size=B, p=self._zipf)
        for t in range(S):
            buckets = out[:, t] % self._n_buckets
            base_draw = rng.choice(cfg.vocab, size=B, p=self._zipf)
            out[:, t + 1] = self._perms[buckets, base_draw]
        return {"tokens": out.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def unigram_entropy(vocab: int) -> float:
    p = _zipf_probs(vocab)
    return float(-(p * np.log(p)).sum())
