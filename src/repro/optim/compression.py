"""Gradient compression with error feedback for the DP all-reduce.

At 1000+-node scale the DP gradient all-reduce is the dominant inter-pod
collective; int8 quantization cuts its bytes 4x (vs f32) and error feedback
keeps convergence (the compression error is re-injected next step, giving
the classic EF-SGD contraction).  In SPMD jit the all-reduce itself is
implicit, so compression is applied to the gradient *before* the optimizer:
on real hardware the quantized tensor is what crosses the wire (paired with
an int8 psum via shard_map); the roofline accounting in EXPERIMENTS.md uses
the compressed byte count for the DP collective term.

  topk_ef keeps the largest |g| fraction per tensor (magnitude sparsification).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    err: object          # pytree like grads (f32 residuals)


def init_ef(params) -> EFState:
    return EFState(err=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def init_ef_abstract(params) -> EFState:
    return EFState(err=jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params))


def _q_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_int8_ef(grads, ef: EFState) -> Tuple[object, EFState]:
    """Returns (decompressed grads as seen post-all-reduce, new EF state)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _q_int8(gf)
        dq = _dq_int8(q, s)
        return dq, gf - dq
    out = jax.tree.map(one, grads, ef.err)
    dq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return dq, EFState(err=err)


def compress_topk_ef(grads, ef: EFState, frac: float = 0.1):
    """Magnitude top-k sparsification with error feedback."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        flat = gf.reshape(-1)
        k = max(int(flat.shape[0] * frac), 1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(gf) >= thresh, gf, 0.0)
        return kept, gf - kept
    out = jax.tree.map(one, grads, ef.err)
    kept = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return kept, EFState(err=err)
