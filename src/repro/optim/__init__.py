from .adamw import AdamW, AdamWState, global_norm, warmup_cosine
from .compression import (EFState, init_ef, init_ef_abstract,
                          compress_int8_ef, compress_topk_ef)

__all__ = ["AdamW", "AdamWState", "global_norm", "warmup_cosine", "EFState",
           "init_ef", "init_ef_abstract", "compress_int8_ef",
           "compress_topk_ef"]
