"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX,
pytree-native; optimizer moments inherit the parameter shardings so FSDP
shards optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array      # [] int32
    m: object             # pytree like params (f32)
    v: object             # pytree like params (f32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def init_abstract(self, params) -> AdamWState:
        sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return AdamWState(
            count=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(sds, params),
            v=jax.tree.map(sds, params),
        )

    def _lr(self, count):
        if callable(self.lr):
            return self.lr(count)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.count + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v,
                         grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c
        lr = self._lr(count)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            step = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(count=count, m=m, v=v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def sched(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(warmup, 1)
        frac = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(c < warmup, warm, cos)
    return sched
