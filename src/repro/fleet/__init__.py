"""Fleet: sharded multi-scenario sweep engine (padded batching + streaming).

Public API:
  scenarios:  Scenario, register_scenario, get_scenario, list_scenarios,
              ARRIVAL_MODELS, EVENT_MODELS
  batching:   PaddedProblem, PadDims, pad_problem, stack_problems,
              make_buckets, validate_buckets, problem_shape
  engine:     FleetJob, FleetResult, run_fleet, stream_simulate,
              make_stream_runner, make_group_launch, VerdictConfig
  report:     capacity_report, sweep_jobs, policy_bound, policy_bound_exact,
              exact_lam_star, atlas_table, policy_surface_table,
              problem_fingerprint
  frontier:   find_lambda_max, FrontierResult, RateProbe, fold_seed,
              Bisection
  atlas:      sweep_lambda_max, sweep_policy_surface, registry_cells,
              AtlasJob, AtlasRow, AtlasResult
"""
from repro.core.queues import (VERDICT_NAMES, VERDICT_STABLE,
                               VERDICT_UNDECIDED, VERDICT_UNSTABLE)
from .scenarios import (ModState, Scenario, register_scenario, get_scenario,
                        list_scenarios, ARRIVAL_MODELS, EVENT_MODELS,
                        ARRIVAL_MODEL_ORDER, EVENT_MODEL_ORDER)
from .batching import (PaddedProblem, PadDims, make_buckets, pad_problem,
                       problem_shape, stack_problems, validate_buckets)
from .engine import (DEFAULT_VERDICT, FleetJob, FleetResult, StreamStats,
                     VerdictConfig, make_group_launch, resolve_verdict,
                     run_fleet, stream_simulate, make_stream_runner)
from .report import (atlas_table, capacity_report, exact_lam_star,
                     policy_bound, policy_bound_exact,
                     policy_surface_table, problem_fingerprint, sweep_jobs)
from .frontier import (Bisection, FrontierResult, RateProbe, find_lambda_max,
                       fold_seed)
from .atlas import (AtlasJob, AtlasResult, AtlasRow, registry_cells,
                    sweep_lambda_max, sweep_policy_surface)

__all__ = [
    "ModState", "Scenario", "register_scenario", "get_scenario",
    "list_scenarios",
    "ARRIVAL_MODELS", "EVENT_MODELS", "ARRIVAL_MODEL_ORDER",
    "EVENT_MODEL_ORDER",
    "PaddedProblem", "PadDims", "pad_problem", "stack_problems",
    "make_buckets", "validate_buckets", "problem_shape",
    "FleetJob", "FleetResult", "StreamStats", "make_group_launch",
    "run_fleet", "stream_simulate", "make_stream_runner",
    "VerdictConfig", "DEFAULT_VERDICT", "resolve_verdict",
    "VERDICT_NAMES", "VERDICT_UNDECIDED", "VERDICT_STABLE",
    "VERDICT_UNSTABLE",
    "capacity_report", "exact_lam_star", "policy_bound",
    "policy_bound_exact", "sweep_jobs", "atlas_table",
    "policy_surface_table", "problem_fingerprint",
    "Bisection", "FrontierResult", "RateProbe", "find_lambda_max",
    "fold_seed",
    "AtlasJob", "AtlasResult", "AtlasRow", "registry_cells",
    "sweep_lambda_max", "sweep_policy_surface",
]
