"""Padded topology batching: heterogeneous graphs as one vmappable pytree.

The seed simulator compiles one XLA program per `ComputeProblem` because the
problem constants (edges, capacities, sinks) are baked into the trace.  The
fleet engine instead pads every instance to fleet-wide maxima and carries the
problem as *traced* arrays, so a thousand different topologies share one
compiled program under `vmap`/`shard_map`.

Mask convention (the single source of truth — narrated in DESIGN.md §3 and
referenced by README and the core policies):

  * Every instance is padded to shared maxima ``(n_nodes, n_edges, n_comp)``.
  * Padded edges are self-loops ``(0, 0)`` with ``edge_cap == 0`` and
    ``edge_mask == 0``.  A self-loop has zero differential backlog, so it can
    never route traffic even before masking; the mask additionally keeps it
    out of wireless matchings and any capacity statistics.
  * Padded computation nodes point at node 0 with ``comp_caps == 0`` and
    ``comp_mask == 0``.  Masked nodes are excluded from the load-balance
    argmin (score forced to +inf) and combine zero pairs per slot; the
    regulator consequently sees ``assigned == 0`` there and pushes nothing
    (``F = 0``), so padded slots accumulate no ``Y``/``H``/``Ddum`` state —
    padding is the network-side mirror of the paper's dummy-packet
    regulator (DESIGN.md §2).
  * ``sink`` rows of padded classes are all ``False``; padded *nodes* simply
    host queues that never receive traffic (no active edge touches them).

`PaddedProblem` is duck-type compatible with `repro.core.queues.StaticProblem`
— `slot_step`, `init_state`, and `make_step` accept either.  The padded node
and class counts stay static (pytree aux data) so shapes remain concrete.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import ComputeProblem
from repro.core.queues import StaticProblem


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PaddedProblem:
    """A (possibly batched) padded problem with traced constants."""

    n_nodes: int               # static: padded node count
    n_comp: int                # static: padded comp-node count
    edges: jax.Array           # [..., E, 2] int32
    edge_cap: jax.Array        # [..., E] float32
    s1: jax.Array              # [...] int32
    s2: jax.Array              # [...] int32
    dest: jax.Array            # [...] int32
    comp_nodes: jax.Array      # [..., NC] int32
    comp_caps: jax.Array       # [..., NC] float32
    sink: jax.Array            # [..., N, 3, NC] bool
    edge_mask: jax.Array       # [..., E] float32
    comp_mask: jax.Array       # [..., NC] float32

    def tree_flatten(self):
        leaves = (self.edges, self.edge_cap, self.s1, self.s2, self.dest,
                  self.comp_nodes, self.comp_caps, self.sink,
                  self.edge_mask, self.comp_mask)
        return leaves, (self.n_nodes, self.n_comp)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(aux[0], aux[1], *leaves)

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[-2])

    def replace(self, **kw) -> "PaddedProblem":
        return dataclasses.replace(self, **kw)

    def with_capacity_scales(self, edge_scale: jax.Array,
                             comp_scale: jax.Array) -> "PaddedProblem":
        """Per-slot time-varying capacities (fleet event models).

        A comp node whose scale is zero this slot — an event-model outage,
        e.g. the Markov `ge_comp` chain — is also gated out of `comp_mask`,
        so it is excluded from the load-balance argmin exactly like a
        padded slot: a Down node keeps its queues but neither combines
        pairs nor attracts new query assignments (DESIGN.md §3).  Edge
        masks need no gating: `bp_route_slot` already weights matching and
        allocation by the scaled capacity."""
        return self.replace(
            edge_cap=self.edge_cap * edge_scale,
            comp_caps=self.comp_caps * comp_scale,
            comp_mask=self.comp_mask * (comp_scale > 0.0).astype(jnp.float32))


def problem_shape(problem: ComputeProblem) -> Tuple[int, int, int]:
    """The (n_nodes, n_edges, n_comp) shape of one instance — the axes
    padding has to cover."""
    return (int(problem.graph.n_nodes), int(problem.graph.n_edges),
            int(problem.n_comp))


@dataclasses.dataclass(frozen=True)
class PadDims:
    n_nodes: int
    n_edges: int
    n_comp: int

    @staticmethod
    def of(problems: Sequence[ComputeProblem]) -> "PadDims":
        problems = list(problems)
        if not problems:
            raise ValueError(
                "PadDims.of: empty problem sequence — there is nothing to "
                "take shape maxima over (did a scenario/topo_seed grid "
                "expand to zero cells?)")
        return PadDims(
            n_nodes=max(p.graph.n_nodes for p in problems),
            n_edges=max(p.graph.n_edges for p in problems),
            n_comp=max(p.n_comp for p in problems),
        )

    def fits(self, problem: ComputeProblem) -> bool:
        n, e, nc = problem_shape(problem)
        return n <= self.n_nodes and e <= self.n_edges and nc <= self.n_comp


def make_buckets(problems: Sequence[ComputeProblem],
                 n_buckets: int = 1
                 ) -> Tuple[List[PadDims], List[int]]:
    """Partition problems into size buckets with per-bucket `PadDims`.

    Returns ``(bucket_dims, assignment)`` where ``assignment[i]`` is the
    bucket index of ``problems[i]`` and ``bucket_dims[b]`` covers every
    problem assigned to bucket ``b``.  Breakpoints are quantiles of a
    lexicographic (n_edges, n_nodes, n_comp) size key — edges first
    because the [E, 2] routing arrays dominate the padded slot cost — so
    a 500-node expander stops inflating every 16-node ring (DESIGN.md
    §13).  Problems with identical shapes always share a bucket, empty
    quantile bins are dropped, and each bucket's dims are the maxima over
    its own members, so every problem fits its bucket by construction
    (re-checked by `validate_buckets`).  ``n_buckets=1`` reproduces the
    single global `PadDims.of` hull exactly.
    """
    problems = list(problems)
    if not problems:
        raise ValueError("make_buckets: empty problem sequence")
    n_buckets = max(1, int(n_buckets))
    shapes = np.array([problem_shape(p) for p in problems], np.int64)
    # Lexicographic (E, N, NC) packed into one int64 so quantiles of the
    # scalar respect the full ordering (shifts leave 2^23 headroom per axis).
    key = (shapes[:, 1] << 40) | (shapes[:, 0] << 20) | shapes[:, 2]
    cuts = [int(np.quantile(key, (b + 1) / n_buckets, method="lower"))
            for b in range(n_buckets - 1)]
    raw = np.zeros(len(problems), np.int64)
    for c in cuts:
        raw += key > c
    dense: Dict[int, int] = {}
    for r in sorted(set(raw.tolist())):
        dense[r] = len(dense)
    assignment = [dense[int(r)] for r in raw]
    bucket_dims = []
    for b in range(len(dense)):
        members = [p for p, a in zip(problems, assignment) if a == b]
        bucket_dims.append(PadDims.of(members))
    validate_buckets(problems, bucket_dims, assignment)
    return bucket_dims, assignment


def validate_buckets(problems: Sequence[ComputeProblem],
                     bucket_dims: Sequence[PadDims],
                     assignment: Sequence[int]) -> None:
    """Check every problem fits its assigned bucket's dims.

    Raises an actionable `ValueError` naming the offending instance shape
    and the bucket dims it overflows — the bucketed-atlas contract
    (DESIGN.md §13) is that a cell is *never* silently truncated."""
    if len(problems) != len(assignment):
        raise ValueError(
            f"validate_buckets: {len(problems)} problems but "
            f"{len(assignment)} bucket assignments")
    for i, (p, b) in enumerate(zip(problems, assignment)):
        if not 0 <= b < len(bucket_dims):
            raise ValueError(
                f"validate_buckets: problem {i} assigned to bucket {b}, "
                f"but only {len(bucket_dims)} buckets exist")
        d = bucket_dims[b]
        if not d.fits(p):
            n, e, nc = problem_shape(p)
            raise ValueError(
                f"validate_buckets: problem {i} with shape (n_nodes={n}, "
                f"n_edges={e}, n_comp={nc}) exceeds bucket {b} dims "
                f"(n_nodes={d.n_nodes}, n_edges={d.n_edges}, "
                f"n_comp={d.n_comp})")


def pad_problem(problem: ComputeProblem, dims: PadDims) -> PaddedProblem:
    """Embed one ComputeProblem into the fleet-wide padded shapes."""
    sp = StaticProblem.build(problem)
    N, E, NC = dims.n_nodes, dims.n_edges, dims.n_comp
    e, nc = sp.edges.shape[0], sp.n_comp
    if sp.n_nodes > N or e > E or nc > NC:
        raise ValueError(
            f"pad_problem: instance shape (n_nodes={sp.n_nodes}, "
            f"n_edges={e}, n_comp={nc}) exceeds pad dims (n_nodes={N}, "
            f"n_edges={E}, n_comp={NC}) — pass PadDims.of over every "
            f"problem in the batch (or its bucket)")

    edges = np.zeros((E, 2), np.int32)               # padding: self-loop (0,0)
    edges[:e] = sp.edges
    edge_cap = np.zeros((E,), np.float32)
    edge_cap[:e] = sp.edge_cap
    edge_mask = np.zeros((E,), np.float32)
    edge_mask[:e] = 1.0

    comp_nodes = np.zeros((NC,), np.int32)           # padding: node 0, cap 0
    comp_nodes[:nc] = sp.comp_nodes
    comp_caps = np.zeros((NC,), np.float32)
    comp_caps[:nc] = sp.comp_caps
    comp_mask = np.zeros((NC,), np.float32)
    comp_mask[:nc] = 1.0

    sink = np.zeros((N, 3, NC), bool)
    sink[:sp.n_nodes, :, :nc] = sp.sink

    return PaddedProblem(
        n_nodes=N, n_comp=NC,
        edges=jnp.asarray(edges), edge_cap=jnp.asarray(edge_cap),
        s1=jnp.int32(sp.s1), s2=jnp.int32(sp.s2), dest=jnp.int32(sp.dest),
        comp_nodes=jnp.asarray(comp_nodes), comp_caps=jnp.asarray(comp_caps),
        sink=jnp.asarray(sink),
        edge_mask=jnp.asarray(edge_mask), comp_mask=jnp.asarray(comp_mask),
    )


def stack_problems(problems: Sequence[ComputeProblem],
                   dims: PadDims | None = None) -> PaddedProblem:
    """Pad + stack a fleet of problems into one batched PaddedProblem.

    Every leaf gains a leading batch axis; `vmap`/`shard_map` over the pytree
    then runs all instances inside a single compiled program.
    """
    dims = dims or PadDims.of(problems)
    padded = [pad_problem(p, dims) for p in problems]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
