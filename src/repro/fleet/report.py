"""Capacity/efficiency reporting: fleet sweeps vs the Theorem-4 LP bound.

For every scenario instance we solve the multicommodity-flow LP
(`repro.core.capacity.capacity_upper_bound`) for its capacity `lam_star`,
sweep offered rates across policies and seeds, and summarize measured
useful rate, efficiency, and the empirical stability frontier.  The result
is a JSON-serializable dict.

Regulated policies (pi2/pi3/pi2_reg/pi3_reg) inflate their computation
output by rho0 = 1 + eps_B (paper eq. (8)), so their operative bound is
NOT the plain Theorem-4 `lam_star`.  Two bounds exist (DESIGN.md §6):

  * `bound_exact`  — the exact regulated LP `capacity_upper_bound(problem,
    rho0=1+eps_B).lam_star`: the max query rate whose rho0-inflated
    processed stream is still feasible.  This is the yardstick every
    efficiency in this module is measured against.
  * `bound_approx` — the closed-form `lam_star / (1 + eps_B)` of Theorems
    3/5.  Always a valid lower bound on `bound_exact` (scale any feasible
    unregulated flow by 1/rho0), tight only when *links* are the binding
    constraint; when computation capacity binds (the paper grid) the dummy
    inflation rides free link slack and `bound_exact == lam_star`.

Exact solves are cached on the **canonical problem fingerprint** (a
content hash of the LP-determining data: graph edges/capacities, sources,
destination, comp placement/capacities, rho0), bounded LRU — so a sweep
over policies x rates x seeds re-solves nothing, a thousand topo_seeds of
a seed-independent family (fat_tree, paper_grid, ...) collapse to *one*
LP solve, and the cache cannot grow past `LP_CACHE_MAX` entries at
atlas scale (DESIGN.md §13).
"""
from __future__ import annotations

import collections
import hashlib
from typing import Dict, List, Sequence

import numpy as np

from repro.core.capacity import capacity_upper_bound
from repro.core.policies import PolicyConfig
from repro.core.queues import VERDICT_NAMES
from .engine import FleetJob, run_fleet
from .scenarios import get_scenario


def policy_bound(lam_star: float, policy: str, eps_b: float) -> float:
    """The closed-form (approximate) throughput bound: lam_star/rho0 for
    regulated policies (rho0 = 1 + eps_B), lam_star itself otherwise.

    A guaranteed *lower* bound on the exact regulated capacity — use
    `policy_bound_exact` for the operative yardstick (DESIGN.md §6)."""
    return float(lam_star) / PolicyConfig(name=policy, eps_b=eps_b).rho0


#: Hard bound on cached LP scalars.  At thousands of random topo_seeds
#: the old per-(scenario, topo_seed, rho0) LRU kept one entry per cell;
#: the fingerprint-keyed cache both dedupes seed-independent families and
#: evicts least-recently-used entries past this bound.
LP_CACHE_MAX = 4096

_LP_CACHE: "collections.OrderedDict[tuple, float]" = collections.OrderedDict()
_LP_STATS = {"hits": 0, "misses": 0}
_CacheInfo = collections.namedtuple("CacheInfo",
                                    ["hits", "misses", "maxsize", "currsize"])


def problem_fingerprint(problem, rho0: float = 1.0) -> str:
    """Canonical content hash of the data that determines the regulated
    capacity LP: graph shape, edges, capacities, sources/destination, comp
    placement/capacities, and rho0.  Two (scenario, topo_seed) cells that
    build the same instance — every seed of a deterministic family — hash
    identically, which is what lets the atlas solve each *distinct* LP
    once (DESIGN.md §13)."""
    h = hashlib.sha256()
    g = problem.graph
    h.update(np.int64([g.n_nodes, problem.s1, problem.s2,
                       problem.dest]).tobytes())
    h.update(np.ascontiguousarray(g.edges, np.int64).tobytes())
    h.update(np.ascontiguousarray(g.capacity, np.float64).tobytes())
    h.update(np.asarray(problem.comp_nodes, np.int64).tobytes())
    h.update(np.asarray(problem.comp_caps, np.float64).tobytes())
    h.update(np.float64([rho0]).tobytes())
    return h.hexdigest()


def exact_lam_star(scenario: str, topo_seed: int, rho0: float) -> float:
    """Exact (possibly regulated) LP capacity of one scenario instance.

    Solves `capacity_upper_bound(scenario.build(topo_seed), rho0=rho0)`
    and caches the scalar under the canonical `problem_fingerprint` — the
    key is the *data* that determines the LP, so sweeps over policies,
    rates, and seeds hit the cache, and distinct topo_seeds of a
    seed-independent topology share one solve.  The cache is a bounded
    LRU (`LP_CACHE_MAX`); `exact_lam_star.cache_info()` /
    `.cache_clear()` keep the `functools.lru_cache` introspection
    surface (misses == LP solves actually performed)."""
    problem = get_scenario(scenario).build(topo_seed)
    key = ("lam_star", problem_fingerprint(problem, rho0))
    hit = _LP_CACHE.get(key)
    if hit is not None:
        _LP_CACHE.move_to_end(key)
        _LP_STATS["hits"] += 1
        return hit
    _LP_STATS["misses"] += 1
    val = float(capacity_upper_bound(problem, rho0=rho0).lam_star)
    _LP_CACHE[key] = val
    while len(_LP_CACHE) > LP_CACHE_MAX:
        _LP_CACHE.popitem(last=False)
    return val


def _lp_cache_info() -> _CacheInfo:
    return _CacheInfo(_LP_STATS["hits"], _LP_STATS["misses"],
                      LP_CACHE_MAX, len(_LP_CACHE))


def _lp_cache_clear() -> None:
    _LP_CACHE.clear()
    _LP_STATS["hits"] = _LP_STATS["misses"] = 0


exact_lam_star.cache_info = _lp_cache_info
exact_lam_star.cache_clear = _lp_cache_clear


def policy_bound_exact(scenario: str, policy: str, eps_b: float,
                       topo_seed: int = 0) -> float:
    """The operative throughput bound from the exact regulated LP
    (DESIGN.md §6): lam_star(rho0 = 1 + eps_B) for regulated policies,
    which degenerates to the plain Theorem-4 lam_star (rho0 = 1) for
    unregulated ones."""
    rho0 = PolicyConfig(name=policy, eps_b=eps_b).rho0
    return exact_lam_star(scenario, int(topo_seed), round(float(rho0), 9))


def sweep_jobs(scenario_policies: Dict[str, Sequence[str]],
               rate_fracs: Sequence[float], seeds: Sequence[int],
               topo_seed: int = 0,
               lam_star_of: Dict[str, float] | None = None,
               eps_b: float = 0.01, exact: bool = True) -> List[FleetJob]:
    """Expand a {scenario: [policies]} spec into the full job grid, with
    offered rates expressed as fractions of each policy's operative bound:
    frac 0.95 loads every policy to 95% of what it could sustain,
    regulated or not.

    With `exact=True` (default) the operative bound is the exact regulated
    LP (`policy_bound_exact`, LRU-cached).  `exact=False` falls back to the
    closed-form `policy_bound(lam_star, ...)` approximation, with
    `lam_star_of` as an optional per-scenario cache of plain Theorem-4
    capacities (solved on demand when omitted)."""
    jobs = []
    for scen, policies in scenario_policies.items():
        lam_star = (lam_star_of or {}).get(scen)
        if lam_star is None and not exact:
            lam_star = exact_lam_star(scen, int(topo_seed), 1.0)
        for pol in policies:
            if exact:
                bound = policy_bound_exact(scen, pol, eps_b,
                                           topo_seed=topo_seed)
            else:
                bound = policy_bound(lam_star, pol, eps_b)
            for frac in rate_fracs:
                for seed in seeds:
                    jobs.append(FleetJob(scenario=scen, policy=pol,
                                         lam=float(frac) * bound,
                                         seed=int(seed),
                                         topo_seed=topo_seed,
                                         eps_b=float(eps_b)))
    return jobs


def _ratio_band(ratios: np.ndarray) -> dict:
    """The per-family λ_max confidence band (DESIGN.md §13): q10/q90 of
    the ratio distribution over the family's (cell × topo_seed) rows plus
    the band width.  Quantiles use the ``lower`` method so the band is a
    pair of *measured* cell ratios (deterministic, dispatch-order
    invariant) rather than an interpolation artifact."""
    q10 = float(np.quantile(ratios, 0.10, method="lower"))
    q90 = float(np.quantile(ratios, 0.90, method="lower"))
    return {"q10": q10, "q90": q90, "width": q90 - q10}


def atlas_table(result) -> dict:
    """JSON-serializable capacity-atlas table (DESIGN.md §10, §13).

    Takes an `atlas.AtlasResult` (duck-typed: anything with its fields
    works, which keeps this module import-free of `fleet.atlas`) and
    summarizes the measured-vs-LP frontier per scenario family: ratio
    median/min/max and the q10–q90 seed-replication band over the
    family's cells, how many cells ended UNDECIDED at the bracket top
    (horizon-limited localization, DESIGN.md §8) vs proven UNSTABLE, how
    many were rescued by adaptive re-queues, plus the fleet-level
    launch + bucket accounting the atlas bench gates on."""
    fam: Dict[str, list] = {}
    for r in result.rows:
        fam.setdefault(r.scenario, []).append(r)
    families = {}
    # Canonical order — (policy, topo_seed) within a family, families by
    # name — so the table is invariant to cell dispatch order and seed-
    # band entries diff cleanly in CI (DESIGN.md §13).
    for scen in sorted(fam):
        rows = sorted(fam[scen], key=lambda r: (r.policy, r.topo_seed))
        ratios = np.array([r.ratio for r in rows])
        families[scen] = {
            "n_cells": len(rows),
            "ratio_median": float(np.median(ratios)),
            "ratio_min": float(ratios.min()),
            "ratio_max": float(ratios.max()),
            "band": _ratio_band(ratios),
            "n_undecided_hi": int(sum(r.undecided for r in rows)),
            "n_requeued": int(sum(r.n_requeues > 0 for r in rows)),
            "n_calls_mean": float(np.mean([r.n_calls for r in rows])),
            "bound_exact_mean": float(np.mean([r.bound_exact
                                               for r in rows])),
            "cells": [
                {"topo_seed": r.topo_seed, "lam_max": r.lam_max,
                 "bound_exact": r.bound_exact, "ratio": r.ratio,
                 "lo": r.lo, "hi": r.hi, "n_calls": r.n_calls,
                 "undecided_hi": bool(r.undecided),
                 "hi_certain": r.hi_certain,
                 "bucket": r.bucket, "n_requeues": r.n_requeues}
                for r in rows],
        }
    return {
        "n_cells": result.n_cells,
        "n_lanes": result.n_lanes,
        "n_programs": result.n_programs,
        "n_launches": result.n_launches,
        "seq_launches": result.seq_launches,
        "launch_speedup": result.launch_speedup,
        "n_rewrites": result.n_rewrites,
        "n_step_compiles": result.n_step_compiles,
        "slots_saved": result.slots_saved,
        "full_slots": result.full_slots,
        "launch_slots_saved": result.launch_slots_saved,
        "pad_dims": {"n_nodes": result.dims.n_nodes,
                     "n_edges": result.dims.n_edges,
                     "n_comp": result.dims.n_comp},
        "n_buckets": result.n_buckets,
        "bucket_dims": [{"n_nodes": d.n_nodes, "n_edges": d.n_edges,
                         "n_comp": d.n_comp}
                        for d in result.bucket_dims],
        "bucket_cells": {str(b): int(n)
                         for b, n in sorted(result.bucket_cells.items())},
        "bucket_launches": {str(b): int(n)
                            for b, n in
                            sorted(result.bucket_launches.items())},
        "n_requeues": result.n_requeues,
        "T": result.T, "chunk": result.chunk,
        "families": families,
    }


def policy_surface_table(result) -> dict:
    """Pivot an atlas-over-policies sweep (`atlas.sweep_policy_surface`)
    into the policy-surface table: per (policy × family) ratio medians and
    q10–q90 bands over the shared topology grid, so policies compare on
    identical cells against identical exact bounds (DESIGN.md §13).  The
    per-family ``gap_vs`` entries report each policy's median-ratio gap
    to the best policy on that family."""
    surf: Dict[str, Dict[str, list]] = {}
    for r in result.rows:
        surf.setdefault(r.policy, {}).setdefault(r.scenario, []).append(r)
    policies = {}
    for pol in sorted(surf):        # canonical order, like atlas_table
        fams = surf[pol]
        entry = {}
        for scen in sorted(fams):
            rows = fams[scen]
            ratios = np.array([r.ratio for r in rows])
            entry[scen] = {
                "n_cells": len(rows),
                "ratio_median": float(np.median(ratios)),
                "band": _ratio_band(ratios),
                "n_undecided_hi": int(sum(r.undecided for r in rows)),
            }
        policies[pol] = entry
    fam_names = sorted({s for fams in surf.values() for s in fams})
    best = {scen: max(policies[p][scen]["ratio_median"]
                      for p in policies if scen in policies[p])
            for scen in fam_names}
    for pol, entry in policies.items():
        for scen, row in entry.items():
            row["gap_vs_best"] = best[scen] - row["ratio_median"]
    return {
        "n_cells": result.n_cells,
        "n_policies": len(policies),
        "families": fam_names,
        "policies": policies,
    }


def capacity_report(scenario_policies: Dict[str, Sequence[str]],
                    rate_fracs: Sequence[float], seeds: Sequence[int],
                    T: int, chunk: int = 1024, window: int | None = None,
                    topo_seed: int = 0, devices=None,
                    eps_b: float = 0.01,
                    memory_stats: bool = False,
                    early_stop: bool = False,
                    stream: bool = False, stream_log=None,
                    stream_path: str | None = None) -> dict:
    """Run the sweep and assemble the capacity/efficiency table.

    Per-policy rows report both bounds — `bound_exact` (the per-(scenario,
    eps_B) regulated LP) and `bound_approx` (`lam_star/rho0`) — plus
    `bound`/`efficiency` measured against the exact one (DESIGN.md §6).
    Points carry the streaming verdict and its decision slot
    (DESIGN.md §8); `early_stop=True` additionally freezes decided sims
    and stops chunk launches per group (frontier semantics — off by
    default so efficiency numbers stay full-horizon).

    ``stream``/``stream_log``/``stream_path`` pass through to `run_fleet`
    (DESIGN.md §11): per-chunk telemetry records are emitted while the
    sweep is in flight — ``stream_path`` is what `scripts/run_fleet.sh`
    wires so `capacity_report --follow` can tail the run — and the table
    gains a ``stream_records`` count.
    """
    lam_star_of = {
        scen: exact_lam_star(scen, int(topo_seed), 1.0)
        for scen in scenario_policies}
    # One bound/rho0 lookup per (scenario, policy) group, hoisted out of the
    # row/point assembly below — the LP solves behind these are LRU-cached
    # (`exact_lam_star`), so this whole dict costs cache hits only
    # (asserted by tests/test_fleet.py::TestExactBounds).
    rho0_of = {pol: PolicyConfig(name=pol, eps_b=eps_b).rho0
               for pols in scenario_policies.values() for pol in pols}
    bound_of = {
        (scen, pol): policy_bound_exact(scen, pol, eps_b,
                                        topo_seed=topo_seed)
        for scen, pols in scenario_policies.items() for pol in pols}
    jobs = sweep_jobs(scenario_policies, rate_fracs, seeds,
                      topo_seed=topo_seed, eps_b=eps_b, exact=True)
    res = run_fleet(jobs, T=T, chunk=chunk, window=window, devices=devices,
                    memory_stats=memory_stats, early_stop=early_stop,
                    stream=stream, stream_log=stream_log,
                    stream_path=stream_path)

    table: dict = {
        "T": res.T, "window": res.window,
        "n_sims": res.n_sims, "n_programs": res.n_programs,
        "pad_dims": {"n_nodes": res.dims.n_nodes, "n_edges": res.dims.n_edges,
                     "n_comp": res.dims.n_comp},
        "rate_fracs": [float(f) for f in rate_fracs],
        "scenarios": {},
    }
    if res.memory_stats is not None:
        table["memory"] = res.memory_stats
    if res.stream_records:
        table["stream_records"] = len(res.stream_records)
    for scen, policies in scenario_policies.items():
        lam_star = lam_star_of[scen]
        entry = {"lam_star": lam_star, "policies": {}}
        for pol in policies:
            rows = [(job, m) for job, m in zip(res.jobs, res.metrics)
                    if job.scenario == scen and job.policy == pol]
            useful = np.array([m["useful_rate"] for _, m in rows])
            offered = np.array([m["offered"] for _, m in rows])
            stable = np.array([m["stable"] for _, m in rows]) > 0.5
            best = float(useful.max()) if len(useful) else 0.0
            stable_offered = offered[stable] if stable.any() else np.array([0.0])
            bound_exact = bound_of[(scen, pol)]
            entry["policies"][pol] = {
                "best_useful_rate": best,
                "rho0": rho0_of[pol],
                "bound": bound_exact,
                "bound_exact": bound_exact,
                "bound_approx": policy_bound(lam_star, pol, eps_b),
                "efficiency": best / bound_exact if bound_exact > 0 else 0.0,
                "max_stable_offered": float(stable_offered.max()),
                "mean_queue_at_best": float(
                    rows[int(useful.argmax())][1]["mean_queue"]) if rows else 0.0,
                "points": [
                    {"offered": float(m["offered"]),
                     "useful_rate": float(m["useful_rate"]),
                     "stable": bool(m["stable"] > 0.5),
                     "verdict": VERDICT_NAMES[int(m["verdict"])],
                     "decided_at_slot": int(m["decided_at_slot"]),
                     "slots_saved": int(m["slots_saved"]),
                     "mean_queue": float(m["mean_queue"]),
                     "max_queue": float(m["max_queue"])}
                    for _, m in rows],
            }
        table["scenarios"][scen] = entry
    return table
