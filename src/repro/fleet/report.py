"""Capacity/efficiency reporting: fleet sweeps vs the Theorem-4 LP bound.

For every scenario instance we solve the multicommodity-flow LP
(`repro.core.capacity.capacity_upper_bound`) for its capacity `lam_star`,
sweep offered rates across policies and seeds, and summarize measured
useful rate, efficiency, and the empirical stability frontier.  The result
is a JSON-serializable dict.

Regulated policies (pi2/pi3/pi2_reg/pi3_reg) inflate their computation
output by rho0 = 1 + eps_B (paper eq. (8)), so their operative bound is the
*rho0-adjusted* `lam_star / (1 + eps_B)` (Theorems 3/5), not the plain
Theorem-4 `lam_star`.  Offered rates and efficiencies here are expressed
against each policy's own bound — a regulated policy at efficiency 0.95 and
an unregulated one at 0.95 are doing equally well relative to what is
achievable for them, which is the comparison the paper's Fig. 5 makes.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.capacity import capacity_upper_bound
from repro.core.policies import PolicyConfig
from .engine import FleetJob, FleetResult, run_fleet
from .scenarios import get_scenario


def policy_bound(lam_star: float, policy: str, eps_b: float) -> float:
    """The operative throughput bound: lam_star/rho0 for regulated policies
    (rho0 = 1 + eps_B), lam_star itself otherwise."""
    return float(lam_star) / PolicyConfig(name=policy, eps_b=eps_b).rho0


def sweep_jobs(scenario_policies: Dict[str, Sequence[str]],
               rate_fracs: Sequence[float], seeds: Sequence[int],
               topo_seed: int = 0,
               lam_star_of: Dict[str, float] | None = None,
               eps_b: float = 0.01) -> List[FleetJob]:
    """Expand a {scenario: [policies]} spec into the full job grid, with
    offered rates expressed as fractions of each policy's operative bound
    (`policy_bound`): frac 0.95 loads every policy to 95% of what it could
    sustain, regulated or not."""
    jobs = []
    for scen, policies in scenario_policies.items():
        lam_star = (lam_star_of or {}).get(scen)
        if lam_star is None:
            lam_star = capacity_upper_bound(
                get_scenario(scen).build(topo_seed)).lam_star
        for pol in policies:
            bound = policy_bound(lam_star, pol, eps_b)
            for frac in rate_fracs:
                for seed in seeds:
                    jobs.append(FleetJob(scenario=scen, policy=pol,
                                         lam=float(frac) * bound,
                                         seed=int(seed),
                                         topo_seed=topo_seed,
                                         eps_b=float(eps_b)))
    return jobs


def capacity_report(scenario_policies: Dict[str, Sequence[str]],
                    rate_fracs: Sequence[float], seeds: Sequence[int],
                    T: int, chunk: int = 1024, window: int | None = None,
                    topo_seed: int = 0, devices=None,
                    eps_b: float = 0.01) -> dict:
    """Run the sweep and assemble the capacity/efficiency table.

    Per-policy rows report `bound` (the rho0-adjusted LP bound for regulated
    policies) and `efficiency` = best useful rate / bound."""
    lam_star_of = {
        scen: float(capacity_upper_bound(
            get_scenario(scen).build(topo_seed)).lam_star)
        for scen in scenario_policies}
    jobs = sweep_jobs(scenario_policies, rate_fracs, seeds,
                      topo_seed=topo_seed, lam_star_of=lam_star_of,
                      eps_b=eps_b)
    res = run_fleet(jobs, T=T, chunk=chunk, window=window, devices=devices)

    table: dict = {
        "T": res.T, "window": res.window,
        "n_sims": res.n_sims, "n_programs": res.n_programs,
        "pad_dims": {"n_nodes": res.dims.n_nodes, "n_edges": res.dims.n_edges,
                     "n_comp": res.dims.n_comp},
        "rate_fracs": [float(f) for f in rate_fracs],
        "scenarios": {},
    }
    for scen, policies in scenario_policies.items():
        lam_star = lam_star_of[scen]
        entry = {"lam_star": lam_star, "policies": {}}
        for pol in policies:
            rows = [(job, m) for job, m in zip(res.jobs, res.metrics)
                    if job.scenario == scen and job.policy == pol]
            useful = np.array([m["useful_rate"] for _, m in rows])
            offered = np.array([m["offered"] for _, m in rows])
            stable = np.array([m["stable"] for _, m in rows]) > 0.5
            best = float(useful.max()) if len(useful) else 0.0
            stable_offered = offered[stable] if stable.any() else np.array([0.0])
            bound = policy_bound(lam_star, pol, eps_b)
            entry["policies"][pol] = {
                "best_useful_rate": best,
                "rho0": PolicyConfig(name=pol, eps_b=eps_b).rho0,
                "bound": bound,
                "efficiency": best / bound if bound > 0 else 0.0,
                "max_stable_offered": float(stable_offered.max()),
                "mean_queue_at_best": float(
                    rows[int(useful.argmax())][1]["mean_queue"]) if rows else 0.0,
                "points": [
                    {"offered": float(m["offered"]),
                     "useful_rate": float(m["useful_rate"]),
                     "stable": bool(m["stable"] > 0.5),
                     "mean_queue": float(m["mean_queue"]),
                     "max_queue": float(m["max_queue"])}
                    for _, m in rows],
            }
        table["scenarios"][scen] = entry
    return table
