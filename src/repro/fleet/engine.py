"""Sharded fleet engine: thousands of simulations as a few device launches.

Jobs = (scenario x policy x rate x seed) tuples.  The engine

  1. builds each job's topology once, pads all of them to fleet-wide maxima
     (`batching.stack_problems`), and
  2. groups jobs by `PolicyConfig` — the only axis that changes Python-level
     control flow in `slot_step`, hence the only axis that forces a separate
     compiled program.  Everything else (topology, arrival model, event
     model, rate, seed) is traced data: heterogeneous scenarios ride one
     program via padded constants and `lax.switch` over model codes.
  3. runs each group as a short Python loop of `jax.jit(shard_map(vmap(
     chunk_step)))` launches over the (host-platform) device mesh — each
     launch advances one chunk of the time scan with the carry *donated*
     back into the next launch (`make_group_launch`), and per-slot *online*
     metric accumulators ride the carry — no [T]-shaped trace is ever
     allocated and the fleet state exists exactly once, so horizons of
     10^6+ slots are memory-O(1).

Per-job streaming metrics: trailing-window useful rate, running mean/max
backlog, a head/tail backlog ratio and the derived stability heuristic,
plus the streaming stability *verdict* (DESIGN.md §8): anchored
Lyapunov-drift statistics in the carry latch each sim
STABLE/UNSTABLE/UNDECIDED at a chunk boundary, `early_stop=True`
bit-freezes decided sims and stops launching chunks for fully-decided
groups.  Backlog sums are Kahan-compensated, and `NetState`'s cumulative
delivery counters are compensated at the source
(`NetState.credit_delivery`), so horizons past ~10^7 delivered packets
keep exact counts in plain float32.

Regulated policies (pi2/pi3 and the explicit `pi2_reg`/`pi3_reg` aliases)
carry the regulator parameter eps_B as *per-job traced data*, and the
Markov-modulated event/arrival models (Gilbert–Elliott fading, ON-OFF
bursty arrivals) carry their chain state through the scan — neither axis
forks a compiled program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.fault import RecoveryPlan, plan_recovery

from repro.core.graph import ComputeProblem
from repro.core.policies import PolicyConfig, slot_step
from repro.core.queues import (DriftStats, VERDICT_NAMES, VERDICT_STABLE,
                               VERDICT_UNDECIDED, VERDICT_UNSTABLE,
                               drift_verdict_update, init_state, kahan_add)
from .batching import PadDims, PaddedProblem, pad_problem
from .scenarios import (ARRIVAL_MODELS, ARRIVAL_MODEL_ORDER, EVENT_MODELS,
                        EVENT_MODEL_ORDER, ModState, arrival_code, event_code,
                        get_scenario)


@dataclasses.dataclass(frozen=True)
class FleetJob:
    """One simulation of the sweep grid."""

    scenario: str
    policy: str = "pi3"
    lam: float = 1.0
    seed: int = 0                 # simulation randomness
    topo_seed: int = 0            # topology-generator randomness
    eps_b: float = 0.01           # regulator parameter — traced per-job data,
                                  # sweeping it does not fork compiled programs
    pairing: str = "fifo"
    threshold: float = 0.0
    fixed_node: int = 0
    backend: str = "xla"          # slot-decision backend: "xla" | "pallas"
                                  # (fused tiled kernels, DESIGN.md §7)
    interpret: bool = True        # Pallas interpreter mode — True on CPU CI,
                                  # False for compiled kernels on TPU

    def policy_config(self) -> PolicyConfig:
        return PolicyConfig(
            name=self.policy, eps_b=self.eps_b, pairing=self.pairing,
            threshold=self.threshold, fixed_node=self.fixed_node,
            wireless=get_scenario(self.scenario).wireless,
            backend=self.backend, interpret=self.interpret)


@dataclasses.dataclass(frozen=True)
class VerdictConfig:
    """Streaming stability-verdict parameters (DESIGN.md §8).

    The verdict is *always* computed — `DriftStats` rides the donated scan
    carry and costs a handful of scalar ops per slot — but only
    ``freeze=True`` (what `run_fleet(early_stop=True)` resolves to) makes
    it consequential: a decided sim is bit-frozen in place inside its
    still-running padded batch, and the engine stops launching chunks for
    a group once every sim in it has decided.

    A frozen dataclass so it can key the `make_stream_runner` memo cache:
    two sweeps with the same verdict parameters share compiled programs.
    """

    window: int = 0        # verdict window in slots; <= 0 -> the chunk size
    burn_in: int = 0       # slots before evidence counts; <= 0 -> 2 windows
    k_stable: int = 3      # consecutive stable windows that latch STABLE
    k_unstable: int = 3    # consecutive unstable windows that latch UNSTABLE
    drift_tol: float = 0.02   # per-slot drift threshold, x max(lam, 1)
    gap_tol: float = 0.05     # delivered-vs-offered gap threshold, x max(lam, 1)
    freeze: bool = False      # bit-freeze decided sims (early-stop semantics)


DEFAULT_VERDICT = VerdictConfig()


def resolve_verdict(verdict: VerdictConfig | None,
                    early_stop: bool) -> VerdictConfig:
    """The verdict config `run_fleet` actually runs: the default when none
    is given, with ``freeze`` forced on when early stopping is requested.
    Shared with `fleet.frontier` so cache probes key the same runner."""
    v = verdict or DEFAULT_VERDICT
    if early_stop and not v.freeze:
        v = dataclasses.replace(v, freeze=True)
    return v


class StreamStats(NamedTuple):
    """Online accumulators carried through the scan (O(1) memory).

    The backlog sums are Kahan-compensated (`c_*` carry the compensation
    term) so float32 running sums stay accurate far beyond the naive
    ~2^24-increment saturation point.  The *cumulative* delivery counters
    live in `NetState` and are compensated the same way
    (`NetState.credit_delivery`, DESIGN.md §4).
    """

    sum_queue: jax.Array          # [] running sum of total backlog
    c_queue: jax.Array            # [] Kahan compensation for sum_queue
    sum_queue_q3: jax.Array       # [] backlog sum over slots [T/2, 3T/4)
    c_q3: jax.Array
    sum_queue_q4: jax.Array       # [] backlog sum over slots [3T/4, T)
    c_q4: jax.Array
    max_queue: jax.Array          # []
    useful_at_mark: jax.Array     # [] cumulative useful count at window start

    @staticmethod
    def zero() -> "StreamStats":
        z = jnp.zeros((), jnp.float32)
        return StreamStats(z, z, z, z, z, z, z, z)


def make_stream_runner(cfg: PolicyConfig, T: int, chunk: int = 1024,
                       window: int | None = None,
                       verdict: VerdictConfig | None = None):
    """Build `run(pp, lam, eps_b, akind, ekind, key, arrivals=None) -> dict`.

    Memoized on `(cfg, T, chunk, window, verdict)` (PolicyConfig and
    VerdictConfig are frozen, hashable dataclasses): repeated calls — every
    `stream_simulate`, every `run_fleet` group with the same shape, every
    frontier bisection step — get the *same* runner object, so the
    `jax.jit` caches hanging off it (`make_group_launch`, the
    `stream_simulate` closed program) are reused instead of re-traced.

    `eps_b` is the regulator parameter as *traced per-job data* (ignored by
    unregulated policies); a `ModState` (Gilbert–Elliott link/comp chains,
    the bursty-arrival phase) rides the scan carry next to `NetState`, so
    Markov-modulated scenarios stay O(1) in memory too.

    The horizon is rounded up to a whole number of chunks; `run.T` exposes
    the effective slot count.  With `arrivals=None` the arrival process is
    generated per-slot from (key, t) — passing an explicit [T] trace is the
    reference path used by equivalence tests (the arrival modulation chain
    is bypassed; event chains still run).

    Besides `run` (a single closed program, used by `stream_simulate` and
    the explicit-arrivals path), the returned object exposes the pieces the
    fleet engine drives chunk-by-chunk from Python with a *donated* carry
    (`run_fleet`): `run.init_carry(pp)`, `run.chunk_step(pp, lam, eps_b,
    akind, ekind, key, carry)` (advances `chunk` slots; the slot index in
    the carry keeps the RNG stream and window marks global), and
    `run.finalize(lam, eps_b, carry)` (the metrics dict).  `run.n_chunks`
    is the number of chunk_step applications that make up one run.
    """
    # Normalize before the memo key: `verdict=None` and an explicit
    # DEFAULT_VERDICT must hit the same cache entry, or stream_simulate
    # (passes None) and run_fleet (passes the resolved config) would each
    # compile their own copy of an identical program.
    return _make_stream_runner(cfg, T, chunk, window,
                               verdict or DEFAULT_VERDICT)


@functools.lru_cache(maxsize=64)
def _make_stream_runner(cfg: PolicyConfig, T: int, chunk: int,
                        window: int | None, verdict: VerdictConfig):
    chunk = max(1, min(chunk, T))
    n_chunks = -(-T // chunk)
    T_eff = n_chunks * chunk
    win = T_eff // 2 if window is None else min(window, T_eff)
    win = max(win, 1)             # T==1 / window==0 would divide by zero
    mark = T_eff - win            # windowed rate baseline: end of slot mark-1
    q3_lo, q4_lo = T_eff // 2, (3 * T_eff) // 4
    vcfg = verdict
    # Verdict windows default to the chunk length so decisions land exactly
    # on the boundaries the engine's Python chunk loop can observe; the
    # burn-in skips the fill-up transient (DESIGN.md §8).
    vwin = chunk if vcfg.window <= 0 else max(1, min(vcfg.window, T_eff))
    vburn = 2 * vwin if vcfg.burn_in <= 0 else vcfg.burn_in

    arrival_branches = tuple(ARRIVAL_MODELS[k] for k in ARRIVAL_MODEL_ORDER)
    event_branches = tuple(EVENT_MODELS[k] for k in EVENT_MODEL_ORDER)

    def slot(pp, lam, eps_b, akind, ekind, key, carry, slot_arr):
        state, stats, drift, mod, t = carry
        kt = jax.random.fold_in(key, t)
        k_arr, k_ev, k_step = jax.random.split(kt, 3)
        if slot_arr is None:
            arr, mod2 = jax.lax.switch(akind, arrival_branches, k_arr, lam,
                                       mod)
        else:
            arr, mod2 = slot_arr, mod
        esc, csc, mod2 = jax.lax.switch(ekind, event_branches, pp, t, k_ev,
                                        mod2)
        new_state, m = slot_step(pp.with_capacity_scales(esc, csc), cfg,
                                 state, arr, k_step, eps_b=eps_b)
        tq = m["total_queue"]
        sq, cq = kahan_add(stats.sum_queue, stats.c_queue, tq)
        s3, c3 = kahan_add(stats.sum_queue_q3, stats.c_q3,
                           tq * ((t >= q3_lo) & (t < q4_lo)))
        s4, c4 = kahan_add(stats.sum_queue_q4, stats.c_q4, tq * (t >= q4_lo))
        new_stats = StreamStats(
            sum_queue=sq, c_queue=cq,
            sum_queue_q3=s3, c_q3=c3,
            sum_queue_q4=s4, c_q4=c4,
            max_queue=jnp.maximum(stats.max_queue, tq),
            useful_at_mark=jnp.where(t == mark - 1, m["delivered_useful"],
                                     stats.useful_at_mark),
        )
        new_drift = drift_verdict_update(
            drift, t, tq, m["delivered_useful"], lam,
            window=vwin, burn_in=vburn, k_stable=vcfg.k_stable,
            k_unstable=vcfg.k_unstable, drift_tol=vcfg.drift_tol,
            gap_tol=vcfg.gap_tol)
        new_carry = (new_state, new_stats, new_drift, mod2, t + 1)
        if vcfg.freeze:
            # Per-sim freeze mask: a sim whose verdict latched *before*
            # this slot passes its whole carry through bit-unchanged (t
            # included, so the RNG stream and window marks stay pinned at
            # decided_at) while the rest of the padded batch keeps
            # running.  where(False, old, new) is exactly `new`, so
            # undecided sims are bit-identical to a freeze-free run.
            frozen = drift.verdict != VERDICT_UNDECIDED
            new_carry = jax.tree_util.tree_map(
                lambda o, n: jnp.where(frozen, o, n), carry, new_carry)
        return new_carry, None

    def init_carry(pp: PaddedProblem):
        return (init_state(pp), StreamStats.zero(), DriftStats.zero(),
                ModState.init(pp), jnp.int32(0))

    def chunk_step(pp: PaddedProblem, lam, eps_b, akind, ekind, key, carry):
        """Advance one chunk of slots.  Pure; the engine jits this with
        `donate_argnums` on `carry` so the scan carry is updated in place
        across the Python-level chunk loop (no 2x peak on the [B, N, 3, NC]
        queue state at fleet batch sizes)."""
        body = functools.partial(slot, pp, lam, eps_b, akind, ekind, key)
        carry, _ = jax.lax.scan(lambda c, x: body(c, None), carry,
                                xs=None, length=chunk)
        return carry

    def finalize(lam, eps_b, carry) -> Dict[str, jax.Array]:
        state, stats, drift, _, t = carry
        mean_q3 = stats.sum_queue_q3 / max(q4_lo - q3_lo, 1)
        mean_q4 = stats.sum_queue_q4 / max(T_eff - q4_lo, 1)
        decided = drift.verdict != VERDICT_UNDECIDED
        decided_at = jnp.where(decided, drift.decided_at,
                               T_eff).astype(jnp.float32)
        # Heuristic verdict comparing the 3rd vs 4th quarter of the run
        # (both past the fill-up transient): a stable network's backlog
        # plateaus, so the ratio stays near 1; linearly growing backlog
        # (instability) gives mean_q4/mean_q3 -> 7/5.
        stable_heur = mean_q4 <= 1.25 * mean_q3 + 5.0
        useful_rate = (state.delivered_useful - stats.useful_at_mark) / win
        # `t` is the per-sim slots-advanced counter (frozen sims pin it at
        # decided_at); dividing by it — a *runtime* value in every program
        # — keeps frozen and full-horizon runs emitting the identical
        # division op, so their mean_queue agrees bit-for-bit (a constant
        # T_eff denominator would constant-fold to a reciprocal multiply).
        mean_queue = stats.sum_queue / jnp.maximum(t.astype(jnp.float32),
                                                   1.0)
        slots_saved = jnp.zeros((), jnp.float32)
        if vcfg.freeze:
            # A frozen sim's accumulators stop at decided_at: the trailing
            # useful-rate window and the q3/q4 heuristic never complete, so
            # report the last full verdict window's (anchored) rate and let
            # the latched verdict *be* the stability flag.
            useful_rate = jnp.where(decided, drift.last_rate, useful_rate)
            stable_heur = jnp.where(decided, drift.verdict == VERDICT_STABLE,
                                    stable_heur)
            slots_saved = jnp.where(decided, T_eff - decided_at, 0.0)
        return {
            "offered": jnp.asarray(lam, jnp.float32),
            "eps_b": jnp.asarray(eps_b, jnp.float32),
            "useful_rate": useful_rate,
            "delivered": state.delivered,
            "delivered_useful": state.delivered_useful,
            "delivered_dummy": state.delivered - state.delivered_useful,
            "mean_queue": mean_queue,
            "mean_queue_mid": mean_q3,
            "mean_queue_tail": mean_q4,
            "max_queue": stats.max_queue,
            "stable": stable_heur.astype(jnp.float32),
            # Streaming verdict (DESIGN.md §8): latched drift-test outcome,
            # the slot it latched at (= T for undecided sims), and the
            # simulated slots the freeze saved (0 unless freezing is on).
            "verdict": drift.verdict.astype(jnp.float32),
            "decided_at_slot": decided_at,
            "slots_saved": slots_saved,
        }

    def run(pp: PaddedProblem, lam, eps_b, akind, ekind, key,
            arrivals: jax.Array | None = None) -> Dict[str, jax.Array]:
        carry = init_carry(pp)
        if arrivals is None:
            def chunk_body(c, _):
                return chunk_step(pp, lam, eps_b, akind, ekind, key, c), None
            carry, _ = jax.lax.scan(chunk_body, carry, xs=None,
                                    length=n_chunks)
        else:
            if arrivals.shape[0] != T_eff:
                raise ValueError(
                    f"explicit arrivals must have length {T_eff} "
                    f"(= n_chunks*chunk), got {arrivals.shape[0]}")
            # Reshape the arrival trace to [n_chunks, chunk] once, ahead of
            # the chunk scan — not per chunk.
            arr_chunks = arrivals.astype(jnp.float32).reshape(n_chunks, chunk)
            body = functools.partial(slot, pp, lam, eps_b, akind, ekind, key)
            def chunk_body(c, a):
                c, _ = jax.lax.scan(body, c, a)
                return c, None
            carry, _ = jax.lax.scan(chunk_body, carry, arr_chunks)
        return finalize(lam, eps_b, carry)

    run.T = T_eff
    run.window = win
    run.chunk = chunk
    run.n_chunks = n_chunks
    run.verdict_window = vwin
    run.verdict_burn_in = vburn
    run.init_carry = init_carry
    run.chunk_step = chunk_step
    run.finalize = finalize
    # Cheap between-chunk readout: the [B] int32 verdict leaf of the carry
    # (the only thing `run_fleet` transfers per chunk when early-stopping).
    run.verdict_of = lambda carry: carry[2].verdict
    # Atlas readout (DESIGN.md §10): the two drift leaves a bisection host
    # loop needs per launch boundary — latched verdict + the slot it
    # latched at — without running `finalize` mid-flight.
    run.drift_of = lambda carry: (carry[2].verdict, carry[2].decided_at)

    def probe(carry) -> Dict[str, jax.Array]:
        """Telemetry tap (DESIGN.md §11): the windowed-rate / backlog /
        drift leaves of the carry, as plain pytree indexing — no program,
        so tapping cannot fork the compiled chunk step.  The emitter
        differences consecutive probes into per-chunk stream records."""
        state, stats, drift, _, t = carry
        return {
            "t": t,
            "delivered_useful": state.delivered_useful,
            "sum_queue": stats.sum_queue,
            "max_queue": stats.max_queue,
            "last_rate": drift.last_rate,
            "last_drift": drift.last_drift,
            "verdict": drift.verdict,
            "decided_at": drift.decided_at,
        }

    run.probe = probe
    return run


def stream_simulate(problem: ComputeProblem, cfg: PolicyConfig, lam: float,
                    T: int, chunk: int = 1024, window: int | None = None,
                    seed: int = 0, arrivals: jax.Array | None = None,
                    arrival: str = "poisson", events: str = "static",
                    dims: PadDims | None = None) -> Dict[str, jax.Array]:
    """Single-problem streaming simulation (the fleet path without sharding).

    Memory is O(N + E) regardless of T — the reference `simulate` keeps
    O(T) traces.  Matches `simulate(...).delivered_useful[-1]` exactly for
    key-free policies (pi1/pi1'/pi3bar) given the same arrival trace.
    """
    dims = dims or PadDims.of([problem])
    pp = pad_problem(problem, dims)
    run = make_stream_runner(cfg, T, chunk=chunk, window=window)
    # `run` is memoized and `arrivals` is passed as a traced operand (None
    # is static pytree structure), so repeated calls with the same
    # (cfg, T, chunk, window) share one compiled program instead of
    # re-jitting a fresh partial per invocation.
    out = _jit_run(run)(
        pp, jnp.float32(lam), jnp.float32(cfg.eps_b), arrival_code(arrival),
        event_code(events), jax.random.PRNGKey(seed), arrivals)
    return out


@functools.lru_cache(maxsize=64)
def _jit_run(run):
    return jax.jit(run)


@dataclasses.dataclass
class FleetResult:
    jobs: List[FleetJob]
    metrics: List[Dict[str, float]]     # one dict per job, same order
    n_programs: int
    n_sims: int
    dims: PadDims
    T: int
    window: int
    memory_stats: Dict[str, float] | None = None  # XLA memory analysis of the
                                                  # largest chunk-step program
                                                  # (run_fleet(memory_stats=True))
    slots_saved: int = 0          # sum of per-sim frozen slots (early stop):
                                  # simulated slots never advanced past each
                                  # sim's decided_at_slot
    launch_slots_saved: int = 0   # device-level savings: slots in chunk
                                  # launches skipped once a whole group
                                  # decided (<= slots_saved)
    stream_records: List[dict] = dataclasses.field(default_factory=list)
                                  # per-chunk telemetry (run_fleet(stream=True),
                                  # DESIGN.md §11), schema'd by repro.obs
    resumed_from: int | None = None    # checkpoint step this run restored
                                       # (None = started fresh), DESIGN.md §12
    degraded: Dict[int, str] = dataclasses.field(default_factory=dict)
                                  # job index -> reason for jobs whose lanes
                                  # were parked by a host dropout: their
                                  # metrics reflect a truncated sim and must
                                  # not be read as converged (degraded, not
                                  # silent)
    recovery_plan: RecoveryPlan | None = None  # re-plan for the dropout
    n_fault_retries: int = 0      # transient launch failures absorbed

    def column(self, name: str) -> np.ndarray:
        return np.array([m[name] for m in self.metrics])

    def verdicts(self) -> List[str]:
        """Per-job streaming verdicts as names (DESIGN.md §8)."""
        return [VERDICT_NAMES[int(m["verdict"])] for m in self.metrics]


@functools.lru_cache(maxsize=64)
def make_group_launch(runner, mesh: Mesh, n_step_args: int = 7):
    """Jit the three per-group programs of the chunked fleet launch.

    Returns `(init_fn, step_fn, fin_fn)`, each a
    `jax.jit(shard_map(vmap(...)))` over the `"fleet"` mesh axis.  `step_fn`
    donates its carry argument — the *last* of the `n_step_args` chunk-step
    arguments (7 for the fleet runner, 6 for the serving runner, which has
    no arrival-model switch code): across the Python-level chunk loop the
    [B, N, 3, NC] queue state is updated in place instead of being
    double-buffered — the memory audit that matters once B·N·NC grows past
    cache sizes.  Donation is asserted by `tests/test_fleet.py::TestDonation`.

    Memoized on `(runner, mesh, n_step_args)` (runners are themselves
    memoized, Mesh is hashable): two sweeps over the same policy group
    reuse the compiled programs instead of re-tracing, and within one sweep
    the chunk loop is guaranteed a single compilation
    (`tests/test_fleet.py::TestNoRecompilation`)."""
    spec = P("fleet")

    def _sharded(fn, n_in):
        return shard_map(jax.vmap(fn), mesh=mesh, in_specs=(spec,) * n_in,
                         out_specs=spec,
                         check_rep=False)  # scan carries: no replication rule
    init_fn = jax.jit(_sharded(runner.init_carry, 1))
    step_fn = jax.jit(_sharded(runner.chunk_step, n_step_args),
                      donate_argnums=(n_step_args - 1,))
    fin_fn = jax.jit(_sharded(runner.finalize, 3))
    return init_fn, step_fn, fin_fn


@functools.lru_cache(maxsize=64)
def make_sim_rewriter(runner, mesh: Mesh):
    """Jit the per-sim carry *rewrite* of the capacity atlas (DESIGN.md §10).

    Returns ``rewrite_fn(pp, reset, park, carry) -> carry``, a
    `jax.jit(shard_map(vmap(...)))` over the same `"fleet"` mesh axis as
    `make_group_launch`, with the carry donated like the chunk step.  Two
    [B] bool masks drive it at a launch boundary:

      * ``reset`` — the lane starts its cell's *next* bisection probe:
        its whole carry is replaced by a fresh `init_carry(pp)` (t = 0
        included, so the RNG stream restarts under the new fold_seed key
        the host passes to the next launch).  `where(False, fresh, old)`
        is exactly ``old``, so untouched lanes are bit-identical to a
        rewrite-free run — the atlas-vs-sequential equivalence hinge.
      * ``park`` — the lane's cell finished its whole search: the verdict
        leaf is forced to UNSTABLE so the freeze mask pins the carry
        bit-exactly for every remaining launch (a no-op unless the runner
        freezes, i.e. `early_stop=True` semantics).

    Memoized on `(runner, mesh)` like the launch programs: one compiled
    rewrite per policy group, asserted by the atlas single-compile test."""
    spec = P("fleet")

    def rewrite(pp, reset, park, carry):
        fresh = runner.init_carry(pp)
        state, stats, drift, mod, t = jax.tree_util.tree_map(
            lambda f, o: jnp.where(reset, f, o), fresh, carry)
        drift = drift._replace(verdict=jnp.where(
            park, jnp.int32(VERDICT_UNSTABLE), drift.verdict))
        return (state, stats, drift, mod, t)

    return jax.jit(
        shard_map(jax.vmap(rewrite), mesh=mesh, in_specs=(spec,) * 4,
                  out_specs=spec, check_rep=False),
        donate_argnums=(3,))


def _memory_analysis(step_fn, args) -> Dict[str, float] | None:
    """XLA memory analysis of a compiled chunk-step (peak/live byte sizes).

    Best-effort: backends without `memory_analysis` return None."""
    try:
        ma = step_fn.lower(*args).compile().memory_analysis()
        if ma is None:
            return None
        # The output carry is donated onto the input carry (aliased), so
        # counting argument + output + temp would double-count the fleet
        # state; peak live memory of a launch is arguments + temporaries.
        return {
            "argument_bytes": float(ma.argument_size_in_bytes),
            "output_bytes": float(ma.output_size_in_bytes),
            "temp_bytes": float(ma.temp_size_in_bytes),
            "peak_bytes": float(ma.argument_size_in_bytes
                                + ma.temp_size_in_bytes),
        }
    except Exception:  # pragma: no cover - backend-dependent surface
        return None


def _policy_group_key(job: FleetJob):
    """Axes that change Python-level control flow => separate XLA program.

    Deliberately *semantic*, not the policy name: pi3 and pi3_reg trace to
    identical programs (both regulated, load-balancing), and eps_b is traced
    per-job data — so a sweep over regulator parameters, or over plain and
    ``_reg``-aliased variants, still compiles once per behavior."""
    cfg = job.policy_config()
    return (cfg.use_regulator, cfg.load_balance, cfg.thresholded,
            cfg.pairing, cfg.threshold, cfg.fixed_node, cfg.wireless,
            # interpret only matters when the pallas kernels actually run;
            # keying it unconditionally would fork identical xla programs.
            cfg.backend, cfg.interpret if cfg.backend == "pallas" else None)


def run_fleet(jobs: Sequence[FleetJob], T: int, chunk: int = 1024,
              window: int | None = None, devices=None,
              dims: PadDims | None = None,
              memory_stats: bool = False,
              early_stop: bool = False,
              verdict: VerdictConfig | None = None,
              stream: bool = False,
              stream_log=None,
              stream_path: str | None = None,
              resilience=None) -> FleetResult:
    """Run the whole sweep, one compiled program set per policy group.

    Each group runs as a Python-level loop of `n_chunks` launches of one
    `jit(shard_map(vmap(chunk_step)))` with the scan carry *donated*
    between launches (`make_group_launch`), so arbitrarily long horizons
    keep a single in-place copy of the fleet state.  `memory_stats=True`
    additionally attaches the XLA memory analysis of the largest group's
    chunk-step program to the result (one extra lowering, so opt-in).

    ``early_stop=True`` turns the streaming stability verdict
    (DESIGN.md §8) into an actual early exit: decided sims are bit-frozen
    inside their still-running padded batch (``VerdictConfig.freeze``),
    the [B] verdict leaf is read back between chunk launches, and a group
    stops launching chunks as soon as *every* sim in it has decided.
    Per-sim savings land in each row's ``slots_saved`` (simulated slots
    never advanced past ``decided_at_slot``); launch-level savings — the
    chunks that were never dispatched — in ``FleetResult.launch_slots_saved``.

    ``stream=True`` (implied by ``stream_log``/``stream_path``) turns on
    the telemetry plane (DESIGN.md §11): after every chunk launch the
    engine dispatches the carry's probe leaves through the io_callback
    emitter — a separate tiny program, so the chunk step is byte-identical
    to a telemetry-off run and all metrics stay bit-equal.  Records land
    in ``FleetResult.stream_records``; ``stream_path`` additionally
    appends them live as JSONL (tail with ``capacity_report --follow``)
    and ``stream_log`` is called per record *on the callback thread*.

    ``resilience`` (a `runtime.resilience.ResilienceConfig`) makes the run
    preemption-safe (DESIGN.md §12): the donated carry + host cursor are
    snapshotted at chunk boundaries (before the next launch donates the
    buffers), a killed run resumes bit-exact from the newest intact
    checkpoint, injected launch failures retry with bounded backoff, and
    host dropouts park the dead lanes via `make_sim_rewriter` — surfaced
    in ``FleetResult.degraded``/``recovery_plan`` rather than aborting.
    """
    jobs = list(jobs)
    stream = stream or stream_log is not None or stream_path is not None
    vcfg = resolve_verdict(verdict, early_stop)
    devices = list(devices or jax.devices())
    ndev = len(devices)
    mesh = Mesh(np.array(devices), ("fleet",))

    # Build and pad every distinct topology once; jobs share by reference.
    problem_of: Dict[tuple, ComputeProblem] = {}
    for job in jobs:
        k = (job.scenario, job.topo_seed)
        if k not in problem_of:
            problem_of[k] = get_scenario(job.scenario).build(job.topo_seed)
    dims = dims or PadDims.of(list(problem_of.values()))
    padded_of = {k: pad_problem(p, dims) for k, p in problem_of.items()}

    groups: Dict[tuple, List[int]] = {}
    for i, job in enumerate(jobs):
        groups.setdefault(_policy_group_key(job), []).append(i)

    rt = resumed = None
    if resilience is not None:
        from repro.runtime.resilience import (host_lane_mask as
                                              _host_lane_mask,
                                              maybe_resilient)
        rt = maybe_resilient(resilience, "fleet", jobs=tuple(jobs), T=T,
                             chunk=chunk, window=window, verdict=vcfg,
                             early_stop=early_stop, dims=dims, ndev=ndev)
        resumed = rt.resumed

    metrics: List[Dict[str, float] | None] = [None] * len(jobs)
    eff_T = eff_win = 0
    launch_saved = 0
    glaunch = 0                    # launches completed, across groups — the
                                   # checkpoint step / fault-plane clock
    degraded: Dict[int, str] = {}
    recovery = None
    mem: Dict[str, float] | None = None
    mem_B = -1
    sink = None
    if stream:
        from repro.obs.emitter import StreamSink
        sink = StreamSink(path=stream_path, log=stream_log,
                          append=resumed is not None)
    if resumed is not None:
        from repro.runtime.resilience import metrics_restore, plan_restore
        for i, m in enumerate(metrics_restore(resumed["metrics"])):
            if m is not None:
                metrics[i] = m
        launch_saved = resumed["launch_saved"]
        glaunch = resumed["global_launch"]
        degraded = {int(k): v for k, v in resumed["degraded"].items()}
        recovery = plan_restore(resumed["recovery"])
    try:
        for g, (gkey, idxs) in enumerate(groups.items()):
            cfg = jobs[idxs[0]].policy_config()
            runner = make_stream_runner(cfg, T, chunk=chunk, window=window,
                                        verdict=vcfg)
            eff_T, eff_win = runner.T, runner.window
            if resumed is not None and g < resumed["group"]:
                continue          # finished pre-kill: metrics restored above

            # Per-group host work is hoisted to exactly here — one batch of
            # device constants per group, built *before* the chunk loop.  Pad
            # the group batch to a multiple of the mesh size by repeating the
            # last job; replicas are dropped when results are scattered back.
            B = len(idxs)
            Bp = -(-B // ndev) * ndev
            padded_idxs = idxs + [idxs[-1]] * (Bp - B)
            pp = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[padded_of[(jobs[i].scenario, jobs[i].topo_seed)]
                  for i in padded_idxs])
            lam = jnp.array([jobs[i].lam for i in padded_idxs], jnp.float32)
            eps = jnp.array([jobs[i].eps_b for i in padded_idxs], jnp.float32)
            ak = jnp.array([arrival_code(
                get_scenario(jobs[i].scenario).arrival)
                for i in padded_idxs], jnp.int32)
            ek = jnp.array([event_code(get_scenario(jobs[i].scenario).events)
                            for i in padded_idxs], jnp.int32)
            # One vmapped derivation instead of B host-side PRNGKey calls.
            # int32 keeps negative seeds legal (uint32 would overflow at the
            # host conversion); PRNGKey folds them identically either way.
            keys = jax.vmap(jax.random.PRNGKey)(
                jnp.array([jobs[i].seed for i in padded_idxs], jnp.int32))

            init_fn, step_fn, fin_fn = make_group_launch(runner, mesh)
            emitter = None
            try:
                if sink is not None:
                    from repro.obs.emitter import ChunkEmitter
                    emitter = ChunkEmitter("fleet", group=g, n_real=B,
                                           runner=runner, mesh=mesh,
                                           sink=sink)
                launched = 0
                if resumed is not None and g == resumed["group"]:
                    launched = resumed["launched"]
                    if launched > 0:
                        # Bit-exact restore of the donated carry at the
                        # snapshot boundary; lam/eps/keys/... above were
                        # rebuilt deterministically from the job list.
                        like = jax.eval_shape(init_fn, pp)
                        carry = rt.restore_carry(like, mesh)
                    else:
                        carry = init_fn(pp)
                    if emitter is not None and launched > 0:
                        # The snapshot probe is derivable from the carry:
                        # runner.probe is pure pytree indexing.
                        emitter.restore_clock(
                            launched, {k: np.asarray(v) for k, v in
                                       runner.probe(carry).items()})
                    if sink is not None:
                        from repro.obs import schema
                        sink.write(schema.make_record(
                            "resume", group=g, chunk=launched,
                            t=launched * runner.chunk, n_sims=B,
                            engine="fleet",
                            ckpt_step=resumed["ckpt_step"],
                            n_preloaded=sink.n_preloaded))
                else:
                    carry = init_fn(pp)
                while launched < runner.n_chunks:
                    if rt is not None:
                        carry = rt.launch(g, glaunch, step_fn, pp, lam, eps,
                                          ak, ek, keys, carry)
                    else:
                        carry = step_fn(pp, lam, eps, ak, ek, keys, carry)
                    launched += 1
                    glaunch += 1
                    if emitter is not None:
                        # Dispatch the chunk-boundary telemetry probe
                        # *before* the next launch donates these carry
                        # buffers (DESIGN.md §11); non-blocking — records
                        # assemble on the callback thread.
                        emitter.emit(runner.probe(carry))
                    if rt is not None:
                        dead = rt.dead_hosts(glaunch)
                        if dead:
                            lane_dead = _host_lane_mask(Bp, ndev, dead)
                            fresh = [l for l in range(B) if lane_dead[l]
                                     and idxs[l] not in degraded]
                            if fresh:
                                # Park the dead lanes: their verdict leaf
                                # is forced UNSTABLE (bit-frozen under
                                # early_stop), their jobs flagged degraded.
                                carry = make_sim_rewriter(runner, mesh)(
                                    pp, jnp.zeros(Bp, bool),
                                    jnp.asarray(lane_dead), carry)
                                per = Bp // ndev
                                for l in fresh:
                                    degraded[idxs[l]] = \
                                        f"host_dropout:host{l // per}"
                                recovery = plan_recovery(
                                    ndev, 1,
                                    [f"host{h}" for h in dead], [], 1)
                        if rt.should_snapshot(glaunch):
                            from repro.runtime.resilience import plan_state
                            rt.snapshot(glaunch, carry, {
                                "group": g, "launched": launched,
                                "global_launch": glaunch,
                                "metrics": metrics,
                                "launch_saved": launch_saved,
                                "degraded": {str(k): v
                                             for k, v in degraded.items()},
                                "recovery": plan_state(recovery)})
                        # After the snapshot: a simulated SIGTERM here
                        # leaves a durable, bit-exact resume point.
                        rt.maybe_preempt(glaunch)
                    if early_stop and launched < runner.n_chunks:
                        # Between-chunk readout of the [Bp] int32 verdict
                        # leaf — the mid-run readout the donated-carry
                        # structure permits.  All sims (mesh-padding
                        # replicas mirror a real job) decided => the
                        # remaining chunks would only shuffle frozen bits;
                        # stop dispatching them.
                        v = np.asarray(
                            jax.device_get(runner.verdict_of(carry)))
                        if np.all(v != VERDICT_UNDECIDED):
                            break
                launch_saved += (len(idxs) * (runner.n_chunks - launched)
                                 * runner.chunk)
                if memory_stats and Bp > mem_B:
                    m = _memory_analysis(step_fn,
                                         (pp, lam, eps, ak, ek, keys, carry))
                    if m is not None:
                        mem, mem_B = m, Bp
                out = jax.device_get(fin_fn(lam, eps, carry))
                for j, i in enumerate(idxs):
                    metrics[i] = {k: float(v[j]) for k, v in out.items()}
            finally:
                if emitter is not None:
                    emitter.close()   # flush in-flight records, also when
                                      # a fault/preemption propagates
            if rt is not None:
                from repro.runtime.resilience import plan_state
                # Group-boundary marker: a kill between groups resumes at
                # g+1 with the finished metrics, never re-running group g.
                rt.snapshot(glaunch, (), {
                    "group": g + 1, "launched": 0, "global_launch": glaunch,
                    "metrics": metrics, "launch_saved": launch_saved,
                    "degraded": {str(k): v for k, v in degraded.items()},
                    "recovery": plan_state(recovery)})
    finally:
        if sink is not None:
            sink.close()
    return FleetResult(jobs=jobs, metrics=metrics, n_programs=len(groups),
                       n_sims=len(jobs), dims=dims, T=eff_T, window=eff_win,
                       memory_stats=mem,
                       slots_saved=int(sum(m["slots_saved"]
                                           for m in metrics)),
                       launch_slots_saved=launch_saved,
                       stream_records=sink.records if sink is not None
                       else [],
                       resumed_from=(resumed["ckpt_step"]
                                     if resumed is not None else None),
                       degraded=degraded, recovery_plan=recovery,
                       n_fault_retries=(rt.n_retries if rt is not None
                                        else 0))
