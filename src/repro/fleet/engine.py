"""Sharded fleet engine: thousands of simulations as a few device launches.

Jobs = (scenario x policy x rate x seed) tuples.  The engine

  1. builds each job's topology once, pads all of them to fleet-wide maxima
     (`batching.stack_problems`), and
  2. groups jobs by `PolicyConfig` — the only axis that changes Python-level
     control flow in `slot_step`, hence the only axis that forces a separate
     compiled program.  Everything else (topology, arrival model, event
     model, rate, seed) is traced data: heterogeneous scenarios ride one
     program via padded constants and `lax.switch` over model codes.
  3. runs each group as a short Python loop of `jax.jit(shard_map(vmap(
     chunk_step)))` launches over the (host-platform) device mesh — each
     launch advances one chunk of the time scan with the carry *donated*
     back into the next launch (`make_group_launch`), and per-slot *online*
     metric accumulators ride the carry — no [T]-shaped trace is ever
     allocated and the fleet state exists exactly once, so horizons of
     10^6+ slots are memory-O(1).

Per-job streaming metrics: trailing-window useful rate, running mean/max
backlog, a head/tail backlog ratio and the derived stability verdict.
Backlog sums are Kahan-compensated, and `NetState`'s cumulative delivery
counters are compensated at the source (`NetState.credit_delivery`), so
horizons past ~10^7 delivered packets keep exact counts in plain float32.

Regulated policies (pi2/pi3 and the explicit `pi2_reg`/`pi3_reg` aliases)
carry the regulator parameter eps_B as *per-job traced data*, and the
Markov-modulated event/arrival models (Gilbert–Elliott fading, ON-OFF
bursty arrivals) carry their chain state through the scan — neither axis
forks a compiled program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import ComputeProblem
from repro.core.policies import PolicyConfig, slot_step
from repro.core.queues import init_state, kahan_add
from .batching import PadDims, PaddedProblem, pad_problem
from .scenarios import (ARRIVAL_MODELS, ARRIVAL_MODEL_ORDER, EVENT_MODELS,
                        EVENT_MODEL_ORDER, ModState, arrival_code, event_code,
                        get_scenario)


@dataclasses.dataclass(frozen=True)
class FleetJob:
    """One simulation of the sweep grid."""

    scenario: str
    policy: str = "pi3"
    lam: float = 1.0
    seed: int = 0                 # simulation randomness
    topo_seed: int = 0            # topology-generator randomness
    eps_b: float = 0.01           # regulator parameter — traced per-job data,
                                  # sweeping it does not fork compiled programs
    pairing: str = "fifo"
    threshold: float = 0.0
    fixed_node: int = 0
    backend: str = "xla"          # slot-decision backend: "xla" | "pallas"
                                  # (fused tiled kernels, DESIGN.md §7)
    interpret: bool = True        # Pallas interpreter mode — True on CPU CI,
                                  # False for compiled kernels on TPU

    def policy_config(self) -> PolicyConfig:
        return PolicyConfig(
            name=self.policy, eps_b=self.eps_b, pairing=self.pairing,
            threshold=self.threshold, fixed_node=self.fixed_node,
            wireless=get_scenario(self.scenario).wireless,
            backend=self.backend, interpret=self.interpret)


class StreamStats(NamedTuple):
    """Online accumulators carried through the scan (O(1) memory).

    The backlog sums are Kahan-compensated (`c_*` carry the compensation
    term) so float32 running sums stay accurate far beyond the naive
    ~2^24-increment saturation point.  The *cumulative* delivery counters
    live in `NetState` and are compensated the same way
    (`NetState.credit_delivery`, DESIGN.md §4).
    """

    sum_queue: jax.Array          # [] running sum of total backlog
    c_queue: jax.Array            # [] Kahan compensation for sum_queue
    sum_queue_q3: jax.Array       # [] backlog sum over slots [T/2, 3T/4)
    c_q3: jax.Array
    sum_queue_q4: jax.Array       # [] backlog sum over slots [3T/4, T)
    c_q4: jax.Array
    max_queue: jax.Array          # []
    useful_at_mark: jax.Array     # [] cumulative useful count at window start

    @staticmethod
    def zero() -> "StreamStats":
        z = jnp.zeros((), jnp.float32)
        return StreamStats(z, z, z, z, z, z, z, z)


@functools.lru_cache(maxsize=64)
def make_stream_runner(cfg: PolicyConfig, T: int, chunk: int = 1024,
                       window: int | None = None):
    """Build `run(pp, lam, eps_b, akind, ekind, key, arrivals=None) -> dict`.

    Memoized on `(cfg, T, chunk, window)` (PolicyConfig is a frozen,
    hashable dataclass): repeated calls — every `stream_simulate`, every
    `run_fleet` group with the same shape — get the *same* runner object,
    so the `jax.jit` caches hanging off it (`make_group_launch`, the
    `stream_simulate` closed program) are reused instead of re-traced.

    `eps_b` is the regulator parameter as *traced per-job data* (ignored by
    unregulated policies); a `ModState` (Gilbert–Elliott link/comp chains,
    the bursty-arrival phase) rides the scan carry next to `NetState`, so
    Markov-modulated scenarios stay O(1) in memory too.

    The horizon is rounded up to a whole number of chunks; `run.T` exposes
    the effective slot count.  With `arrivals=None` the arrival process is
    generated per-slot from (key, t) — passing an explicit [T] trace is the
    reference path used by equivalence tests (the arrival modulation chain
    is bypassed; event chains still run).

    Besides `run` (a single closed program, used by `stream_simulate` and
    the explicit-arrivals path), the returned object exposes the pieces the
    fleet engine drives chunk-by-chunk from Python with a *donated* carry
    (`run_fleet`): `run.init_carry(pp)`, `run.chunk_step(pp, lam, eps_b,
    akind, ekind, key, carry)` (advances `chunk` slots; the slot index in
    the carry keeps the RNG stream and window marks global), and
    `run.finalize(lam, eps_b, carry)` (the metrics dict).  `run.n_chunks`
    is the number of chunk_step applications that make up one run.
    """
    chunk = max(1, min(chunk, T))
    n_chunks = -(-T // chunk)
    T_eff = n_chunks * chunk
    win = T_eff // 2 if window is None else min(window, T_eff)
    win = max(win, 1)             # T==1 / window==0 would divide by zero
    mark = T_eff - win            # windowed rate baseline: end of slot mark-1
    q3_lo, q4_lo = T_eff // 2, (3 * T_eff) // 4

    arrival_branches = tuple(ARRIVAL_MODELS[k] for k in ARRIVAL_MODEL_ORDER)
    event_branches = tuple(EVENT_MODELS[k] for k in EVENT_MODEL_ORDER)

    def slot(pp, lam, eps_b, akind, ekind, key, carry, slot_arr):
        state, stats, mod, t = carry
        kt = jax.random.fold_in(key, t)
        k_arr, k_ev, k_step = jax.random.split(kt, 3)
        if slot_arr is None:
            arr, mod = jax.lax.switch(akind, arrival_branches, k_arr, lam,
                                      mod)
        else:
            arr = slot_arr
        esc, csc, mod = jax.lax.switch(ekind, event_branches, pp, t, k_ev,
                                       mod)
        state, m = slot_step(pp.with_capacity_scales(esc, csc), cfg, state,
                             arr, k_step, eps_b=eps_b)
        tq = m["total_queue"]
        sq, cq = kahan_add(stats.sum_queue, stats.c_queue, tq)
        s3, c3 = kahan_add(stats.sum_queue_q3, stats.c_q3,
                           tq * ((t >= q3_lo) & (t < q4_lo)))
        s4, c4 = kahan_add(stats.sum_queue_q4, stats.c_q4, tq * (t >= q4_lo))
        stats = StreamStats(
            sum_queue=sq, c_queue=cq,
            sum_queue_q3=s3, c_q3=c3,
            sum_queue_q4=s4, c_q4=c4,
            max_queue=jnp.maximum(stats.max_queue, tq),
            useful_at_mark=jnp.where(t == mark - 1, m["delivered_useful"],
                                     stats.useful_at_mark),
        )
        return (state, stats, mod, t + 1), None

    def init_carry(pp: PaddedProblem):
        return (init_state(pp), StreamStats.zero(), ModState.init(pp),
                jnp.int32(0))

    def chunk_step(pp: PaddedProblem, lam, eps_b, akind, ekind, key, carry):
        """Advance one chunk of slots.  Pure; the engine jits this with
        `donate_argnums` on `carry` so the scan carry is updated in place
        across the Python-level chunk loop (no 2x peak on the [B, N, 3, NC]
        queue state at fleet batch sizes)."""
        body = functools.partial(slot, pp, lam, eps_b, akind, ekind, key)
        carry, _ = jax.lax.scan(lambda c, x: body(c, None), carry,
                                xs=None, length=chunk)
        return carry

    def finalize(lam, eps_b, carry) -> Dict[str, jax.Array]:
        state, stats, _, _ = carry
        mean_q3 = stats.sum_queue_q3 / max(q4_lo - q3_lo, 1)
        mean_q4 = stats.sum_queue_q4 / max(T_eff - q4_lo, 1)
        return {
            "offered": jnp.asarray(lam, jnp.float32),
            "eps_b": jnp.asarray(eps_b, jnp.float32),
            "useful_rate": (state.delivered_useful - stats.useful_at_mark) / win,
            "delivered": state.delivered,
            "delivered_useful": state.delivered_useful,
            "delivered_dummy": state.delivered - state.delivered_useful,
            "mean_queue": stats.sum_queue / T_eff,
            "mean_queue_mid": mean_q3,
            "mean_queue_tail": mean_q4,
            "max_queue": stats.max_queue,
            # Heuristic verdict comparing the 3rd vs 4th quarter of the run
            # (both past the fill-up transient): a stable network's backlog
            # plateaus, so the ratio stays near 1; linearly growing backlog
            # (instability) gives mean_q4/mean_q3 -> 7/5.
            "stable": (mean_q4 <= 1.25 * mean_q3 + 5.0).astype(jnp.float32),
        }

    def run(pp: PaddedProblem, lam, eps_b, akind, ekind, key,
            arrivals: jax.Array | None = None) -> Dict[str, jax.Array]:
        carry = init_carry(pp)
        if arrivals is None:
            def chunk_body(c, _):
                return chunk_step(pp, lam, eps_b, akind, ekind, key, c), None
            carry, _ = jax.lax.scan(chunk_body, carry, xs=None,
                                    length=n_chunks)
        else:
            if arrivals.shape[0] != T_eff:
                raise ValueError(
                    f"explicit arrivals must have length {T_eff} "
                    f"(= n_chunks*chunk), got {arrivals.shape[0]}")
            # Reshape the arrival trace to [n_chunks, chunk] once, ahead of
            # the chunk scan — not per chunk.
            arr_chunks = arrivals.astype(jnp.float32).reshape(n_chunks, chunk)
            body = functools.partial(slot, pp, lam, eps_b, akind, ekind, key)
            def chunk_body(c, a):
                c, _ = jax.lax.scan(body, c, a)
                return c, None
            carry, _ = jax.lax.scan(chunk_body, carry, arr_chunks)
        return finalize(lam, eps_b, carry)

    run.T = T_eff
    run.window = win
    run.chunk = chunk
    run.n_chunks = n_chunks
    run.init_carry = init_carry
    run.chunk_step = chunk_step
    run.finalize = finalize
    return run


def stream_simulate(problem: ComputeProblem, cfg: PolicyConfig, lam: float,
                    T: int, chunk: int = 1024, window: int | None = None,
                    seed: int = 0, arrivals: jax.Array | None = None,
                    arrival: str = "poisson", events: str = "static",
                    dims: PadDims | None = None) -> Dict[str, jax.Array]:
    """Single-problem streaming simulation (the fleet path without sharding).

    Memory is O(N + E) regardless of T — the reference `simulate` keeps
    O(T) traces.  Matches `simulate(...).delivered_useful[-1]` exactly for
    key-free policies (pi1/pi1'/pi3bar) given the same arrival trace.
    """
    dims = dims or PadDims.of([problem])
    pp = pad_problem(problem, dims)
    run = make_stream_runner(cfg, T, chunk=chunk, window=window)
    # `run` is memoized and `arrivals` is passed as a traced operand (None
    # is static pytree structure), so repeated calls with the same
    # (cfg, T, chunk, window) share one compiled program instead of
    # re-jitting a fresh partial per invocation.
    out = _jit_run(run)(
        pp, jnp.float32(lam), jnp.float32(cfg.eps_b), arrival_code(arrival),
        event_code(events), jax.random.PRNGKey(seed), arrivals)
    return out


@functools.lru_cache(maxsize=64)
def _jit_run(run):
    return jax.jit(run)


@dataclasses.dataclass
class FleetResult:
    jobs: List[FleetJob]
    metrics: List[Dict[str, float]]     # one dict per job, same order
    n_programs: int
    n_sims: int
    dims: PadDims
    T: int
    window: int
    memory_stats: Dict[str, float] | None = None  # XLA memory analysis of the
                                                  # largest chunk-step program
                                                  # (run_fleet(memory_stats=True))

    def column(self, name: str) -> np.ndarray:
        return np.array([m[name] for m in self.metrics])


@functools.lru_cache(maxsize=64)
def make_group_launch(runner, mesh: Mesh):
    """Jit the three per-group programs of the chunked fleet launch.

    Returns `(init_fn, step_fn, fin_fn)`, each a
    `jax.jit(shard_map(vmap(...)))` over the `"fleet"` mesh axis.  `step_fn`
    donates its carry argument (`donate_argnums=6`): across the Python-level
    chunk loop the [B, N, 3, NC] queue state is updated in place instead of
    being double-buffered — the memory audit that matters once B·N·NC grows
    past cache sizes.  Donation is asserted by
    `tests/test_fleet.py::TestDonation`.

    Memoized on `(runner, mesh)` (runners are themselves memoized, Mesh is
    hashable): two sweeps over the same policy group reuse the compiled
    programs instead of re-tracing, and within one sweep the chunk loop is
    guaranteed a single compilation
    (`tests/test_fleet.py::TestNoRecompilation`)."""
    spec = P("fleet")

    def _sharded(fn, n_in):
        return shard_map(jax.vmap(fn), mesh=mesh, in_specs=(spec,) * n_in,
                         out_specs=spec,
                         check_rep=False)  # scan carries: no replication rule
    init_fn = jax.jit(_sharded(runner.init_carry, 1))
    step_fn = jax.jit(_sharded(runner.chunk_step, 7), donate_argnums=(6,))
    fin_fn = jax.jit(_sharded(runner.finalize, 3))
    return init_fn, step_fn, fin_fn


def _memory_analysis(step_fn, args) -> Dict[str, float] | None:
    """XLA memory analysis of a compiled chunk-step (peak/live byte sizes).

    Best-effort: backends without `memory_analysis` return None."""
    try:
        ma = step_fn.lower(*args).compile().memory_analysis()
        if ma is None:
            return None
        # The output carry is donated onto the input carry (aliased), so
        # counting argument + output + temp would double-count the fleet
        # state; peak live memory of a launch is arguments + temporaries.
        return {
            "argument_bytes": float(ma.argument_size_in_bytes),
            "output_bytes": float(ma.output_size_in_bytes),
            "temp_bytes": float(ma.temp_size_in_bytes),
            "peak_bytes": float(ma.argument_size_in_bytes
                                + ma.temp_size_in_bytes),
        }
    except Exception:  # pragma: no cover - backend-dependent surface
        return None


def _policy_group_key(job: FleetJob):
    """Axes that change Python-level control flow => separate XLA program.

    Deliberately *semantic*, not the policy name: pi3 and pi3_reg trace to
    identical programs (both regulated, load-balancing), and eps_b is traced
    per-job data — so a sweep over regulator parameters, or over plain and
    ``_reg``-aliased variants, still compiles once per behavior."""
    cfg = job.policy_config()
    return (cfg.use_regulator, cfg.load_balance, cfg.thresholded,
            cfg.pairing, cfg.threshold, cfg.fixed_node, cfg.wireless,
            # interpret only matters when the pallas kernels actually run;
            # keying it unconditionally would fork identical xla programs.
            cfg.backend, cfg.interpret if cfg.backend == "pallas" else None)


def run_fleet(jobs: Sequence[FleetJob], T: int, chunk: int = 1024,
              window: int | None = None, devices=None,
              dims: PadDims | None = None,
              memory_stats: bool = False) -> FleetResult:
    """Run the whole sweep, one compiled program set per policy group.

    Each group runs as a Python-level loop of `n_chunks` launches of one
    `jit(shard_map(vmap(chunk_step)))` with the scan carry *donated*
    between launches (`make_group_launch`), so arbitrarily long horizons
    keep a single in-place copy of the fleet state.  `memory_stats=True`
    additionally attaches the XLA memory analysis of the largest group's
    chunk-step program to the result (one extra lowering, so opt-in)."""
    jobs = list(jobs)
    devices = list(devices or jax.devices())
    ndev = len(devices)
    mesh = Mesh(np.array(devices), ("fleet",))

    # Build and pad every distinct topology once; jobs share by reference.
    problem_of: Dict[tuple, ComputeProblem] = {}
    for job in jobs:
        k = (job.scenario, job.topo_seed)
        if k not in problem_of:
            problem_of[k] = get_scenario(job.scenario).build(job.topo_seed)
    dims = dims or PadDims.of(list(problem_of.values()))
    padded_of = {k: pad_problem(p, dims) for k, p in problem_of.items()}

    groups: Dict[tuple, List[int]] = {}
    for i, job in enumerate(jobs):
        groups.setdefault(_policy_group_key(job), []).append(i)

    metrics: List[Dict[str, float] | None] = [None] * len(jobs)
    eff_T = eff_win = 0
    mem: Dict[str, float] | None = None
    mem_B = -1
    for gkey, idxs in groups.items():
        cfg = jobs[idxs[0]].policy_config()
        runner = make_stream_runner(cfg, T, chunk=chunk, window=window)
        eff_T, eff_win = runner.T, runner.window

        # Per-group host work is hoisted to exactly here — one batch of
        # device constants per group, built *before* the chunk loop.  Pad
        # the group batch to a multiple of the mesh size by repeating the
        # last job; replicas are dropped when results are scattered back.
        B = len(idxs)
        Bp = -(-B // ndev) * ndev
        padded_idxs = idxs + [idxs[-1]] * (Bp - B)
        pp = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[padded_of[(jobs[i].scenario, jobs[i].topo_seed)]
              for i in padded_idxs])
        lam = jnp.array([jobs[i].lam for i in padded_idxs], jnp.float32)
        eps = jnp.array([jobs[i].eps_b for i in padded_idxs], jnp.float32)
        ak = jnp.array([arrival_code(get_scenario(jobs[i].scenario).arrival)
                        for i in padded_idxs], jnp.int32)
        ek = jnp.array([event_code(get_scenario(jobs[i].scenario).events)
                        for i in padded_idxs], jnp.int32)
        # One vmapped derivation instead of B host-side PRNGKey calls.
        # int32 keeps negative seeds legal (uint32 would overflow at the
        # host conversion); PRNGKey folds them identically either way.
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.array([jobs[i].seed for i in padded_idxs], jnp.int32))

        init_fn, step_fn, fin_fn = make_group_launch(runner, mesh)
        carry = init_fn(pp)
        for _ in range(runner.n_chunks):
            carry = step_fn(pp, lam, eps, ak, ek, keys, carry)
        if memory_stats and Bp > mem_B:
            m = _memory_analysis(step_fn, (pp, lam, eps, ak, ek, keys, carry))
            if m is not None:
                mem, mem_B = m, Bp
        out = jax.device_get(fin_fn(lam, eps, carry))
        for j, i in enumerate(idxs):
            metrics[i] = {k: float(v[j]) for k, v in out.items()}

    return FleetResult(jobs=jobs, metrics=metrics, n_programs=len(groups),
                       n_sims=len(jobs), dims=dims, T=eff_T, window=eff_win,
                       memory_stats=mem)
