"""Capacity atlas: a fleet of λ_max bisections in one launch per group.

`frontier.find_lambda_max` measures the paper's headline quantity — the
maximum sustainable query rate λ_max — for *one* (scenario, topo_seed)
cell at a time: every probe is its own `run_fleet` call, so sweeping the
scenario registry is serially bottlenecked on launch count.  The atlas
inverts it (DESIGN.md §10): the offered rate was *already* per-sim traced
data in the chunk-step signature, so hundreds of (cell × seed) bisection
lanes ride **one padded launch per (policy group × size bucket)**, each
lane probing its own cell's current grid rate.  Buckets (DESIGN.md §13)
cut the padding hull by size quantiles so one big topology no longer
inflates every small lane; adaptive horizons (``max_requeues``) re-queue
UNDECIDED-at-top cells at doubled chunk budgets instead of reporting a
collapsed bracket.

The host loop is the PR-5 machinery turned into a scheduler:

  1. every cell owns a pure `frontier.Bisection` machine (the *identical*
     machine the sequential path drives — same probe order, same budget
     semantics), and its `len(seeds)` lanes run the machine's pending
     grid rate;
  2. after each chunk launch the host reads the [B] drift leaves
     (`runner.drift_of`: latched verdict + decision slot) and harvests
     every cell whose probe finished — all lanes decided (early-stop
     semantics) or the horizon's `n_chunks` elapsed;
  3. harvested cells `record(...)` into their machine, pull the next
     probe, and get their lanes *rewritten in place* at the launch
     boundary (`engine.make_sim_rewriter`): fresh init carry, t = 0, new
     `fold_seed(topo_seed, rate_index, 0, seed)` key, new lam — exactly
     the state a standalone `run_fleet` probe would start from, which is
     why per-lane streams are bit-identical to the sequential path;
  4. cells whose machine finishes are *parked*: their verdict leaf is
     forced UNSTABLE so the freeze mask pins the carry while the rest of
     the atlas keeps bisecting.

Because untouched lanes pass through the rewrite bit-unchanged
(`where(False, fresh, old) == old`) and vmap lanes never interact, the
atlas returns **bit-identical** λ_max to per-cell `find_lambda_max` given
the same `PadDims` — asserted by `tests/test_atlas.py`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.graph import ComputeProblem
from repro.core.queues import VERDICT_NAMES, VERDICT_UNDECIDED
from .batching import PadDims, make_buckets, pad_problem
from .engine import (FleetJob, VerdictConfig, _policy_group_key,
                     make_group_launch, make_sim_rewriter,
                     make_stream_runner, resolve_verdict)
from .frontier import Bisection, RateProbe, fold_seed
from .report import policy_bound_exact
from .scenarios import arrival_code, event_code, get_scenario


@dataclasses.dataclass(frozen=True)
class AtlasJob:
    """One cell of the capacity atlas: a (scenario, topo_seed) instance
    whose λ_max is bisected against its own exact LP bound."""

    scenario: str
    policy: str = "pi3"
    topo_seed: int = 0
    eps_b: float = 0.01


@dataclasses.dataclass(frozen=True)
class AtlasRow:
    """One cell's finished frontier search (the atlas analog of
    `frontier.FrontierResult`, minus the per-search launch accounting
    that only makes sense sequentially)."""

    scenario: str
    policy: str
    eps_b: float
    topo_seed: int
    lam_max: float           # largest grid rate verified sustainable
    bound_exact: float       # the exact regulated LP bound of *this* cell
    ratio: float             # lam_max / bound_exact
    lo: float                # final bracket: sustainable side
    hi: float                # final bracket: unsustainable side
    n_calls: int             # probes evaluated for this cell
    n_iters: int             # bisection halvings
    undecided: bool          # hi never *proven* unstable (DESIGN.md §8):
                             # blocked by UNDECIDED-at-horizon evidence only
    hi_certain: float | None  # smallest rate with genuine UNSTABLE evidence
    total_slots: int         # simulated slots advanced across the probes
    full_slots: int          # slots a freeze-free search would have run
    slots_saved: int         # full_slots - total_slots
    probes: Tuple[RateProbe, ...]
    degraded: bool = False   # the cell's lanes sat on a dropped host: the
                             # search was cut short and (lo, hi) is the
                             # bracket *at the dropout*, not a converged
                             # localization (DESIGN.md §12)
    bucket: int = 0          # PadDims bucket the cell's lanes ran in
                             # (DESIGN.md §13); 0 in single-bucket sweeps
    n_requeues: int = 0      # adaptive-horizon escalations: each re-queue
                             # restarted the search at double the horizon
                             # with a bumped fold_seed call_index


@dataclasses.dataclass
class AtlasResult:
    """The whole atlas: per-cell rows + fleet-level launch accounting."""

    rows: List[AtlasRow]
    n_cells: int
    n_lanes: int             # (cell × seed) bisection lanes advanced
    n_programs: int          # (policy group × bucket) launch units, each
                             # its own padded-shape compiled program
    n_launches: int          # chunk-step launches the atlas dispatched
    seq_launches: int        # launches per-cell find_lambda_max would issue
    n_rewrites: int          # in-place carry rewrites at launch boundaries
    n_step_compiles: int     # summed step-trace cache sizes (== n_programs
                             # in a cold process; warm memoized caches from
                             # an earlier same-process sweep count too, so
                             # resume bit-equality holds — compare deltas
                             # across back-to-back sweeps, not absolutes)
    total_slots: int
    full_slots: int
    slots_saved: int
    launch_slots_saved: int  # sequential-semantics launch savings
    dims: PadDims
    T: int
    chunk: int
    stream_records: List[dict] = dataclasses.field(default_factory=list)
                             # per-launch bisection progress
                             # (sweep_lambda_max(stream=True), DESIGN.md §11)
    resumed_from: int | None = None   # checkpoint step this sweep restored
                                      # (DESIGN.md §12); None = fresh
    degraded: Dict[int, str] = dataclasses.field(default_factory=dict)
                             # cell index -> reason for cells parked by a
                             # host dropout (their rows carry degraded=True)
    recovery_plan: object | None = None   # runtime.fault.RecoveryPlan
    n_fault_retries: int = 0
    bucket_dims: List[PadDims] = dataclasses.field(default_factory=list)
                             # per-bucket padded shapes (DESIGN.md §13);
                             # [dims] for single-bucket sweeps
    bucket_cells: Dict[int, int] = dataclasses.field(default_factory=dict)
                             # bucket -> cells assigned to it
    bucket_launches: Dict[int, int] = dataclasses.field(default_factory=dict)
                             # bucket -> chunk launches dispatched in it
    n_requeues: int = 0      # total adaptive-horizon re-queues across cells

    @property
    def n_buckets(self) -> int:
        return max(len(self.bucket_dims), 1)

    @property
    def launch_speedup(self) -> float:
        """How many sequential launches one atlas launch replaced."""
        return self.seq_launches / self.n_launches if self.n_launches else 0.0


def registry_cells(families: Sequence[str], topo_seeds: Sequence[int],
                   policy: str = "pi3", eps_b: float = 0.01
                   ) -> List[AtlasJob]:
    """The (family × topo_seed) atlas grid as `AtlasJob` cells.

    Random families (random_geometric, expander, ...) vary their topology
    with ``topo_seed``; deterministic ones (paper_grid, ring, ...) reuse
    the graph but still decouple their probe streams, because every probe
    seed is `fold_seed(topo_seed, ...)` — so the grid doubles as a
    seed-replicate study on fixed topologies."""
    return [AtlasJob(scenario=f, policy=policy, topo_seed=int(ts),
                     eps_b=eps_b)
            for f in families for ts in topo_seeds]


def sweep_lambda_max(cells: Sequence[AtlasJob], *,
                     seeds: Sequence[int] = (0,), T: int = 4096,
                     chunk: int = 512, window: int | None = None,
                     rel_tol: float = 0.025,
                     bracket: Tuple[float, float] = (0.5, 1.1),
                     max_calls: int = 24, early_stop: bool = True,
                     verdict: VerdictConfig | None = None,
                     devices=None, dims: PadDims | None = None,
                     n_buckets: int = 1, max_requeues: int = 0,
                     stream: bool = False, stream_log=None,
                     stream_path: str | None = None,
                     resilience=None) -> AtlasResult:
    """Bisect λ_max for every atlas cell, batched: one padded chunk-step
    launch per (policy group × size bucket) advances all of its cells'
    current probes at once.

    Parameters mirror `find_lambda_max` — each cell's search is driven by
    the same `Bisection` machine on the same rel_tol-quantized grid of its
    own exact bound, with the same `fold_seed` probe streams, so per-cell
    results are bit-identical to the sequential path run with the cell's
    bucket dims (`AtlasResult.bucket_dims[row.bucket]`).
    ``early_stop=True`` (default) harvests a probe as soon as all its
    lanes latch; ``False`` reproduces full-horizon probing (every probe
    runs all ``n_chunks`` launches).

    ``n_buckets > 1`` groups the distinct topologies into quantile-based
    size buckets (`batching.make_buckets`, DESIGN.md §13): each (policy
    group × bucket) launches its own padded program, so one big expander
    no longer inflates every small ring lane.  An explicit ``dims``
    forces the single-bucket path padded to those shared dims (the
    equivalence-test hook).  Padded shapes change reduction shapes hence
    bits, so per-cell results are compared against the sequential path
    *at the same bucket dims*, never across bucketings.

    ``max_requeues > 0`` turns on adaptive per-cell horizons: a cell
    whose finished machine is still UNDECIDED-at-top (`undecided_hi` —
    the bracket top blocked by horizon-limited evidence only) *or*
    whose bracket fully collapsed (``k_lo == 0``: no rate proved
    sustainable, which at rates far below capacity is usually the
    gradient-fill transient masquerading as a proven UNSTABLE — both
    are the collapsed-bracket failure mode) restarts its whole search
    with double the chunk budget (2×T, then 4×T, ... up to
    ``max_requeues`` escalations).  Re-probes ride the same compiled program — verdict
    latching depends on the window config, not T, so a longer horizon is
    just more chunk launches — through the same `make_sim_rewriter`
    reset path, with the fold_seed ``call_index`` bumped to the attempt
    number so re-probe streams never alias first-attempt streams.

    ``stream``/``stream_log``/``stream_path`` mirror `run_fleet`: one
    "atlas"-kind record per chunk launch (DESIGN.md §11) — active/done
    cell counts, harvested probes, per-family bracket medians — assembled
    host-side from the scheduler state the loop already reads back, so
    streaming cannot perturb the bisections.  Records land in
    `AtlasResult.stream_records`; the stream clock ``t`` counts slots
    *dispatched* per lane (lane carries reset t to 0 on probe rewrites,
    so the raw carry clock is not monotone — the dispatch count is).

    ``resilience`` makes the sweep preemption-safe (DESIGN.md §12): every
    launch boundary snapshots the donated carry *and* the host scheduler —
    each cell's serialized `Bisection` machine, `RateProbe` history,
    pending (rate, seed) lane tables and the launch counters — so a killed
    sweep resumes with bit-identical brackets, rows and stream records.
    Host dropouts park the affected cells' lanes and finish their rows
    from the current bracket with ``degraded=True`` (reported in
    ``AtlasResult.degraded``) while the rest of the atlas keeps bisecting.
    """
    cells = list(cells)
    if not cells:
        raise ValueError("empty atlas")
    stream = stream or stream_log is not None or stream_path is not None
    seeds = tuple(seeds)
    vcfg = resolve_verdict(verdict, early_stop)
    devices = list(devices or jax.devices())
    ndev = len(devices)
    mesh = Mesh(np.array(devices), ("fleet",))
    S = len(seeds)

    # --- per-cell bound, grid step, and bisection machine.  The bracket
    # arithmetic repeats find_lambda_max token-for-token so both paths
    # start from the identical integer bracket.
    bounds: List[float] = []
    steps: List[float] = []
    machines: List[Bisection] = []
    k_lo0: List[int] = []
    k_hi0: List[int] = []
    for c in cells:
        bound = policy_bound_exact(c.scenario, c.policy, c.eps_b,
                                   topo_seed=c.topo_seed)
        if bound <= 0.0:
            raise ValueError(f"{c.scenario}: exact LP bound is {bound}; "
                             "nothing to bisect")
        step = rel_tol * bound
        bounds.append(bound)
        steps.append(step)
        k_lo0.append(max(int(np.floor(bracket[0] * bound / step)), 0))
        k_hi0.append(max(int(np.ceil(bracket[1] * bound / step)), 1))
        machines.append(Bisection(k_lo=k_lo0[-1], k_hi=k_hi0[-1],
                                  max_calls=max_calls))

    # --- topologies: build each distinct one once, pad to its bucket's
    # dims.  An explicit `dims` forces one shared bucket (the equivalence
    # hook); otherwise `make_buckets` cuts quantile-based size buckets
    # (DESIGN.md §13) and each problem is padded only to its bucket hull.
    problem_of: Dict[tuple, ComputeProblem] = {}
    for c in cells:
        k = (c.scenario, c.topo_seed)
        if k not in problem_of:
            problem_of[k] = get_scenario(c.scenario).build(c.topo_seed)
    problem_keys = list(problem_of)
    if dims is not None:
        bucket_dims = [dims]
        bucket_of = {k: 0 for k in problem_keys}
    else:
        bucket_dims, assignment = make_buckets(
            [problem_of[k] for k in problem_keys], n_buckets)
        bucket_of = {k: b for k, b in zip(problem_keys, assignment)}
    dims = PadDims(
        n_nodes=max(d.n_nodes for d in bucket_dims),
        n_edges=max(d.n_edges for d in bucket_dims),
        n_comp=max(d.n_comp for d in bucket_dims))
    padded_of = {k: pad_problem(p, bucket_dims[bucket_of[k]])
                 for k, p in problem_of.items()}
    cell_bucket = [bucket_of[(c.scenario, c.topo_seed)] for c in cells]

    # --- launch units: policy groups (the only axis that forks traced
    # control flow) × buckets (padded shapes fork programs within one
    # group's jit cache).  Outer order is group insertion order, inner is
    # ascending bucket, so the single-bucket path enumerates units exactly
    # like the pre-bucketing group loop.
    groups: Dict[tuple, List[int]] = {}
    for ci, c in enumerate(cells):
        key = _policy_group_key(FleetJob(scenario=c.scenario,
                                         policy=c.policy, eps_b=c.eps_b,
                                         topo_seed=c.topo_seed))
        groups.setdefault(key, []).append(ci)
    units: List[Tuple[tuple, int, List[int], bool]] = []
    for gkey, cidx_g in groups.items():
        by_bucket: Dict[int, List[int]] = {}
        for ci in cidx_g:
            by_bucket.setdefault(cell_bucket[ci], []).append(ci)
        bs = sorted(by_bucket)
        for b in bs:
            units.append((gkey, b, by_bucket[b], b == bs[-1]))

    rt = resumed = None
    if resilience is not None:
        from repro.runtime import resilience as rz
        rt = rz.maybe_resilient(resilience, "atlas", cells=tuple(cells),
                                seeds=seeds, T=T, chunk=chunk, window=window,
                                rel_tol=rel_tol, bracket=tuple(bracket),
                                max_calls=max_calls, early_stop=early_stop,
                                verdict=vcfg, dims=tuple(bucket_dims),
                                n_buckets=n_buckets,
                                max_requeues=max_requeues, ndev=ndev)
        resumed = rt.resumed

    rows: List[AtlasRow | None] = [None] * len(cells)
    attempt: List[int] = [0] * len(cells)
    n_launches = seq_launches = n_rewrites = 0
    launch_slots_saved = 0
    n_step_compiles = 0
    n_requeues = 0
    bucket_launches: Dict[int, int] = {b: 0 for b in range(len(bucket_dims))}
    eff_T = eff_chunk = 0
    degraded: Dict[int, str] = {}
    recovery = None
    sink = None
    if stream:
        from repro.obs.emitter import StreamSink
        sink = StreamSink(path=stream_path, log=stream_log,
                          append=resumed is not None)
    if resumed is not None:
        # Host scheduler restore: every cell's machine (cells in already-
        # finished units carry their final state; unstarted ones their
        # initial state — both re-serialize identically), finished rows,
        # attempt counters, and the launch counters.
        for ci_s, ms in resumed["machines"].items():
            machines[int(ci_s)] = Bisection.from_state(ms)
        for ci_s, rs in resumed["rows"].items():
            rows[int(ci_s)] = rz.row_restore(rs)
        for ci_s, a in resumed["attempt"].items():
            attempt[int(ci_s)] = int(a)
        n_launches = resumed["n_launches"]
        seq_launches = resumed["seq_launches"]
        n_rewrites = resumed["n_rewrites"]
        launch_slots_saved = resumed["launch_slots_saved"]
        n_step_compiles = resumed["n_step_compiles"]
        n_requeues = resumed["n_requeues"]
        bucket_launches.update(
            {int(b): int(n) for b, n in resumed["bucket_launches"].items()})
        degraded = {int(k): v for k, v in resumed["degraded"].items()}
        recovery = rz.plan_restore(resumed["recovery"])

    for g, (gkey, bkt, cidx, group_last) in enumerate(units):
        cfg = FleetJob(scenario=cells[cidx[0]].scenario,
                       policy=cells[cidx[0]].policy,
                       eps_b=cells[cidx[0]].eps_b,
                       topo_seed=cells[cidx[0]].topo_seed).policy_config()
        runner = make_stream_runner(cfg, T, chunk=chunk, window=window,
                                    verdict=vcfg)
        eff_T, eff_chunk = runner.T, runner.chunk
        n_chunks = runner.n_chunks
        if resumed is not None and g < resumed["group"]:
            continue              # finished pre-kill: rows restored above

        # Lane layout: S contiguous lanes per cell, mesh-padded by
        # repeating the last real lane (run_fleet's replica convention —
        # replicas mirror every rewrite of their source cell and are never
        # harvested).
        lane_cells = [ci for ci in cidx for _ in seeds]
        B = len(lane_cells)
        Bp = -(-B // ndev) * ndev
        lane_pad = lane_cells + [lane_cells[-1]] * (Bp - B)
        lane_of = {ci: slice(j * S, (j + 1) * S)
                   for j, ci in enumerate(cidx)}

        pp = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[padded_of[(cells[ci].scenario, cells[ci].topo_seed)]
              for ci in lane_pad])
        eps = jnp.array([cells[ci].eps_b for ci in lane_pad], jnp.float32)
        ak = jnp.array([arrival_code(get_scenario(cells[ci].scenario).arrival)
                        for ci in lane_pad], jnp.int32)
        ek = jnp.array([event_code(get_scenario(cells[ci].scenario).events)
                        for ci in lane_pad], jnp.int32)

        init_fn, step_fn, _ = make_group_launch(runner, mesh)
        rewrite_fn = make_sim_rewriter(runner, mesh)

        # Host-side scheduler state: each active cell's pending grid index
        # and how many chunk launches its current probe has consumed.
        pending: Dict[int, int] = {}
        chunks_used: Dict[int, int] = {}
        probes_of: Dict[int, List[RateProbe]] = {ci: [] for ci in cidx}
        lam_host = np.zeros(Bp, np.float32)
        seed_host = np.zeros(Bp, np.int32)
        active: set = set()

        def _assign(ci: int, k: int) -> None:
            # call_index = attempt: first-attempt probes replay the exact
            # sequential fold_seed stream (call_index 0); adaptive re-probes
            # draw from the documented re-probe stream so doubled-horizon
            # evidence never aliases the evidence that failed to decide.
            pending[ci] = k
            chunks_used[ci] = 0
            sl = lane_of[ci]
            lam_host[sl] = np.float32(k * steps[ci])
            seed_host[sl] = [fold_seed(cells[ci].topo_seed, k,
                                       attempt[ci], s)
                             for s in seeds]

        resume_here = resumed is not None and g == resumed["group"]
        if resume_here and resumed["g_launches"] > 0:
            # Mid-group restore: the carry at the snapshot boundary plus
            # the lane tables / pending probes exactly as the killed sweep
            # left them; machines/rows/counters were restored above.
            pending = {int(k): v for k, v in resumed["pending"].items()}
            chunks_used = {int(k): v
                           for k, v in resumed["chunks_used"].items()}
            for ci_s, ps in resumed["probes"].items():
                probes_of[int(ci_s)] = [rz.probe_restore(p) for p in ps]
            lam_host = np.array(resumed["lam_host"], np.float32)
            seed_host = np.array(resumed["seed_host"], np.int32)
            active = set(resumed["active"])
            like = jax.eval_shape(init_fn, pp)
            carry = rt.restore_carry(like, mesh)
            g_launches = resumed["g_launches"]
        else:
            carry = init_fn(pp)
            park0 = np.zeros(Bp, bool)
            for ci in cidx:
                k = machines[ci].next_rate_index()
                if k is None:       # degenerate budget: decided probe-free
                    rows[ci] = _finish_row(cells[ci], bounds[ci], steps[ci],
                                           machines[ci], [], bucket=bkt)
                    park0[lane_of[ci]] = True
                else:
                    active.add(ci)
                    _assign(ci, k)
            lam_host[B:] = lam_host[B - 1]
            seed_host[B:] = seed_host[B - 1]
            park0[B:] = park0[B - 1]
            if park0.any():
                carry = rewrite_fn(pp, jnp.zeros(Bp, bool),
                                   jnp.asarray(park0), carry)
                n_rewrites += 1
            g_launches = 0
        if sink is not None and resume_here:
            from repro.obs import schema
            sink.write(schema.make_record(
                "resume", group=g, chunk=g_launches,
                t=g_launches * runner.chunk, n_sims=B, engine="atlas",
                ckpt_step=resumed["ckpt_step"],
                n_preloaded=sink.n_preloaded))

        while active:
            lam = jnp.asarray(lam_host)
            keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(seed_host))
            if rt is not None:
                try:
                    carry = rt.launch(g, n_launches, step_fn, pp, lam, eps,
                                      ak, ek, keys, carry)
                except Exception:
                    if sink is not None:
                        sink.close()
                    raise
            else:
                carry = step_fn(pp, lam, eps, ak, ek, keys, carry)
            n_launches += 1
            g_launches += 1
            bucket_launches[bkt] += 1
            for ci in active:
                chunks_used[ci] += 1

            # Between-launch readout: the two [Bp] drift leaves only.
            v_leaf, d_leaf = runner.drift_of(carry)
            verdicts = np.asarray(jax.device_get(v_leaf))
            decided_at = np.asarray(jax.device_get(d_leaf))

            reset = np.zeros(Bp, bool)
            park = np.zeros(Bp, bool)
            changed = False
            if rt is not None:
                dead = rt.dead_hosts(n_launches)
                if dead:
                    # Graceful degradation: park every active cell with a
                    # lane on a dead host, finish its row from the bracket
                    # *at the dropout* (degraded=True, never silent), and
                    # re-plan the mesh.  The rest of the atlas keeps
                    # bisecting.
                    lane_dead = rz.host_lane_mask(Bp, ndev, dead)
                    per = Bp // ndev
                    for ci in sorted(active):
                        sl = lane_of[ci]
                        if lane_dead[sl].any():
                            active.discard(ci)
                            park[sl] = True
                            rows[ci] = _finish_row(
                                cells[ci], bounds[ci], steps[ci],
                                machines[ci], probes_of[ci], degraded=True,
                                bucket=bkt, n_requeues=attempt[ci])
                            hosts = sorted({l // per
                                            for l in range(sl.start, sl.stop)
                                            if lane_dead[l]})
                            degraded[ci] = "host_dropout:" + ",".join(
                                f"host{h}" for h in hosts)
                            changed = True
                    if recovery is None or set(dead) != set(recovery.evict):
                        from repro.runtime.fault import plan_recovery
                        recovery = plan_recovery(
                            ndev, 1, [f"host{h}" for h in dead], [], 1)
            for ci in sorted(active):
                sl = lane_of[ci]
                v = verdicts[sl]
                # Adaptive horizon: attempt a probes up to n_chunks << a
                # launches — verdict latching lives in the window config,
                # not T, so a doubled horizon is just more chunk launches
                # of the same program.
                horizon = n_chunks << attempt[ci]
                finished = chunks_used[ci] >= horizon or (
                    early_stop and bool(np.all(v != VERDICT_UNDECIDED)))
                if not finished:
                    continue
                # --- harvest: the exact RateProbe the sequential path
                # would have built from run_fleet's finalize metrics.
                k = pending[ci]
                cell_T = runner.T << attempt[ci]
                names = tuple(VERDICT_NAMES[int(x)] for x in v)
                sustainable = all(n == "STABLE" for n in names)
                d_eff = np.where(v != VERDICT_UNDECIDED,
                                 decided_at[sl], cell_T)
                saved = (int(np.sum(cell_T - d_eff)) if vcfg.freeze
                         else 0)
                probes_of[ci].append(RateProbe(
                    rate_index=k, call_index=attempt[ci],
                    lam=k * steps[ci],
                    sustainable=sustainable, verdicts=names,
                    decided_at=tuple(int(x) for x in d_eff),
                    slots_run=S * cell_T - saved, slots_saved=saved,
                    undecided=not sustainable and "UNSTABLE" not in names))
                seq_launches += chunks_used[ci]
                launch_slots_saved += \
                    S * (horizon - chunks_used[ci]) * runner.chunk
                machines[ci].record(k, sustainable,
                                    probes_of[ci][-1].undecided)
                k2 = machines[ci].next_rate_index()
                if k2 is None and (machines[ci].undecided_hi
                                   or machines[ci].k_lo == 0) \
                        and attempt[ci] < max_requeues:
                    # Re-queue (DESIGN.md §13): either the bracket top is
                    # blocked by UNDECIDED-at-horizon evidence only, or
                    # the bracket fully collapsed (k_lo == 0: no rate
                    # proved sustainable).  The collapse case covers the
                    # low-rate false-UNSTABLE artifact — at rates far
                    # below capacity the backpressure gradient fills so
                    # slowly that the whole horizon sits inside the
                    # transient and the drift + gap tests both latch
                    # UNSTABLE (paper_grid topo_seeds 5/8/15 at T=4096
                    # read proven-UNSTABLE at 0.1x their own exact
                    # bound); genuinely-capacity-0 cells (wireless_grid)
                    # burn the re-queue ladder and still report 0, which
                    # the bench asserts.  Restart the whole search from
                    # the original integer bracket with a doubled chunk
                    # budget instead of reporting the collapsed bracket.
                    # The fresh machine replays the deterministic probe
                    # order; _assign stamps the bumped call_index into
                    # every fold_seed.
                    attempt[ci] += 1
                    n_requeues += 1
                    machines[ci] = Bisection(k_lo=k_lo0[ci], k_hi=k_hi0[ci],
                                             max_calls=max_calls)
                    k2 = machines[ci].next_rate_index()
                if k2 is None:
                    active.discard(ci)
                    park[sl] = True
                    rows[ci] = _finish_row(cells[ci], bounds[ci],
                                           steps[ci], machines[ci],
                                           probes_of[ci], bucket=bkt,
                                           n_requeues=attempt[ci])
                else:
                    reset[sl] = True
                    _assign(ci, k2)
                changed = True
            if changed and active:
                # Replicas mirror the last real lane's fate so they stay
                # bit-synchronized with (or parked alongside) their source.
                # No rewrite once the group drains: nothing launches again.
                reset[B:] = reset[B - 1]
                park[B:] = park[B - 1]
                lam_host[B:] = lam_host[B - 1]
                seed_host[B:] = seed_host[B - 1]
                carry = rewrite_fn(pp, jnp.asarray(reset),
                                   jnp.asarray(park), carry)
                n_rewrites += 1
            if sink is not None:
                sink.write(_atlas_record(
                    g, bkt, n_requeues, g_launches, runner.chunk, B, cells,
                    cidx, active, machines, steps, bounds, probes_of,
                    verdicts[:B]))

            if rt is not None and rt.should_snapshot(n_launches):
                rt.snapshot(n_launches, carry, _atlas_extra(
                    g, g_launches, n_launches, seq_launches, n_rewrites,
                    launch_slots_saved, n_step_compiles, machines, rows,
                    pending, chunks_used, probes_of, cidx, lam_host,
                    seed_host, active, degraded, recovery, attempt,
                    n_requeues, bucket_launches))
            if rt is not None:
                try:
                    rt.maybe_preempt(n_launches)
                except Exception:
                    if sink is not None:
                        sink.close()
                    raise

        if group_last:
            # One readout per policy group, after its *last* bucket: the
            # jit cache holds one trace per bucket shape, so summing per
            # bucket would double-count earlier buckets of the same group.
            try:
                n_step_compiles += int(step_fn._cache_size())
            except Exception:  # pragma: no cover - private API moved
                n_step_compiles = -10 ** 6

        if rt is not None and rt.should_snapshot(n_launches):
            # Unit-end marker: empty carry, cursor at the next unit's
            # start — a resume here re-enters the fresh path with the
            # restored machines re-pulling the same deterministic grid.
            rt.snapshot(n_launches, (), _atlas_extra(
                g + 1, 0, n_launches, seq_launches, n_rewrites,
                launch_slots_saved, n_step_compiles, machines, rows,
                {}, {}, {ci: [] for ci in cidx}, cidx, lam_host,
                seed_host, set(), degraded, recovery, attempt,
                n_requeues, bucket_launches))

    if sink is not None:
        sink.close()
    done_rows = [r for r in rows if r is not None]
    assert len(done_rows) == len(cells)
    n_bucket_cells: Dict[int, int] = {}
    for b in cell_bucket:
        n_bucket_cells[b] = n_bucket_cells.get(b, 0) + 1
    return AtlasResult(
        rows=done_rows, n_cells=len(cells), n_lanes=len(cells) * S,
        n_programs=len(units), n_launches=n_launches,
        seq_launches=seq_launches, n_rewrites=n_rewrites,
        n_step_compiles=n_step_compiles,
        total_slots=sum(r.total_slots for r in done_rows),
        full_slots=sum(r.full_slots for r in done_rows),
        slots_saved=sum(r.slots_saved for r in done_rows),
        launch_slots_saved=launch_slots_saved,
        dims=dims, T=eff_T, chunk=eff_chunk,
        stream_records=sink.records if sink is not None else [],
        resumed_from=(resumed["n_launches"] if resumed is not None
                      else None),
        degraded=degraded, recovery_plan=recovery,
        n_fault_retries=rt.n_retries if rt is not None else 0,
        bucket_dims=list(bucket_dims),
        bucket_cells=n_bucket_cells,
        bucket_launches=dict(bucket_launches),
        n_requeues=n_requeues)


def sweep_policy_surface(families: Sequence[str],
                         topo_seeds: Sequence[int], *,
                         policies: Sequence[str] = ("pi3", "pi3_reg",
                                                    "pi3bar"),
                         eps_b: float = 0.01, **kw) -> AtlasResult:
    """Atlas-over-policies: one sweep of (policy × family × topo_seed).

    Every policy runs the *same* grid of topologies against the same
    per-cell exact bounds, so ratio gaps between policies are pure policy
    effects — the λ_max surface the in-network placement literature
    compares on.  Policies that fork traced control flow
    (`_policy_group_key`) land in separate launch units automatically;
    policies that trace identically (pi3 vs pi3_reg) share one program
    and differ only in data.  Pivot the rows with
    `report.policy_surface_table`.  Keyword args pass through to
    `sweep_lambda_max` (seeds, T, chunk, n_buckets, max_requeues, ...).
    """
    cells = [AtlasJob(scenario=f, policy=p, topo_seed=int(ts), eps_b=eps_b)
             for p in policies for f in families for ts in topo_seeds]
    return sweep_lambda_max(cells, **kw)


def _atlas_extra(group, g_launches, n_launches, seq_launches, n_rewrites,
                 launch_slots_saved, n_step_compiles, machines, rows,
                 pending, chunks_used, probes_of, cidx, lam_host,
                 seed_host, active, degraded, recovery, attempt,
                 n_requeues, bucket_launches) -> dict:
    """JSON-serializable sweep cursor for one checkpoint (DESIGN.md §12).

    Machines, finished rows and attempt counters are global (every cell,
    so already-finished units restore without replay); the lane tables
    and pending probes are the current (group × bucket) unit's only.
    ``group`` is the unit cursor — the bucket identity is implied by the
    deterministic unit enumeration."""
    from repro.runtime import resilience as rz

    return {
        "group": group, "g_launches": g_launches,
        "n_launches": n_launches, "seq_launches": seq_launches,
        "n_rewrites": n_rewrites,
        "launch_slots_saved": launch_slots_saved,
        "n_step_compiles": n_step_compiles,
        "n_requeues": n_requeues,
        "bucket_launches": {str(b): int(n)
                            for b, n in bucket_launches.items()},
        "machines": {str(ci): m.to_state()
                     for ci, m in enumerate(machines)},
        "rows": {str(ci): rz.row_state(r)
                 for ci, r in enumerate(rows) if r is not None},
        "attempt": {str(ci): int(a) for ci, a in enumerate(attempt)},
        "pending": {str(ci): int(k) for ci, k in pending.items()},
        "chunks_used": {str(ci): int(n) for ci, n in chunks_used.items()},
        "probes": {str(ci): [rz.probe_state(p) for p in probes_of[ci]]
                   for ci in cidx},
        "lam_host": [float(x) for x in lam_host],
        "seed_host": [int(x) for x in seed_host],
        "active": sorted(int(ci) for ci in active),
        "degraded": {str(ci): v for ci, v in degraded.items()},
        "recovery": rz.plan_state(recovery),
    }


def _atlas_record(group: int, bucket: int, n_requeues: int,
                  g_launches: int, chunk: int, n_real: int,
                  cells, cidx, active, machines, steps, bounds, probes_of,
                  lane_verdicts: np.ndarray) -> dict:
    """One launch's bisection-progress record, assembled from the host
    scheduler state (DESIGN.md §11).  ``t`` is the per-lane dispatch count
    (launches × chunk): lane carries reset their slot clock on probe
    rewrites, so the carry's own t is not a usable stream clock.
    ``group`` is the (policy group × bucket) unit cursor; ``bucket`` names
    the PadDims bucket the unit runs in (DESIGN.md §13)."""
    from repro.obs import schema

    def rel(ci, k):
        return k * steps[ci] / bounds[ci]

    widths = [rel(ci, machines[ci].k_hi - machines[ci].k_lo)
              for ci in cidx]
    fams: Dict[str, dict] = {}
    for ci in cidx:
        fam = fams.setdefault(cells[ci].scenario, {"cells": 0, "done": 0,
                                                   "_lo": [], "_hi": []})
        fam["cells"] += 1
        fam["done"] += ci not in active
        fam["_lo"].append(rel(ci, machines[ci].k_lo))
        fam["_hi"].append(rel(ci, machines[ci].k_hi))
    for fam in fams.values():
        fam["lo_med"] = round(float(np.median(fam.pop("_lo"))), 4)
        fam["hi_med"] = round(float(np.median(fam.pop("_hi"))), 4)
    v = lane_verdicts.astype(int)
    return schema.make_record(
        "atlas",
        group=group, bucket=bucket, n_requeues=n_requeues,
        chunk=g_launches - 1, t=g_launches * chunk,
        n_sims=n_real,
        n_active_cells=len(active),
        n_done_cells=len(cidx) - len(active),
        n_probes=sum(len(probes_of[ci]) for ci in cidx),
        bracket_rel_width_med=round(float(np.median(widths)), 4),
        verdicts={VERDICT_NAMES[k]: int((v == k).sum())
                  for k in sorted(set(v.tolist()))},
        families=fams)


def _finish_row(cell: AtlasJob, bound: float, step: float, bis: Bisection,
                probes: Sequence[RateProbe],
                degraded: bool = False, bucket: int = 0,
                n_requeues: int = 0) -> AtlasRow:
    full = sum(p.slots_run + p.slots_saved for p in probes)
    run_slots = sum(p.slots_run for p in probes)
    return AtlasRow(
        scenario=cell.scenario, policy=cell.policy, eps_b=cell.eps_b,
        topo_seed=cell.topo_seed,
        lam_max=bis.k_lo * step, bound_exact=bound,
        ratio=bis.k_lo * step / bound,
        lo=bis.k_lo * step, hi=bis.k_hi * step,
        n_calls=len(probes), n_iters=bis.n_iters,
        undecided=bis.undecided_hi,
        hi_certain=(None if bis.k_hi_certain is None
                    else bis.k_hi_certain * step),
        total_slots=run_slots, full_slots=full,
        slots_saved=full - run_slots,
        probes=tuple(probes), degraded=degraded,
        bucket=bucket, n_requeues=n_requeues)
