"""Scenario registry: parameterized topology generators + event/arrival models.

A `Scenario` bundles everything the fleet engine needs to spawn simulation
jobs: a topology factory (seed -> ComputeProblem), an arrival-process model,
a capacity event model (time-varying links / comp-node failure), and the
interference model (wired vs wireless).  Scenarios are registered by name so
sweeps are declared as data (`["paper_grid", "random_geometric", ...]`).

Event and arrival models are *online*: functions of (slot index, key) plus a
fixed-shape modulation state `ModState`, evaluated inside the scan body, so
a 10^6-slot horizon never materializes a [T]-shaped trace.  Memoryless
models ignore and pass through the state; Markov-modulated models
(Gilbert–Elliott link fading, ON-OFF bursty arrivals) update it — the
engine threads one `ModState` through the scan carry (DESIGN.md §4).  The
registry order is frozen into tuples (`ARRIVAL_MODEL_ORDER`,
`EVENT_MODEL_ORDER`) so per-job integer codes can drive a `lax.switch` —
heterogeneous scenarios share one compiled program.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import ComputeProblem, Graph, grid_graph, paper_grid_problem
from repro.sim import workload


class ModState(NamedTuple):
    """Markov-modulation state carried through the scan (O(E + NC) memory).

    Every event/arrival model receives and returns the full state so all
    `lax.switch` branches share one pytree signature; memoryless models pass
    it through untouched.

      link[e] : 1.0 = Good / 0.0 = Bad   (Gilbert–Elliott channel state)
      comp[n] : 1.0 = Up   / 0.0 = Down  (Gilbert–Elliott comp-node state)
      burst   : 1.0 = ON  / 0.0 = OFF    (Markov-modulated arrival phase)
    """

    link: jax.Array    # [E] float32
    comp: jax.Array    # [NC] float32
    burst: jax.Array   # [] float32

    @staticmethod
    def init(sp) -> "ModState":
        """All links Good, all comp nodes Up, arrivals ON — the chains mix
        within O(1/p) slots."""
        E = sp.edges.shape[-2]
        return ModState(jnp.ones((E,), jnp.float32),
                        jnp.ones((sp.n_comp,), jnp.float32),
                        jnp.ones((), jnp.float32))


# ---------------------------------------------------------------------------
# Arrival models: (key, lam, mod) -> (scalar arrivals, mod').  Memoryless
# models wrap the canonical [T]-trace law in repro.sim.workload with T=1 so
# the two stay in lockstep (same clipping rules, same batch defaults).
# ---------------------------------------------------------------------------

def _arrival_poisson(key: jax.Array, lam: jax.Array, mod: ModState):
    return workload.poisson_arrivals(key, lam, 1)[0], mod


def _arrival_bernoulli_batch(key: jax.Array, lam: jax.Array, mod: ModState):
    return workload.bernoulli_batch_arrivals(key, lam, 1)[0], mod


def _arrival_constant(key: jax.Array, lam: jax.Array, mod: ModState):
    return workload.constant_arrivals(lam, 1)[0], mod


# Markov ON-OFF (interrupted-Poisson) defaults: stationary P(ON) = 0.75,
# mean ON run 1/P_OFF = 20 slots, mean OFF run 1/P_ON ≈ 6.7 slots.
MMPP_P_ON_OFF = 0.05     # P(ON -> OFF) per slot
MMPP_P_OFF_ON = 0.15     # P(OFF -> ON) per slot


def _arrival_markov_onoff(key: jax.Array, lam: jax.Array, mod: ModState):
    """Markov-modulated ON-OFF Poisson arrivals (bursty, *correlated* load).

    A 2-state chain gates the query stream: while ON, arrivals are
    Poisson(lam / P(ON)); while OFF, none.  The long-run mean is exactly
    `lam`, so capacity sweeps are comparable with the memoryless models —
    only the correlation structure changes (mean burst length 1/P_OFF
    slots instead of 1)."""
    k_flip, k_arr = jax.random.split(key)
    pi_on = MMPP_P_OFF_ON / (MMPP_P_ON_OFF + MMPP_P_OFF_ON)
    u = jax.random.uniform(k_flip)
    on = jnp.where(mod.burst > 0.5,
                   (u >= MMPP_P_ON_OFF).astype(jnp.float32),
                   (u < MMPP_P_OFF_ON).astype(jnp.float32))
    arr = workload.poisson_arrivals(k_arr, lam / pi_on, 1)[0] * on
    return arr, mod._replace(burst=on)


ARRIVAL_MODELS: Dict[str, Callable] = {
    "poisson": _arrival_poisson,
    "bernoulli_batch": _arrival_bernoulli_batch,
    "constant": _arrival_constant,
    "markov_onoff": _arrival_markov_onoff,
}
ARRIVAL_MODEL_ORDER: Tuple[str, ...] = tuple(ARRIVAL_MODELS)


def arrival_code(name: str) -> int:
    return ARRIVAL_MODEL_ORDER.index(name)


# ---------------------------------------------------------------------------
# Event models: (problem, t, key, mod) -> (edge_scale [E], comp_scale [NC],
# mod').  `problem` is any StaticProblem/PaddedProblem duck type; scales
# multiply the static capacities for this slot only.
# ---------------------------------------------------------------------------

def _ones(sp):
    E = sp.edges.shape[-2]
    return jnp.ones((E,), jnp.float32), jnp.ones((sp.n_comp,), jnp.float32)


def _ev_static(sp, t: jax.Array, key: jax.Array, mod: ModState):
    es, cs = _ones(sp)
    return es, cs, mod


def _ev_fading(sp, t: jax.Array, key: jax.Array, mod: ModState,
               period: float = 200.0, depth: float = 0.35):
    """Deterministic per-link slow fading: capacity oscillates in
    [1 - 2*depth, 1] with an edge-dependent phase."""
    E = sp.edges.shape[-2]
    phase = jnp.arange(E, dtype=jnp.float32) / jnp.float32(max(E, 1))
    s = 1.0 - depth + depth * jnp.cos(
        2.0 * jnp.pi * (t.astype(jnp.float32) / period + phase))
    return s.astype(jnp.float32), _ones(sp)[1], mod


def _ev_link_flaps(sp, t: jax.Array, key: jax.Array, mod: ModState,
                   p_up: float = 0.9):
    """i.i.d. per-slot link outages: each edge is up w.p. `p_up`."""
    E = sp.edges.shape[-2]
    up = jax.random.bernoulli(key, p_up, (E,)).astype(jnp.float32)
    return up, _ones(sp)[1], mod


def _ev_comp_failures(sp, t: jax.Array, key: jax.Array, mod: ModState,
                      p_up: float = 0.9):
    """i.i.d. per-slot comp-node failure/recovery: node computes w.p. `p_up`.
    Failed nodes keep their queues (state is untouched) but combine nothing."""
    up = jax.random.bernoulli(key, p_up, (sp.n_comp,)).astype(jnp.float32)
    return _ones(sp)[0], up, mod


# Gilbert–Elliott defaults: stationary P(Bad) = P_GB/(P_GB+P_BG) ≈ 0.091,
# mean Bad run 1/P_BG = 5 slots, long-run mean capacity scale ≈ 0.93.
GE_P_GB = 0.02           # P(Good -> Bad) per slot, per link
GE_P_BG = 0.20           # P(Bad -> Good) per slot, per link
GE_BAD_SCALE = 0.25      # capacity multiplier while Bad

# Comp-node Gilbert–Elliott defaults: stationary P(Down) =
# P_UD/(P_UD+P_DU) = 0.0625, mean outage 1/P_DU ≈ 6.7 slots.  A Down node
# keeps its queues but combines nothing and is excluded from the
# load-balance argmin for the slot (mask gating, DESIGN.md §3).
GE_COMP_P_UD = 0.01      # P(Up -> Down) per slot, per comp node
GE_COMP_P_DU = 0.15      # P(Down -> Up) per slot, per comp node


def _ge_step(u: jax.Array, good: jax.Array, p_enter_bad: float,
             p_exit_bad: float) -> jax.Array:
    """One transition of independent 2-state Good/Bad chains.

    `good` is the current state as float (1.0 = Good/Up); `u` is uniform
    randomness of the same shape.  Returns the next state as float32."""
    return jnp.where(good > 0.5,
                     (u >= p_enter_bad).astype(jnp.float32),
                     (u < p_exit_bad).astype(jnp.float32))


def _ev_gilbert_elliott(sp, t: jax.Array, key: jax.Array, mod: ModState):
    """2-state Markov (Gilbert–Elliott) per-link fading.

    Each link runs an independent Good/Bad chain; Bad links keep only
    `GE_BAD_SCALE` of their capacity.  Unlike `link_flaps` the outages are
    *correlated in time* (mean Bad run 1/P_BG slots), the regime where
    backpressure's implicit re-routing matters — the chain state lives in
    `mod.link` and is updated here, inside the scan."""
    E = sp.edges.shape[-2]
    good = _ge_step(jax.random.uniform(key, (E,)), mod.link, GE_P_GB, GE_P_BG)
    scale = GE_BAD_SCALE + (1.0 - GE_BAD_SCALE) * good
    return scale, _ones(sp)[1], mod._replace(link=good)


def _ev_ge_comp(sp, t: jax.Array, key: jax.Array, mod: ModState):
    """Markov (Gilbert–Elliott) comp-node failures: each computation node
    runs an independent Up/Down chain in `mod.comp`.

    Unlike the i.i.d. `comp_failures` model, outages persist (mean Down run
    1/P_DU slots) — the regime of Benoit et al., *Resource Allocation
    Strategies for In-Network Stream Processing*, where the operative
    question is whether load balancing reroutes queries around a node that
    will stay dark for many slots.  The returned comp scale is 0/1; the
    engine's `with_capacity_scales` gates `comp_mask` with it, so a Down
    node combines nothing *and* never wins the load-balance argmin."""
    up = _ge_step(jax.random.uniform(key, (sp.n_comp,)), mod.comp,
                  GE_COMP_P_UD, GE_COMP_P_DU)
    return _ones(sp)[0], up, mod._replace(comp=up)


def _ev_ge_full(sp, t: jax.Array, key: jax.Array, mod: ModState):
    """Combined Markov dynamics: Gilbert–Elliott link fading *and* comp-node
    failures, both chains advancing every slot (independent randomness)."""
    k_link, k_comp = jax.random.split(key)
    link_scale, _, mod = _ev_gilbert_elliott(sp, t, k_link, mod)
    _, comp_up, mod = _ev_ge_comp(sp, t, k_comp, mod)
    return link_scale, comp_up, mod


# Scripted comp-node outage: one deterministic Gilbert–Elliott Down run
# with its endpoints pinned, so tests can assert shed/recover *timing*
# (the serving fault-injection test, tests/test_serving.py).  Node
# `OUTAGE_NODE` is Down for slots [OUTAGE_LO, OUTAGE_HI).
OUTAGE_NODE = 0
OUTAGE_LO = 1024
OUTAGE_HI = 1536


def _ev_outage_window(sp, t: jax.Array, key: jax.Array, mod: ModState):
    down = (t >= OUTAGE_LO) & (t < OUTAGE_HI)
    up = jnp.ones((sp.n_comp,), jnp.float32).at[OUTAGE_NODE].set(
        jnp.where(down, 0.0, 1.0))
    return _ones(sp)[0], up, mod


EVENT_MODELS: Dict[str, Callable] = {
    "static": _ev_static,
    "fading": _ev_fading,
    "link_flaps": _ev_link_flaps,
    "comp_failures": _ev_comp_failures,
    "gilbert_elliott": _ev_gilbert_elliott,
    "ge_comp": _ev_ge_comp,
    "ge_full": _ev_ge_full,
    "outage_window": _ev_outage_window,   # appended: switch codes are frozen
}
EVENT_MODEL_ORDER: Tuple[str, ...] = tuple(EVENT_MODELS)


def event_code(name: str) -> int:
    return EVENT_MODEL_ORDER.index(name)


# ---------------------------------------------------------------------------
# Topology generators.  All are (seed, **params) -> ComputeProblem with
# sources/dest/comp-node placement chosen by simple degree/eccentricity
# heuristics so every instance is feasible (connected, lam* > 0).
# ---------------------------------------------------------------------------

def _place(graph: Graph, n_comp: int, C: float,
           rng: np.random.Generator) -> ComputeProblem:
    """Pick s1/s2 far apart, dest far from both, comp nodes by degree."""
    n = graph.n_nodes
    deg = np.zeros(n, np.int64)
    for m, l in graph.edges:
        deg[m] += 1
        deg[l] += 1
    # BFS eccentricity from a random start to find a far pair.
    adj = [[] for _ in range(n)]
    for m, l in graph.edges:
        adj[m].append(int(l))
        adj[l].append(int(m))

    def bfs(src):
        dist = np.full(n, -1)
        dist[src] = 0
        q = [src]
        while q:
            u = q.pop(0)
            for v in adj[u]:
                if dist[v] < 0:
                    dist[v] = dist[u] + 1
                    q.append(v)
        return dist

    s1 = int(rng.integers(n))
    d1 = bfs(s1)
    s2 = int(np.argmax(d1))
    d2 = bfs(s2)
    dest = int(np.argmax(d1 + d2))
    if dest in (s1, s2):
        dest = int(np.argsort(-(d1 + d2))[1])
    # highest-degree nodes (excluding endpoints) host computation
    order = np.argsort(-deg)
    comp = [int(u) for u in order if u not in (s1, s2, dest)][:n_comp]
    if len(comp) < n_comp:                       # tiny graphs: allow overlap
        comp += [int(u) for u in order if int(u) not in comp][:n_comp - len(comp)]
    return ComputeProblem(graph, s1, s2, dest,
                          tuple(comp), (C,) * len(comp))


def random_geometric(seed: int, n: int = 14, radius: float = 0.42,
                     cap: float = 4.0, n_comp: int = 3,
                     C: float = 2.0) -> ComputeProblem:
    """Random geometric graph in the unit square; a chain over x-sorted nodes
    is added so the graph is always connected."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(size=(n, 2))
    order = np.argsort(pts[:, 0])
    edges = set()
    for i in range(n):
        for j in range(i + 1, n):
            if np.linalg.norm(pts[i] - pts[j]) <= radius:
                edges.add((min(i, j), max(i, j)))
    for a, b in zip(order[:-1], order[1:]):      # connectivity backbone
        edges.add((min(int(a), int(b)), max(int(a), int(b))))
    e = np.array(sorted(edges), np.int32)
    g = Graph(n, e, np.full(len(e), cap))
    return _place(g, n_comp, C, rng)


def ring(seed: int, n: int = 12, cap: float = 4.0, n_comp: int = 3,
         C: float = 2.0) -> ComputeProblem:
    e = np.array([(i, (i + 1) % n) for i in range(n)], np.int32)
    g = Graph(n, e, np.full(n, cap))
    return _place(g, n_comp, C, np.random.default_rng(seed))


def balanced_tree(seed: int, branch: int = 2, depth: int = 3, cap: float = 4.0,
                  n_comp: int = 3, C: float = 2.0) -> ComputeProblem:
    """Complete `branch`-ary tree of the given depth."""
    edges, nodes = [], 1
    frontier = [0]
    for _ in range(depth):
        nxt = []
        for u in frontier:
            for _ in range(branch):
                edges.append((u, nodes))
                nxt.append(nodes)
                nodes += 1
        frontier = nxt
    e = np.array(edges, np.int32)
    g = Graph(nodes, e, np.full(len(e), cap))
    return _place(g, n_comp, C, np.random.default_rng(seed))


def expander(seed: int, n: int = 14, cap: float = 4.0, n_comp: int = 3,
             C: float = 2.0) -> ComputeProblem:
    """Circulant expander: ring + chord offsets (2, n//2 - 1) + random chords."""
    rng = np.random.default_rng(seed)
    edges = set()
    for off in (1, 2, max(n // 2 - 1, 3)):
        for i in range(n):
            j = (i + off) % n
            if i != j:
                edges.add((min(i, j), max(i, j)))
    for _ in range(n // 3):                      # extra random chords
        i, j = rng.integers(n), rng.integers(n)
        if i != j:
            edges.add((min(int(i), int(j)), max(int(i), int(j))))
    e = np.array(sorted(edges), np.int32)
    g = Graph(n, e, np.full(len(e), cap))
    return _place(g, n_comp, C, rng)


def fat_tree(seed: int, pods: int = 2, hosts_per_edge: int = 2,
             core_cap: float = 8.0, agg_cap: float = 4.0,
             host_cap: float = 4.0, C: float = 2.0) -> ComputeProblem:
    """Miniature datacenter fat-tree: core -> per-pod agg -> edge -> hosts.
    Computation lives in the aggregation layer (in-network processing)."""
    edges, caps = [], []
    core, n = 0, 1                # node 0 is the single core of the mini tree
    aggs, hosts = [], []
    for _ in range(pods):
        agg, n = n, n + 1
        aggs.append(agg)
        edges.append((core, agg))
        caps.append(core_cap)
        for _ in range(2):
            sw, n = n, n + 1
            edges.append((agg, sw))
            caps.append(agg_cap)
            for _ in range(hosts_per_edge):
                h, n = n, n + 1
                hosts.append(h)
                edges.append((sw, h))
                caps.append(host_cap)
    g = Graph(n, np.array(edges, np.int32), np.array(caps))
    s1, s2 = int(hosts[0]), int(hosts[-1])       # opposite pods
    dest = int(hosts[len(hosts) // 2])
    if dest in (s1, s2):
        dest = int(hosts[1])
    return ComputeProblem(g, s1, s2, dest, tuple(aggs), (C,) * len(aggs))


def wireless_grid(seed: int, rows: int = 4, cols: int = 4, cap: float = 5.0,
                  C: float = 2.0) -> ComputeProblem:
    """The paper-§IV-C setting: grid graph under node-exclusive interference
    (pair with `wireless=True` in the scenario)."""
    g = grid_graph(rows, cols, cap)
    rng = np.random.default_rng(seed)
    return _place(g, n_comp=4, C=C, rng=rng)


# ---------------------------------------------------------------------------
# Scenario registry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    factory: Callable[[int], ComputeProblem]     # topo seed -> problem
    arrival: str = "poisson"                     # ARRIVAL_MODELS key
    events: str = "static"                       # EVENT_MODELS key
    wireless: bool = False
    description: str = ""

    def build(self, topo_seed: int = 0) -> ComputeProblem:
        return self.factory(topo_seed)


SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(s: Scenario) -> Scenario:
    if s.name in SCENARIOS:
        raise ValueError(f"scenario {s.name!r} already registered")
    if s.arrival not in ARRIVAL_MODELS:
        raise ValueError(f"unknown arrival model {s.arrival!r}")
    if s.events not in EVENT_MODELS:
        raise ValueError(f"unknown event model {s.events!r}")
    SCENARIOS[s.name] = s
    return s


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}") from None


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


register_scenario(Scenario(
    "paper_grid", lambda seed: paper_grid_problem(),
    description="The paper's 4x4 grid (Fig. 5a), C=2, R=5."))
register_scenario(Scenario(
    "random_geometric", random_geometric,
    description="Random geometric graph, degree-placed comp nodes."))
register_scenario(Scenario(
    "ring", ring, description="Cycle topology; worst-case path diversity."))
register_scenario(Scenario(
    "tree", balanced_tree,
    description="Complete binary tree; single-path routing stress."))
register_scenario(Scenario(
    "expander", expander,
    description="Circulant expander + random chords; high conductance."))
register_scenario(Scenario(
    "fat_tree", fat_tree, arrival="bernoulli_batch",
    description="Mini datacenter fat-tree; bursty arrivals, agg-layer compute."))
register_scenario(Scenario(
    "wireless_grid", wireless_grid, wireless=True,
    description="Grid under node-exclusive interference (greedy matching)."))
register_scenario(Scenario(
    "fading_geometric", random_geometric, events="fading",
    description="Random geometric graph with sinusoidal link fading."))
register_scenario(Scenario(
    "flaky_expander", expander, events="link_flaps",
    description="Expander with i.i.d. per-slot link outages."))
register_scenario(Scenario(
    "failing_grid", lambda seed: paper_grid_problem(), events="comp_failures",
    description="Paper grid with comp-node failure/recovery."))
register_scenario(Scenario(
    "ge_grid", lambda seed: paper_grid_problem(), events="gilbert_elliott",
    description="Paper grid under Gilbert–Elliott (Markov) link fading."))
register_scenario(Scenario(
    "ge_geometric", random_geometric, events="gilbert_elliott",
    description="Random geometric graph under Gilbert–Elliott link fading."))
register_scenario(Scenario(
    "bursty_grid", lambda seed: paper_grid_problem(), arrival="markov_onoff",
    description="Paper grid with Markov ON-OFF (correlated bursty) arrivals."))
register_scenario(Scenario(
    "ge_comp_grid", lambda seed: paper_grid_problem(), events="ge_comp",
    description="Paper grid with Markov (Gilbert–Elliott) comp-node "
                "failures: outages persist for ~1/P_DU slots."))
register_scenario(Scenario(
    "ge_full_grid", lambda seed: paper_grid_problem(), events="ge_full",
    description="Paper grid under combined Markov link fading AND "
                "comp-node failures."))
register_scenario(Scenario(
    "outage_grid", lambda seed: paper_grid_problem(), events="outage_window",
    description="Paper grid with a scripted comp-node outage in slots "
                "[OUTAGE_LO, OUTAGE_HI) — deterministic fault-injection "
                "for the serving shed/recover test."))
