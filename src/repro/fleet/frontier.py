"""Adaptive λ_max frontier search: bisection over early-stopped fleet runs.

The paper's headline quantity is the *maximum sustainable query rate*
λ_max.  `find_lambda_max` measures it empirically: it brackets the PR-3
exact regulated LP bound (`fleet.report.policy_bound_exact`), then bisects
the offered rate over successive `run_fleet` calls, each early-stopped by
the streaming stability verdict (DESIGN.md §8) — a rate is *sustainable*
iff every seed's sim latches STABLE.  The search contract:

  * **Grid quantization.**  Probed rates live on the fixed grid
    ``lam = k * rel_tol * bound`` (integer ``k``), so bisection from *any*
    valid initial bracket converges to the same boundary index — the
    golden-frontier invariance property.  The final bracket width is one
    grid step, i.e. λ_max is localized to ``rel_tol`` relative to the bound.
  * **Seed decoupling.**  Each probe's per-seed PRNG seeds are SplitMix64
    folds of ``(topo_seed, rate_index, call_index, seed)`` (`fold_seed`) —
    NOT the raw job seed — so two bisection steps at different rates never
    share arrival streams.  Within one search every grid index is
    evaluated at most once (memoized), always with ``call_index = 0``, so
    probes are deterministic per rate and the bracket-invariance above
    holds exactly; a driver that *re*-probes a rate for confirmation
    passes ``call_index > 0`` to draw fresh noise.
  * **Launch-only steps.**  Every probe reuses the same memoized
    `make_stream_runner`/`make_group_launch` programs (identical policy
    config, shapes, and verdict config), so after the first call each
    bisection step is launch-only — asserted via
    `FrontierResult.n_step_compiles == 1`.

Verdict aggregation is conservative: UNDECIDED (like UNSTABLE) counts as
unsustainable, so λ_max is biased *down*, never above the true frontier.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from .engine import (FleetJob, VerdictConfig, make_group_launch,
                     make_stream_runner, resolve_verdict, run_fleet)
from .report import policy_bound_exact

_M64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """SplitMix64 finalizer: a bijective avalanche on 64-bit ints."""
    x &= _M64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _M64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _M64
    x ^= x >> 31
    return x


def fold_seed(topo_seed: int, rate_index: int, call_index: int,
              seed: int = 0) -> int:
    """Derive one probe's PRNG seed from the bisection coordinates.

    Successive `run_fleet` calls in the bisection loop must NOT reuse the
    raw job seed: two probes at different rates would then draw the same
    uniforms, coupling their arrival streams (identical noise at every
    probed rate biases the measured frontier).  Folding
    ``(topo_seed, rate_index, call_index, seed)`` through SplitMix64
    decouples every axis while staying deterministic — `find_lambda_max`
    always probes with ``call_index = 0`` (each grid index is evaluated
    once per search), which is what makes the search invariant to the
    initial bracket; confirmation re-probes pass ``call_index > 0`` for
    fresh noise.  Returns a non-negative int31, safe for
    `jax.random.PRNGKey` via the engine's int32 path."""
    h = (0x9E3779B97F4A7C15 * (topo_seed & _M64)
         + 0xBF58476D1CE4E5B9 * (rate_index & _M64)
         + 0x94D049BB133111EB * (call_index & _M64)
         + 0xD6E8FEB86659FD93 * (seed & _M64) + 0x2545F4914F6CDD1D) & _M64
    return int(_mix64(h) & 0x7FFFFFFF)


@dataclasses.dataclass(frozen=True)
class RateProbe:
    """One evaluated rate of the frontier search."""

    rate_index: int          # grid index k (lam = k * rel_tol * bound)
    call_index: int          # how many times this rate had been probed before
    lam: float
    sustainable: bool        # all seeds latched STABLE
    verdicts: Tuple[str, ...]
    decided_at: Tuple[int, ...]
    slots_run: int           # simulated slots actually advanced
    slots_saved: int         # simulated slots the freeze skipped


@dataclasses.dataclass(frozen=True)
class FrontierResult:
    """Outcome of `find_lambda_max` (DESIGN.md §8)."""

    scenario: str
    policy: str
    eps_b: float
    topo_seed: int
    lam_max: float           # largest grid rate verified sustainable
    bound_exact: float       # the exact regulated LP bound it is measured against
    ratio: float             # lam_max / bound_exact
    lo: float                # final bracket: sustainable side
    hi: float                # final bracket: unsustainable side (lo + grid step)
    n_calls: int             # run_fleet launches issued
    n_iters: int             # bisection halvings (excl. bracket validation)
    total_slots: int         # simulated slots advanced across all probes
    full_slots: int          # slots a no-early-stop search would have run
    slots_saved: int         # full_slots - total_slots (per-sim freeze savings)
    launch_slots_saved: int  # chunks never dispatched once groups decided
    n_step_compiles: int     # compiled chunk-step programs used (must be 1)
    probes: Tuple[RateProbe, ...]

    @property
    def slots_saved_frac(self) -> float:
        return self.slots_saved / self.full_slots if self.full_slots else 0.0


def find_lambda_max(scenario: str, policy: str = "pi3", *,
                    eps_b: float = 0.01, topo_seed: int = 0,
                    seeds: Sequence[int] = (0, 1), T: int = 4096,
                    chunk: int = 512, window: int | None = None,
                    rel_tol: float = 0.025,
                    bracket: Tuple[float, float] = (0.5, 1.1),
                    max_calls: int = 24, early_stop: bool = True,
                    verdict: VerdictConfig | None = None,
                    devices=None) -> FrontierResult:
    """Locate the empirical max sustainable rate λ_max of one (scenario,
    policy) pair by bisecting offered rate over early-stopped fleet runs.

    ``bracket`` is the initial (lo, hi) as *fractions of the exact bound*;
    it is validated first (lo must be sustainable, hi unsustainable) and
    expanded/shrunk on the quantized grid if not.  Every probe runs
    ``len(seeds)`` sims through `run_fleet(early_stop=...)`; the probe is
    sustainable iff all of them latch STABLE.  See the module docstring
    for the quantization / seed-fold / launch-only contract."""
    bound = policy_bound_exact(scenario, policy, eps_b, topo_seed=topo_seed)
    if bound <= 0.0:
        raise ValueError(f"{scenario}: exact LP bound is {bound}; "
                         "nothing to bisect")
    step = rel_tol * bound
    vcfg = resolve_verdict(verdict, early_stop)
    seeds = tuple(seeds)

    probes: List[RateProbe] = []
    cache: Dict[int, RateProbe] = {}
    launch_saved = [0]

    def evaluate(k: int) -> bool:
        if k <= 0:
            return True               # lam = 0 is trivially sustainable
        if k in cache:
            return cache[k].sustainable
        if len(probes) >= max_calls:
            return False              # budget exhausted: stay conservative
        # Each grid index is evaluated once per search (the memo above),
        # always at call_index 0 — deterministic per rate, which is what
        # makes the result invariant to the initial bracket.
        jobs = [FleetJob(scenario=scenario, policy=policy, lam=k * step,
                         eps_b=eps_b, topo_seed=topo_seed,
                         seed=fold_seed(topo_seed, k, 0, s))
                for s in seeds]
        res = run_fleet(jobs, T=T, chunk=chunk, window=window,
                        early_stop=early_stop, verdict=verdict,
                        devices=devices)
        launch_saved[0] += res.launch_slots_saved
        names = res.verdicts()
        probe = RateProbe(
            rate_index=k, call_index=0, lam=k * step,
            sustainable=all(v == "STABLE" for v in names),
            verdicts=tuple(names),
            decided_at=tuple(int(d)
                             for d in res.column("decided_at_slot")),
            slots_run=res.n_sims * res.T - res.slots_saved,
            slots_saved=res.slots_saved)
        cache[k] = probe
        probes.append(probe)
        return probe.sustainable

    # --- bracket on the grid, then validate its verdicts.
    k_lo = max(int(np.floor(bracket[0] * bound / step)), 0)
    k_hi = max(int(np.ceil(bracket[1] * bound / step)), k_lo + 1)
    while k_lo > 0 and not evaluate(k_lo):
        k_lo //= 2                    # shrink toward a sustainable floor
    while evaluate(k_hi) and len(probes) < max_calls:
        k_lo = max(k_lo, k_hi)        # hi was sustainable: push the ceiling
        k_hi *= 2

    # --- integer bisection: invariant of the starting bracket.
    n_iters = 0
    while k_hi - k_lo > 1 and len(probes) < max_calls:
        k_mid = (k_lo + k_hi) // 2
        if evaluate(k_mid):
            k_lo = k_mid
        else:
            k_hi = k_mid
        n_iters += 1

    # Each probe's engine accounting already splits n_sims * T_eff into
    # (slots_run, slots_saved); summing both sides recovers the full-run
    # denominator without re-deriving the engine's chunk rounding.
    full = sum(p.slots_run + p.slots_saved for p in probes)
    run_slots = sum(p.slots_run for p in probes)
    return FrontierResult(
        scenario=scenario, policy=policy, eps_b=eps_b, topo_seed=topo_seed,
        lam_max=k_lo * step, bound_exact=bound,
        ratio=k_lo * step / bound, lo=k_lo * step, hi=k_hi * step,
        n_calls=len(probes), n_iters=n_iters,
        total_slots=run_slots, full_slots=full,
        slots_saved=full - run_slots,
        launch_slots_saved=launch_saved[0],
        n_step_compiles=_probe_step_compiles(
            scenario, policy, eps_b, topo_seed, T, chunk, window, vcfg,
            devices),
        probes=tuple(probes))


def _probe_step_compiles(scenario, policy, eps_b, topo_seed, T, chunk,
                         window, vcfg: VerdictConfig, devices) -> int:
    """How many chunk-step programs the search's launches compiled.

    `make_stream_runner`/`make_group_launch` are memoized on exactly the
    values every probe passed, so this lookup returns the *same* jitted
    step_fn the bisection used; its jit cache size is the compile count
    (`TestNoRecompilation` convention)."""
    cfg = FleetJob(scenario=scenario, policy=policy, eps_b=eps_b,
                   topo_seed=topo_seed).policy_config()
    runner = make_stream_runner(cfg, T, chunk=chunk, window=window,
                                verdict=vcfg)
    mesh = Mesh(np.array(list(devices or jax.devices())), ("fleet",))
    _, step_fn, _ = make_group_launch(runner, mesh)
    try:
        return int(step_fn._cache_size())
    except Exception:  # pragma: no cover - private API moved
        return -1
