"""Adaptive λ_max frontier search: bisection over early-stopped fleet runs.

The paper's headline quantity is the *maximum sustainable query rate*
λ_max.  `find_lambda_max` measures it empirically: it brackets the PR-3
exact regulated LP bound (`fleet.report.policy_bound_exact`), then bisects
the offered rate over successive `run_fleet` calls, each early-stopped by
the streaming stability verdict (DESIGN.md §8) — a rate is *sustainable*
iff every seed's sim latches STABLE.  The search contract:

  * **Grid quantization.**  Probed rates live on the fixed grid
    ``lam = k * rel_tol * bound`` (integer ``k``), so bisection from *any*
    valid initial bracket converges to the same boundary index — the
    golden-frontier invariance property.  The final bracket width is one
    grid step, i.e. λ_max is localized to ``rel_tol`` relative to the bound.
  * **Seed decoupling.**  Each probe's per-seed PRNG seeds are SplitMix64
    folds of ``(topo_seed, rate_index, call_index, seed)`` (`fold_seed`) —
    NOT the raw job seed — so two bisection steps at different rates never
    share arrival streams.  Within one search every grid index is
    evaluated at most once (memoized), always with ``call_index = 0``, so
    probes are deterministic per rate and the bracket-invariance above
    holds exactly; a driver that *re*-probes a rate for confirmation
    passes ``call_index > 0`` to draw fresh noise.
  * **Launch-only steps.**  Every probe reuses the same memoized
    `make_stream_runner`/`make_group_launch` programs (identical policy
    config, shapes, and verdict config), so after the first call each
    bisection step is launch-only — asserted via
    `FrontierResult.n_step_compiles == 1`.

Verdict aggregation is conservative: UNDECIDED (like UNSTABLE) counts as
unsustainable, so λ_max is biased *down*, never above the true frontier.
The two outcomes are *recorded* separately, though: every probe carries an
``undecided`` flag (no seed latched UNSTABLE — the probe was blocked by
horizon-limited evidence, not by a diverging queue), and the result's
``undecided`` flag marks a final bracket whose upper end was never
*proven* unstable — the honest reading is "λ_max is at least ``lo``,
localization above it is horizon-limited", not "``hi`` is infeasible".

The bisection *control flow* lives in the pure `Bisection` state machine
so the sequential driver here and the batched capacity atlas
(`fleet.atlas`, DESIGN.md §10) advance bit-identical searches: same probe
order, same budget semantics, same final bracket, given the same verdict
oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from .engine import (FleetJob, VerdictConfig, make_group_launch,
                     make_stream_runner, resolve_verdict, run_fleet)
from .report import policy_bound_exact

_M64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """SplitMix64 finalizer: a bijective avalanche on 64-bit ints."""
    x &= _M64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _M64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _M64
    x ^= x >> 31
    return x


def fold_seed(topo_seed: int, rate_index: int, call_index: int,
              seed: int = 0) -> int:
    """Derive one probe's PRNG seed from the bisection coordinates.

    Successive `run_fleet` calls in the bisection loop must NOT reuse the
    raw job seed: two probes at different rates would then draw the same
    uniforms, coupling their arrival streams (identical noise at every
    probed rate biases the measured frontier).  Folding
    ``(topo_seed, rate_index, call_index, seed)`` through SplitMix64
    decouples every axis while staying deterministic — `find_lambda_max`
    always probes with ``call_index = 0`` (each grid index is evaluated
    once per search), which is what makes the search invariant to the
    initial bracket; confirmation re-probes pass ``call_index > 0`` for
    fresh noise.  Returns a non-negative int31, safe for
    `jax.random.PRNGKey` via the engine's int32 path."""
    h = (0x9E3779B97F4A7C15 * (topo_seed & _M64)
         + 0xBF58476D1CE4E5B9 * (rate_index & _M64)
         + 0x94D049BB133111EB * (call_index & _M64)
         + 0xD6E8FEB86659FD93 * (seed & _M64) + 0x2545F4914F6CDD1D) & _M64
    return int(_mix64(h) & 0x7FFFFFFF)


class Bisection:
    """Pure pull-based bisection state machine for one frontier cell.

    The exact control flow `find_lambda_max` has always run — shrink the
    floor (``k_lo //= 2`` until sustainable), push the ceiling (``k_hi *= 2``
    while sustainable), then integer bisection — inverted into a state
    machine the *driver* pulls probes from: `next_rate_index()` returns the
    grid index to evaluate next (or None when the search is finished), and
    `record(k, sustainable, undecided)` feeds the verdict back.  Cached
    indices and the ``max_calls`` budget are consumed internally, so a
    driver never sees a repeat probe and the budget-exhausted pseudo-result
    (conservative: unsustainable, nothing cached) matches the sequential
    path's semantics exactly.

    This is what makes the batched capacity atlas (`fleet.atlas`,
    DESIGN.md §10) bit-equivalent to per-scenario `find_lambda_max`: both
    drive the *same* machine, only the probe evaluation is batched.

    Outcome bookkeeping is conservative-but-honest (DESIGN.md §8):
    UNDECIDED counts as unsustainable for the bracket update, but
    `undecided_hi` flags a final upper end that was never *proven*
    unstable, and `k_hi_certain` is the smallest index with genuinely
    UNSTABLE evidence (None if the search never saw one) — the widened,
    certain bracket is ``(k_lo, k_hi_certain)``.
    """

    def __init__(self, k_lo: int, k_hi: int, max_calls: int = 24):
        self.k_lo = max(int(k_lo), 0)
        self.k_hi = max(int(k_hi), self.k_lo + 1)
        self.max_calls = int(max_calls)
        self.n_evals = 0             # probes actually evaluated (the budget)
        self.n_iters = 0             # bisection halvings (excl. validation)
        # k -> (sustainable, undecided); undecided = blocked by UNDECIDED
        # seeds only, no UNSTABLE evidence.
        self.outcomes: Dict[int, Tuple[bool, bool]] = {}
        self._phase = "lo"           # lo -> hi -> mid -> done
        self._pending: Optional[int] = None
        self._mid_pending: Optional[int] = None
        self.done = False

    def _resolve(self, k: int) -> Tuple[bool, bool]:
        """evaluate(k) without launching: (resolved, sustainable)."""
        if k <= 0:
            return True, True        # lam = 0 is trivially sustainable
        if k in self.outcomes:
            return True, self.outcomes[k][0]
        if self.n_evals >= self.max_calls:
            return True, False       # budget exhausted: stay conservative
        return False, False

    def next_rate_index(self) -> Optional[int]:
        """The next grid index to probe, or None when the search is done.

        Idempotent while a probe is outstanding: repeated calls return the
        same pending index until `record` resolves it."""
        if self._pending is not None:
            return self._pending
        while not self.done:
            if self._phase == "lo":
                # shrink toward a sustainable floor
                if self.k_lo <= 0:
                    self._phase = "hi"
                    continue
                resolved, ok = self._resolve(self.k_lo)
                if not resolved:
                    self._pending = self.k_lo
                    return self.k_lo
                if ok:
                    self._phase = "hi"
                else:
                    self.k_lo //= 2
            elif self._phase == "hi":
                # a sustainable ceiling means the bracket missed: push it
                resolved, ok = self._resolve(self.k_hi)
                if not resolved:
                    self._pending = self.k_hi
                    return self.k_hi
                if ok and self.n_evals < self.max_calls:
                    self.k_lo = max(self.k_lo, self.k_hi)
                    self.k_hi *= 2
                else:
                    self._phase = "mid"
            else:
                # integer bisection: invariant of the starting bracket
                if self._mid_pending is not None:
                    # A bisection iteration that issued a probe finishes
                    # *after* the budget check it already passed — the
                    # sequential loop applies the outcome of its last
                    # in-budget probe before re-testing the loop guard.
                    mid, self._mid_pending = self._mid_pending, None
                    if self.outcomes[mid][0]:
                        self.k_lo = mid
                    else:
                        self.k_hi = mid
                    self.n_iters += 1
                    continue
                if self.k_hi - self.k_lo <= 1 or \
                        self.n_evals >= self.max_calls:
                    self.done = True
                    break
                mid = (self.k_lo + self.k_hi) // 2
                resolved, ok = self._resolve(mid)
                if not resolved:
                    self._pending = mid
                    self._mid_pending = mid
                    return mid
                if ok:
                    self.k_lo = mid
                else:
                    self.k_hi = mid
                self.n_iters += 1
        return None

    def record(self, k: int, sustainable: bool,
               undecided: bool = False) -> None:
        """Resolve the pending probe.  ``undecided`` marks a probe blocked
        only by UNDECIDED-at-horizon seeds (no UNSTABLE evidence)."""
        if k != self._pending:
            raise ValueError(f"recorded k={k} but pending probe is "
                             f"{self._pending}")
        # A decided (done) machine never has a pending probe, so a stray
        # record after convergence raises above rather than mutating state.
        self.outcomes[k] = (bool(sustainable), bool(undecided))
        self.n_evals += 1
        self._pending = None

    # -- checkpoint serialization (DESIGN.md §12) --------------------------
    # The machine is pure host state, so a JSON round-trip of these fields
    # is a *bit-exact* resume of the search: same pending probe, same memo,
    # same budget — the atlas checkpoints every cell's machine this way.

    def to_state(self) -> dict:
        return {"k_lo": self.k_lo, "k_hi": self.k_hi,
                "max_calls": self.max_calls, "n_evals": self.n_evals,
                "n_iters": self.n_iters,
                "outcomes": [[k, ok, und]
                             for k, (ok, und) in self.outcomes.items()],
                "phase": self._phase, "pending": self._pending,
                "mid_pending": self._mid_pending, "done": self.done}

    @classmethod
    def from_state(cls, state: dict) -> "Bisection":
        b = cls(1, 2)                       # placeholders, overwritten below
        b.k_lo = int(state["k_lo"])
        b.k_hi = int(state["k_hi"])
        b.max_calls = int(state["max_calls"])
        b.n_evals = int(state["n_evals"])
        b.n_iters = int(state["n_iters"])
        b.outcomes = {int(k): (bool(ok), bool(und))
                      for k, ok, und in state["outcomes"]}
        b._phase = state["phase"]
        b._pending = (None if state["pending"] is None
                      else int(state["pending"]))
        b._mid_pending = (None if state["mid_pending"] is None
                          else int(state["mid_pending"]))
        b.done = bool(state["done"])
        return b

    @property
    def undecided_hi(self) -> bool:
        """Final upper end blocked by horizon-limited (UNDECIDED) evidence
        rather than a proven UNSTABLE verdict."""
        o = self.outcomes.get(self.k_hi)
        return bool(o is not None and not o[0] and o[1])

    @property
    def k_hi_certain(self) -> Optional[int]:
        """Smallest probed index with genuinely UNSTABLE evidence — the
        honest (widened) upper bracket end when `undecided_hi`."""
        certain = [k for k, (ok, und) in self.outcomes.items()
                   if not ok and not und]
        return min(certain) if certain else None


@dataclasses.dataclass(frozen=True)
class RateProbe:
    """One evaluated rate of the frontier search."""

    rate_index: int          # grid index k (lam = k * rel_tol * bound)
    call_index: int          # how many times this rate had been probed before
    lam: float
    sustainable: bool        # all seeds latched STABLE
    verdicts: Tuple[str, ...]
    decided_at: Tuple[int, ...]
    slots_run: int           # simulated slots actually advanced
    slots_saved: int         # simulated slots the freeze skipped
    undecided: bool = False  # unsustainable only for lack of evidence: no
                             # seed latched UNSTABLE (horizon-limited)


@dataclasses.dataclass(frozen=True)
class FrontierResult:
    """Outcome of `find_lambda_max` (DESIGN.md §8)."""

    scenario: str
    policy: str
    eps_b: float
    topo_seed: int
    lam_max: float           # largest grid rate verified sustainable
    bound_exact: float       # the exact regulated LP bound it is measured against
    ratio: float             # lam_max / bound_exact
    lo: float                # final bracket: sustainable side
    hi: float                # final bracket: unsustainable side (lo + grid step)
    n_calls: int             # run_fleet launches issued
    n_iters: int             # bisection halvings (excl. bracket validation)
    total_slots: int         # simulated slots advanced across all probes
    full_slots: int          # slots a no-early-stop search would have run
    slots_saved: int         # full_slots - total_slots (per-sim freeze savings)
    launch_slots_saved: int  # chunks never dispatched once groups decided
    n_step_compiles: int     # compiled chunk-step programs used (must be 1)
    probes: Tuple[RateProbe, ...]
    undecided: bool = False  # final bracket's upper end was never *proven*
                             # unstable — blocked by UNDECIDED-at-horizon
                             # evidence only (satellite: DESIGN.md §8)
    hi_certain: float | None = None  # smallest rate with genuine UNSTABLE
                                     # evidence; None if the search saw none.
                                     # When `undecided`, the honest (widened)
                                     # bracket is (lo, hi_certain].

    @property
    def slots_saved_frac(self) -> float:
        return self.slots_saved / self.full_slots if self.full_slots else 0.0


def find_lambda_max(scenario: str, policy: str = "pi3", *,
                    eps_b: float = 0.01, topo_seed: int = 0,
                    seeds: Sequence[int] = (0, 1), T: int = 4096,
                    chunk: int = 512, window: int | None = None,
                    rel_tol: float = 0.025,
                    bracket: Tuple[float, float] = (0.5, 1.1),
                    max_calls: int = 24, early_stop: bool = True,
                    verdict: VerdictConfig | None = None,
                    devices=None, dims=None,
                    stream_log=None) -> FrontierResult:
    """Locate the empirical max sustainable rate λ_max of one (scenario,
    policy) pair by bisecting offered rate over early-stopped fleet runs.

    ``bracket`` is the initial (lo, hi) as *fractions of the exact bound*;
    it is validated first (lo must be sustainable, hi unsustainable) and
    expanded/shrunk on the quantized grid if not.  Every probe runs
    ``len(seeds)`` sims through `run_fleet(early_stop=...)`; the probe is
    sustainable iff all of them latch STABLE.  ``dims`` optionally pins the
    padded topology dims (`batching.PadDims`) — the atlas equivalence tests
    pass the atlas-wide dims here so both paths run the identical padded
    program.  ``stream_log`` taps every probe's per-chunk telemetry
    (DESIGN.md §11): it is handed to each `run_fleet` call, so records
    restart their (group, chunk, t) clocks per probe — a live progress
    feed, not one monotone stream (the atlas emits that).  See the module
    docstring for the quantization / seed-fold / launch-only contract."""
    bound = policy_bound_exact(scenario, policy, eps_b, topo_seed=topo_seed)
    if bound <= 0.0:
        raise ValueError(f"{scenario}: exact LP bound is {bound}; "
                         "nothing to bisect")
    step = rel_tol * bound
    vcfg = resolve_verdict(verdict, early_stop)
    seeds = tuple(seeds)

    probes: List[RateProbe] = []
    launch_saved = 0

    # The control flow lives in the pure Bisection machine — the identical
    # machine `fleet.atlas` advances for hundreds of cells at once — so the
    # sequential and batched searches probe the same grid indices in the
    # same order with the same budget semantics.
    bis = Bisection(
        k_lo=max(int(np.floor(bracket[0] * bound / step)), 0),
        k_hi=max(int(np.ceil(bracket[1] * bound / step)), 1),
        max_calls=max_calls)

    while (k := bis.next_rate_index()) is not None:
        # Each grid index is evaluated once per search (the machine's memo),
        # always at call_index 0 — deterministic per rate, which is what
        # makes the result invariant to the initial bracket.
        jobs = [FleetJob(scenario=scenario, policy=policy, lam=k * step,
                         eps_b=eps_b, topo_seed=topo_seed,
                         seed=fold_seed(topo_seed, k, 0, s))
                for s in seeds]
        res = run_fleet(jobs, T=T, chunk=chunk, window=window,
                        early_stop=early_stop, verdict=verdict,
                        devices=devices, dims=dims, stream_log=stream_log)
        launch_saved += res.launch_slots_saved
        names = res.verdicts()
        sustainable = all(v == "STABLE" for v in names)
        probe = RateProbe(
            rate_index=k, call_index=0, lam=k * step,
            sustainable=sustainable,
            verdicts=tuple(names),
            decided_at=tuple(int(d)
                             for d in res.column("decided_at_slot")),
            slots_run=res.n_sims * res.T - res.slots_saved,
            slots_saved=res.slots_saved,
            undecided=not sustainable and "UNSTABLE" not in names)
        probes.append(probe)
        bis.record(k, probe.sustainable, probe.undecided)

    # Each probe's engine accounting already splits n_sims * T_eff into
    # (slots_run, slots_saved); summing both sides recovers the full-run
    # denominator without re-deriving the engine's chunk rounding.
    full = sum(p.slots_run + p.slots_saved for p in probes)
    run_slots = sum(p.slots_run for p in probes)
    return FrontierResult(
        scenario=scenario, policy=policy, eps_b=eps_b, topo_seed=topo_seed,
        lam_max=bis.k_lo * step, bound_exact=bound,
        ratio=bis.k_lo * step / bound,
        lo=bis.k_lo * step, hi=bis.k_hi * step,
        n_calls=len(probes), n_iters=bis.n_iters,
        total_slots=run_slots, full_slots=full,
        slots_saved=full - run_slots,
        launch_slots_saved=launch_saved,
        n_step_compiles=_probe_step_compiles(
            scenario, policy, eps_b, topo_seed, T, chunk, window, vcfg,
            devices),
        probes=tuple(probes),
        undecided=bis.undecided_hi,
        hi_certain=(None if bis.k_hi_certain is None
                    else bis.k_hi_certain * step))


def _probe_step_compiles(scenario, policy, eps_b, topo_seed, T, chunk,
                         window, vcfg: VerdictConfig, devices) -> int:
    """How many chunk-step programs the search's launches compiled.

    `make_stream_runner`/`make_group_launch` are memoized on exactly the
    values every probe passed, so this lookup returns the *same* jitted
    step_fn the bisection used; its jit cache size is the compile count
    (`TestNoRecompilation` convention)."""
    cfg = FleetJob(scenario=scenario, policy=policy, eps_b=eps_b,
                   topo_seed=topo_seed).policy_config()
    runner = make_stream_runner(cfg, T, chunk=chunk, window=window,
                                verdict=vcfg)
    mesh = Mesh(np.array(list(devices or jax.devices())), ("fleet",))
    _, step_fn, _ = make_group_launch(runner, mesh)
    try:
        return int(step_fn._cache_size())
    except Exception:  # pragma: no cover - private API moved
        return -1
