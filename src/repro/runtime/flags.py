"""Process-local lowering flags.

`layer_scan` wraps `lax.scan` for *layer stacks*: under
`unrolled_scans()` the stack is fully unrolled so XLA's HLO cost analysis
(which counts while-loop bodies once, not x trip count) sees every layer.
The dry-run uses this for its depth-probe compiles; production lowering
keeps the rolled scan (small HLO, fast compile).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax

_UNROLL = contextvars.ContextVar("repro_unroll_scans", default=False)
_ATTN = contextvars.ContextVar("repro_attn_impl", default="naive")
_SEQ_PAR_TP = contextvars.ContextVar("repro_seq_par_tp", default=False)
_CTX_PAR = contextvars.ContextVar("repro_ctx_par", default=False)


@contextlib.contextmanager
def unrolled_scans():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


@contextlib.contextmanager
def attention_impl(name: str):
    """naive (materialized scores) | chunked (online-softmax, flash-in-XLA)."""
    assert name in ("naive", "chunked"), name
    tok = _ATTN.set(name)
    try:
        yield
    finally:
        _ATTN.reset(tok)


def attn_impl() -> str:
    return _ATTN.get()


@contextlib.contextmanager
def seq_parallel_tp(on: bool = True):
    """Megatron-style sequence-parallel TP: residual-stream activations are
    sharded over the model axis on the sequence dim between blocks, turning
    per-layer all-reduces into reduce-scatter + all-gather (2x fewer bytes)."""
    tok = _SEQ_PAR_TP.set(on)
    try:
        yield
    finally:
        _SEQ_PAR_TP.reset(tok)


def seq_par_tp() -> bool:
    return _SEQ_PAR_TP.get()


def scans_unrolled() -> bool:
    return _UNROLL.get()


def layer_scan(f, init, xs, **kw):
    return jax.lax.scan(f, init, xs, unroll=True if _UNROLL.get() else 1, **kw)


@contextlib.contextmanager
def context_parallel(on: bool = True):
    """Context parallelism for train/prefill attention: the query sequence
    dim is sharded over the *model* axis during score computation (K/V are
    gathered), so attention work divides by the model-axis size even when
    head counts don't (e.g. 40 heads on a 16-way axis)."""
    tok = _CTX_PAR.set(on)
    try:
        yield
    finally:
        _CTX_PAR.reset(tok)


def ctx_par() -> bool:
    return _CTX_PAR.get()
