"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP) with divisibility
fallback.

Params and activations carry *logical* axis names; `make_rules` maps them to
mesh axes given the RunConfig knobs, and `spec_for` drops any mesh axis that
does not divide the concrete dim (e.g. qwen2's 14 heads on a 16-way model
axis -> replicated heads, sharded FFN/vocab).

A process-global context (set by the launcher / dry-run) makes
`constrain(x, axes)` a no-op in plain CPU tests and a
`with_sharding_constraint` under a mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    table: dict
    mesh: Mesh

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def make_rules(mesh: Mesh, *, fsdp: bool = True, expert_parallel: bool = True,
               seq_shard_decode: bool = True,
               kv_seq_model: bool = False) -> Rules:
    """kv_seq_model: shard the KV-cache sequence dim over the *model* axis
    (flash-decode style partial-softmax) — the right call when kv_heads do
    not divide the model axis (else the cache would be replicated 16x)."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = "model" if "model" in mesh.shape else None
    fs = dp_axes if fsdp else None
    table = {
        # ---- parameter logical axes
        "layers": None,
        "embed": fs,                      # FSDP shards the d_model dim
        "vocab": tp,
        "heads": tp,
        "kv_heads": tp,
        "head_dim": None,
        "ff": tp,
        "experts": tp if expert_parallel else None,
        "expert_ff": None if expert_parallel else tp,
        "dinner": tp,                     # SSM inner channels
        "conv": None,
        "state": None,
        "ssm_heads": tp,
        # ---- activation logical axes
        "act_batch": dp_axes,
        "act_group": dp_axes,
        "act_seq": None,
        "act_seq_ctx": tp,                # context-parallel attention
        "act_embed": None,
        "act_ff": tp,
        "act_heads": tp,
        "act_kv_heads": tp,
        "act_dinner": tp,
        "act_experts": tp if expert_parallel else None,
        "cache_seq": (("model",) if kv_seq_model else
                      (dp_axes if seq_shard_decode else None)),
        "cache_batch": dp_axes,
    }
    return Rules(table=table, mesh=mesh)


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             rules: Rules) -> P:
    """PartitionSpec with divisibility-aware fallback to replication.

    Tuple-vs-scalar normalization: a rules-table entry that is a *tuple* of
    mesh axes (a multi-axis group like the FSDP ``("pod", "data")``) stays a
    tuple in the spec even when only one axis survives filtering —
    `PartitionSpec` equality distinguishes ``P("data")`` from
    ``P(("data",))``, so collapsing would make specs built from the same
    table compare unequal depending on mesh size.  Scalar (str) entries stay
    scalar."""
    entries = []
    used = set()
    for dim, ax in zip(shape, axes):
        mesh_axes = rules.table.get(ax) if ax else None
        if mesh_axes is None:
            entries.append(None)
            continue
        grouped = not isinstance(mesh_axes, str)
        if not grouped:
            mesh_axes = (mesh_axes,)
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        size = int(np.prod([rules.mesh.shape[a] for a in mesh_axes])) if mesh_axes else 1
        if mesh_axes and dim % size == 0 and dim > 0:
            entries.append(mesh_axes if grouped else mesh_axes[0])
            used.update(mesh_axes)
        else:
            entries.append(None)
    return P(*entries)


def sharding_for(value, axes, rules: Rules) -> NamedSharding:
    return NamedSharding(rules.mesh, spec_for(value.shape, axes, rules))


def tree_shardings(values, axes_tree, rules: Rules):
    """Map an (abstract) value tree + logical-axes tree -> NamedSharding tree."""
    # tree_map flattens `values` first and passes the matching axes subtree
    # (a tuple of logical names) whole to the mapped function.
    return jax.tree_util.tree_map(
        lambda v, a: sharding_for(v, a, rules), values, axes_tree)


# ---------------------------------------------------------------------------
# Process-global constraint context
# ---------------------------------------------------------------------------

_ACTIVE: list = []


@contextlib.contextmanager
def use_rules(rules: Rules):
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def active_rules() -> Optional[Rules]:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint if a rules context is active, else identity."""
    r = active_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, spec_for(x.shape, axes, r)))
