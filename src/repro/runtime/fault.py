"""Fault tolerance: straggler detection, heartbeat tracking, elastic
re-mesh planning.

At 1000+-node scale the failure model is: (i) slow hosts (thermal, network)
-> detect via per-step timing statistics and rebalance/evict; (ii) dead
hosts -> detect via heartbeat timeout -> rebuild a smaller mesh and restore
from the last checkpoint (full-array checkpoints re-shard onto any mesh,
checkpoint/checkpointer.py).  This module is pure control-plane logic so it
is unit-testable on one host; the launcher wires it to real timers.

The straggler policy is itself the paper's lesson: queue-length (backlog)
based decisions beat static assignment — a host whose step-time queue grows
is drained before it stalls the collective.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    window: int = 32               # ring-buffer of recent step times
    factor: float = 1.8            # median multiple considered "straggling"
    patience: int = 8              # consecutive slow steps before action
    heartbeat_timeout_s: float = 60.0


class StragglerDetector:
    """Per-host step-time ring buffers + median-factor rule."""

    def __init__(self, hosts: List[str], cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times: Dict[str, deque] = {h: deque(maxlen=cfg.window)
                                        for h in hosts}
        self.slow_streak: Dict[str, int] = {h: 0 for h in hosts}
        self.last_seen: Dict[str, float] = {h: time.time() for h in hosts}

    def record(self, host: str, step_time: float,
               now: Optional[float] = None) -> None:
        self.times[host].append(step_time)
        self.last_seen[host] = now if now is not None else time.time()

    def _medians(self) -> Dict[str, float]:
        meds = {}
        for h, buf in self.times.items():
            if buf:
                s = sorted(buf)
                meds[h] = s[len(s) // 2]
        return meds

    def stragglers(self) -> List[str]:
        """Hosts whose median step time exceeds factor x fleet median."""
        meds = self._medians()
        if len(meds) < 2:
            return []
        fleet = sorted(meds.values())[len(meds) // 2]
        out = []
        for h, m in meds.items():
            if m > self.cfg.factor * fleet:
                self.slow_streak[h] += 1
                if self.slow_streak[h] >= self.cfg.patience:
                    out.append(h)
            else:
                self.slow_streak[h] = 0
        return out

    def dead(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items()
                if now - t > self.cfg.heartbeat_timeout_s]


# ---------------------------------------------------------------------------
# Injectable fault plane (DESIGN.md §12)
#
# The chunk schedulers (fleet/engine.py, fleet/atlas.py, serving/engine.py)
# consult a FaultPlane at two points of every launch:
#
#   * before dispatch   -> `on_launch` may raise InjectedFault (a transient
#     launch failure).  The carry has NOT been donated yet, so the engine
#     retries the same launch with the live carry, bounded by
#     ResilienceConfig.max_retries with exponential backoff; exhaustion
#     raises FaultExhausted.
#   * at the boundary   -> after the post-launch snapshot, `maybe_preempt`
#     may raise Preempted (a simulated SIGTERM).  The snapshot is already
#     durable, so a resumed run continues bit-exact from this boundary.
#     `dead_hosts` reports which mesh hosts have dropped out by this
#     boundary; the engines park their lanes and re-plan via
#     `plan_recovery` instead of aborting.
#
# The plane is pure host-side state: deterministic, unit-testable, and
# shared across the retries of one run (a `fails=2` spec fails twice total,
# not twice per attempt).


class InjectedFault(RuntimeError):
    """A (simulated) transient launch failure — retryable."""


class FaultExhausted(RuntimeError):
    """A launch kept failing past ResilienceConfig.max_retries."""


class Preempted(RuntimeError):
    """A (simulated) SIGTERM at a chunk boundary.  The engine's snapshot
    for this boundary is already on disk when this propagates."""


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault.

    kind       : "launch_fail" | "host_dropout" | "preempt"
    at_launch  : global launch index (0-based, counted across policy
                 groups).  launch_fail fires when launch `at_launch` is
                 dispatched; host_dropout means the host is dead for every
                 boundary >= at_launch; preempt fires at the boundary after
                 `at_launch` launches have completed.
    group      : restrict launch_fail to one policy group (None = any).
    fails      : launch_fail only — how many consecutive attempts fail
                 before the retry succeeds.
    host       : host_dropout only — mesh host index that dies.
    """
    kind: str
    at_launch: int = 0
    group: Optional[int] = None
    fails: int = 1
    host: int = 0


class FaultPlane:
    """Deterministic fault schedule consumed by the chunk schedulers."""

    def __init__(self, specs: tuple | list = ()):
        self.specs: List[FaultSpec] = list(specs)
        for s in self.specs:
            assert s.kind in ("launch_fail", "host_dropout", "preempt"), s
        self._fails_left = {i: s.fails for i, s in enumerate(self.specs)
                            if s.kind == "launch_fail"}
        self.n_injected = 0
        self.log: List[tuple] = []     # (event, launch_idx, detail)

    # -- convenience constructors ------------------------------------------
    @classmethod
    def preempt_after(cls, n_launches: int) -> "FaultPlane":
        """Simulate SIGTERM at the boundary after `n_launches` launches."""
        return cls([FaultSpec("preempt", at_launch=n_launches)])

    @classmethod
    def launch_fail(cls, at_launch: int, fails: int = 1,
                    group: Optional[int] = None) -> "FaultPlane":
        return cls([FaultSpec("launch_fail", at_launch=at_launch,
                              fails=fails, group=group)])

    @classmethod
    def host_dropout(cls, host: int, at_launch: int) -> "FaultPlane":
        """Host `host` drops out at boundary `at_launch` (and stays dead)."""
        return cls([FaultSpec("host_dropout", at_launch=at_launch,
                              host=host)])

    # -- scheduler hooks ---------------------------------------------------
    def on_launch(self, group: int, launch_idx: int) -> None:
        """Raise InjectedFault if a launch_fail spec targets this attempt."""
        for i, s in enumerate(self.specs):
            if (s.kind == "launch_fail" and s.at_launch == launch_idx
                    and (s.group is None or s.group == group)
                    and self._fails_left.get(i, 0) > 0):
                self._fails_left[i] -= 1
                self.n_injected += 1
                self.log.append(("launch_fail", launch_idx, group))
                raise InjectedFault(
                    f"injected launch failure at launch {launch_idx} "
                    f"(group {group})")

    def maybe_preempt(self, launches_done: int) -> None:
        """Raise Preempted at the boundary after `launches_done` launches."""
        for s in self.specs:
            if s.kind == "preempt" and s.at_launch == launches_done:
                self.log.append(("preempt", launches_done, None))
                raise Preempted(
                    f"simulated SIGTERM after {launches_done} launches")

    def dead_hosts(self, launches_done: int) -> tuple:
        """Sorted mesh-host indices dead at this boundary."""
        return tuple(sorted({s.host for s in self.specs
                             if s.kind == "host_dropout"
                             and s.at_launch <= launches_done}))


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    action: str                    # none | rebalance | remesh
    evict: tuple = ()
    new_mesh_shape: Optional[tuple] = None
    note: str = ""


def plan_recovery(n_hosts: int, devices_per_host: int, dead: List[str],
                  stragglers: List[str], model_parallel: int) -> RecoveryPlan:
    """Decide the cheapest recovery that keeps the mesh factorizable.

    Policy: dead hosts force a re-mesh (drop to the largest device count
    divisible by model_parallel); stragglers are first rebalanced (smaller
    per-host batch via the backpressure admission queue), evicted only if
    they persist.
    """
    if dead:
        alive = n_hosts - len(dead)
        devices = alive * devices_per_host
        dp = devices // model_parallel
        if dp < 1:
            return RecoveryPlan("remesh", tuple(dead), None,
                                "not enough devices for model parallelism")
        return RecoveryPlan("remesh", tuple(dead),
                            (dp, model_parallel),
                            f"rebuild ({dp},{model_parallel}) mesh, restore "
                            "latest checkpoint with resharding")
    if stragglers:
        return RecoveryPlan("rebalance", tuple(stragglers), None,
                            "shift admission quota away from stragglers "
                            "(H-queue weighting), evict on next strike")
    return RecoveryPlan("none")
