"""Fault tolerance: straggler detection, heartbeat tracking, elastic
re-mesh planning.

At 1000+-node scale the failure model is: (i) slow hosts (thermal, network)
-> detect via per-step timing statistics and rebalance/evict; (ii) dead
hosts -> detect via heartbeat timeout -> rebuild a smaller mesh and restore
from the last checkpoint (full-array checkpoints re-shard onto any mesh,
checkpoint/checkpointer.py).  This module is pure control-plane logic so it
is unit-testable on one host; the launcher wires it to real timers.

The straggler policy is itself the paper's lesson: queue-length (backlog)
based decisions beat static assignment — a host whose step-time queue grows
is drained before it stalls the collective.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    window: int = 32               # ring-buffer of recent step times
    factor: float = 1.8            # median multiple considered "straggling"
    patience: int = 8              # consecutive slow steps before action
    heartbeat_timeout_s: float = 60.0


class StragglerDetector:
    """Per-host step-time ring buffers + median-factor rule."""

    def __init__(self, hosts: List[str], cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times: Dict[str, deque] = {h: deque(maxlen=cfg.window)
                                        for h in hosts}
        self.slow_streak: Dict[str, int] = {h: 0 for h in hosts}
        self.last_seen: Dict[str, float] = {h: time.time() for h in hosts}

    def record(self, host: str, step_time: float,
               now: Optional[float] = None) -> None:
        self.times[host].append(step_time)
        self.last_seen[host] = now if now is not None else time.time()

    def _medians(self) -> Dict[str, float]:
        meds = {}
        for h, buf in self.times.items():
            if buf:
                s = sorted(buf)
                meds[h] = s[len(s) // 2]
        return meds

    def stragglers(self) -> List[str]:
        """Hosts whose median step time exceeds factor x fleet median."""
        meds = self._medians()
        if len(meds) < 2:
            return []
        fleet = sorted(meds.values())[len(meds) // 2]
        out = []
        for h, m in meds.items():
            if m > self.cfg.factor * fleet:
                self.slow_streak[h] += 1
                if self.slow_streak[h] >= self.cfg.patience:
                    out.append(h)
            else:
                self.slow_streak[h] = 0
        return out

    def dead(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items()
                if now - t > self.cfg.heartbeat_timeout_s]


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    action: str                    # none | rebalance | remesh
    evict: tuple = ()
    new_mesh_shape: Optional[tuple] = None
    note: str = ""


def plan_recovery(n_hosts: int, devices_per_host: int, dead: List[str],
                  stragglers: List[str], model_parallel: int) -> RecoveryPlan:
    """Decide the cheapest recovery that keeps the mesh factorizable.

    Policy: dead hosts force a re-mesh (drop to the largest device count
    divisible by model_parallel); stragglers are first rebalanced (smaller
    per-host batch via the backpressure admission queue), evicted only if
    they persist.
    """
    if dead:
        alive = n_hosts - len(dead)
        devices = alive * devices_per_host
        dp = devices // model_parallel
        if dp < 1:
            return RecoveryPlan("remesh", tuple(dead), None,
                                "not enough devices for model parallelism")
        return RecoveryPlan("remesh", tuple(dead),
                            (dp, model_parallel),
                            f"rebuild ({dp},{model_parallel}) mesh, restore "
                            "latest checkpoint with resharding")
    if stragglers:
        return RecoveryPlan("rebalance", tuple(stragglers), None,
                            "shift admission quota away from stragglers "
                            "(H-queue weighting), evict on next strike")
    return RecoveryPlan("none")
