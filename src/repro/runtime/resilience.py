"""Preemption-safe engine runs (DESIGN.md §12).

The chunk schedulers (`fleet.run_fleet`, `fleet.atlas.sweep_lambda_max`,
`serving.run_serving`) drive Python loops of donated
`jit(shard_map(vmap(chunk_step)))` launches.  Between launches the carry
is a real pytree of device arrays that nothing aliases yet — the same
window the verdict readouts and telemetry probes already use — so that is
the *only* place a snapshot is taken: read the carry to host
(snapshot-before-donate), publish it atomically with the host-side
scheduler state, then let the next launch donate the buffers.

What a checkpoint holds:

  * the donated carry, as full unsharded numpy (restorable onto any mesh
    via `Checkpointer.restore(..., shardings=...)`), and
  * an ``extra`` JSON payload inside the manifest: engine name, a run
    signature, the group/launch cursor, finished per-job metrics, and —
    for the atlas — every cell's serialized `Bisection` machine,
    `RateProbe` history, pending assignments and the `lam/seed` lane
    tables.  Everything else (padded topologies, per-lane rate/seed/model
    constants, compiled programs) is rebuilt deterministically from the
    job list, so it is *not* checkpointed.

Bit-exact resume follows: the carry round-trips through `.npy` exactly,
the slot counter ``t`` rides *inside* the carry (so the per-slot
`fold_in(key, t)` RNG stream continues unbroken), JSON round-trips the
finished float metrics exactly, and the memoized launch builders hand a
same-process resume the already-compiled programs (zero extra step
compiles).  The run signature guards against resuming someone else's
checkpoint: it hashes the jobs/horizon/verdict/mesh-width axes and a
mismatch raises instead of silently blending two runs.

`ResilienceConfig.fault_plane` additionally wires `runtime.fault`'s
injectable fault plane into the same loops — see `FaultPlane` for the
taxonomy (transient launch failures -> bounded retry with backoff; host
dropout -> park + re-plan; preemption -> durable snapshot then raise).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import Checkpointer
from .fault import (FaultExhausted, FaultPlane, InjectedFault,  # noqa: F401
                    Preempted, RecoveryPlan)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs of one preemption-safe engine run.

    checkpoint_dir : where snapshots live (None = fault plane only).
    every          : snapshot every N-th launch boundary (global count).
    keep           : retained steps (Checkpointer keep-last-k).
    resume         : restore from the newest intact checkpoint if one
                     matches this run's signature; False starts fresh.
    blocking       : False writes the snapshot to disk on a background
                     thread (the host->numpy read is always synchronous —
                     that is the snapshot-before-donate contract).  A kill
                     mid-write costs one interval: restore falls back to
                     the previous intact step.
    fault_plane    : injectable fault schedule (`runtime.fault.FaultPlane`).
    max_retries    : bounded retry budget per launch for InjectedFault.
    backoff_s      : base of the exponential retry backoff (0 = immediate).
    """

    checkpoint_dir: Optional[str] = None
    every: int = 1
    keep: int = 3
    resume: bool = True
    blocking: bool = True
    fault_plane: Optional[FaultPlane] = None
    max_retries: int = 3
    backoff_s: float = 0.0


def run_signature(engine: str, **params) -> str:
    """Stable hash of the axes that define a run's identity.

    Jobs/cells are frozen dataclasses and configs are frozen dataclasses
    or ints, so their reprs are deterministic; resuming a checkpoint whose
    signature differs raises rather than blending two different runs."""
    canon = repr((engine, sorted(params.items())))
    return hashlib.sha256(canon.encode()).hexdigest()


def host_lane_mask(Bp: int, ndev: int, dead_hosts) -> np.ndarray:
    """[Bp] bool mask of lanes living on dead mesh hosts.

    The `"fleet"` mesh shards the padded batch into ``ndev`` contiguous
    blocks, so lane ``l`` lives on host ``l // (Bp // ndev)``."""
    per = Bp // ndev
    mask = np.zeros(Bp, bool)
    for h in dead_hosts:
        if 0 <= h < ndev:
            mask[h * per:(h + 1) * per] = True
    return mask


def plan_state(plan: Optional[RecoveryPlan]) -> Optional[dict]:
    return None if plan is None else dataclasses.asdict(plan)


def plan_restore(state: Optional[dict]) -> Optional[RecoveryPlan]:
    if state is None:
        return None
    return RecoveryPlan(
        action=state["action"], evict=tuple(state["evict"]),
        new_mesh_shape=(None if state["new_mesh_shape"] is None
                        else tuple(state["new_mesh_shape"])),
        note=state["note"])


# -- host-side scheduler-state serialization (atlas) ------------------------
# RateProbe/AtlasRow are frozen dataclasses of scalars + tuples: a plain
# asdict round-trips through JSON up to tuple->list, undone here.

def probe_state(p) -> dict:
    return dataclasses.asdict(p)


def probe_restore(state: dict):
    from repro.fleet.frontier import RateProbe
    s = dict(state)
    s["verdicts"] = tuple(s["verdicts"])
    s["decided_at"] = tuple(int(x) for x in s["decided_at"])
    return RateProbe(**s)


def row_state(row) -> dict:
    s = dataclasses.asdict(row)
    s["probes"] = [probe_state(p) for p in row.probes]
    return s


def row_restore(state: dict):
    from repro.fleet.atlas import AtlasRow
    s = dict(state)
    s["probes"] = tuple(probe_restore(p) for p in s["probes"])
    return AtlasRow(**s)


class ResilientRun:
    """One engine run's resilience runtime: snapshot/restore + faults.

    Built by the engines when a `ResilienceConfig` is passed; `resumed`
    is the newest intact checkpoint's ``extra`` payload (plus its
    ``ckpt_step``) when there is one to continue from, else None.
    """

    def __init__(self, cfg: ResilienceConfig, engine: str, signature: str):
        self.cfg = cfg
        self.engine = engine
        self.signature = signature
        self.ckpt = (Checkpointer(cfg.checkpoint_dir, keep=cfg.keep)
                     if cfg.checkpoint_dir else None)
        self.fault = cfg.fault_plane
        self.n_retries = 0
        self.resumed: Optional[dict] = None
        if self.ckpt is not None and cfg.resume:
            step = self.ckpt.restored_step(fallback=True)
            if step is not None:
                extra = self.ckpt.extra(step)
                if not extra or extra.get("engine") != engine:
                    raise ValueError(
                        f"{cfg.checkpoint_dir}: checkpoint belongs to "
                        f"engine {extra.get('engine') if extra else None!r}"
                        f", not {engine!r}")
                if extra.get("signature") != signature:
                    raise ValueError(
                        f"{cfg.checkpoint_dir}: checkpoint was written by "
                        "a different run (signature mismatch) — point "
                        "checkpoint_dir elsewhere or pass resume=False")
                self.resumed = dict(extra)
                self.resumed["ckpt_step"] = step

    # -- snapshot / restore -------------------------------------------------

    def should_snapshot(self, launches_done: int) -> bool:
        return (self.ckpt is not None
                and launches_done % max(self.cfg.every, 1) == 0)

    def snapshot(self, step: int, carry: Any, extra: dict) -> None:
        """Publish the carry + scheduler state for this boundary.  The
        device->host read happens here, synchronously, *before* the next
        launch donates the carry buffers (snapshot-before-donate)."""
        if self.ckpt is None:
            return
        self.ckpt.save(step, carry, blocking=self.cfg.blocking,
                       extra={"engine": self.engine,
                              "signature": self.signature, **extra})

    def restore_carry(self, like: Any, mesh: Mesh) -> Any:
        """Restore the resumed step's carry, re-sharded onto ``mesh``
        (every carry leaf is batch-sharded along the `"fleet"` axis)."""
        shardings = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P("fleet")), like)
        return self.ckpt.restore(like, step=self.resumed["ckpt_step"],
                                 shardings=shardings)

    # -- fault plane --------------------------------------------------------

    def launch(self, group: int, launch_idx: int, fn, *args):
        """Dispatch one launch through the fault plane: InjectedFault
        triggers bounded retry with exponential backoff.  Safe to retry
        with the live carry because the fault fires *before* dispatch —
        nothing has been donated yet."""
        attempt = 0
        while True:
            try:
                if self.fault is not None:
                    self.fault.on_launch(group, launch_idx)
                return fn(*args)
            except InjectedFault as e:
                attempt += 1
                self.n_retries += 1
                if attempt > self.cfg.max_retries:
                    raise FaultExhausted(
                        f"launch {launch_idx} (group {group}) failed "
                        f"{attempt} times: {e}") from e
                if self.cfg.backoff_s > 0:
                    time.sleep(self.cfg.backoff_s * 2 ** (attempt - 1))

    def maybe_preempt(self, launches_done: int) -> None:
        if self.fault is not None:
            self.fault.maybe_preempt(launches_done)

    def dead_hosts(self, launches_done: int) -> tuple:
        if self.fault is None:
            return ()
        return self.fault.dead_hosts(launches_done)


def maybe_resilient(cfg: "ResilienceConfig | None", engine: str,
                    **sig_params) -> Optional[ResilientRun]:
    """The engines' one-liner: None config -> None, else a ResilientRun
    keyed by `run_signature(engine, **sig_params)`."""
    if cfg is None:
        return None
    return ResilientRun(cfg, engine, run_signature(engine, **sig_params))


def metrics_restore(ms: list) -> list:
    """Finished per-job metrics out of the JSON payload.  Floats
    round-trip exactly (json emits repr-precision doubles); per-class
    list leaves (serving) come back as lists, matching the engine's own
    representation."""
    return [None if m is None else dict(m) for m in ms]
