"""Train / prefill / serve step builders with full sharding metadata.

`make_train_step` assembles loss -> (optional microbatch-accumulated) grads
-> (optional compressed-all-reduce) -> AdamW, threading the backpressure MoE
router queues H through the step (updated outside the gradient, like the
paper's H_n).  Every builder also returns the logical-axes trees for its
state so the launcher / dry-run can derive NamedShardings.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models import get_model, split_tree
from repro.runtime.flags import layer_scan
from repro.optim import (AdamW, AdamWState, EFState, compress_int8_ef,
                         compress_topk_ef, init_ef, init_ef_abstract,
                         warmup_cosine)


class TrainState(NamedTuple):
    step: jax.Array                  # [] int32
    params: Any
    opt: AdamWState
    router_H: Optional[jax.Array]    # [L, E] or None
    ef: Optional[EFState]            # error-feedback residuals or None


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def make_optimizer(total_steps: int = 10_000) -> AdamW:
    return AdamW(lr=warmup_cosine(3e-4, warmup=200, total=total_steps))


def init_train_state(rcfg: RunConfig, key=None, abstract: bool = False,
                     optimizer: AdamW | None = None):
    """Returns (state, state_axes) — concrete or ShapeDtypeStruct."""
    api = get_model(rcfg.model)
    opt = optimizer or make_optimizer()
    ann = api.init(key=key, dtype=_dtype(rcfg.param_dtype), abstract=abstract)
    params, p_axes = split_tree(ann)
    opt_state = opt.init_abstract(params) if abstract else opt.init(params)
    ms = api.init_state()
    H = ms.router_H
    if H is not None and abstract:
        H = jax.ShapeDtypeStruct(H.shape, H.dtype)
    ef = None
    if rcfg.grad_compression != "none":
        ef = init_ef_abstract(params) if abstract else init_ef(params)

    step0 = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
             else jnp.zeros((), jnp.int32))
    state = TrainState(step=step0, params=params, opt=opt_state,
                       router_H=H, ef=ef)

    axes = TrainState(
        step=(),
        params=p_axes,
        opt=AdamWState(count=(), m=p_axes, v=p_axes),
        router_H=(None, None) if H is not None else None,
        ef=EFState(err=p_axes) if ef is not None else None,
    )
    return state, axes


def make_train_step(rcfg: RunConfig, optimizer: AdamW | None = None):
    api = get_model(rcfg.model)
    opt = optimizer or make_optimizer()
    adt = _dtype(rcfg.activ_dtype)

    def loss_fn(params, batch, router_H):
        loss, (H, metrics) = api.loss(params, batch, activ_dtype=adt,
                                      remat=rcfg.remat, router_H=router_H)
        return loss, (H, metrics)

    def train_step(state: TrainState, batch):
        if rcfg.grad_accum > 1:
            # microbatch accumulation via scan (batch dim 0 splits evenly)
            def micro(carry, mb):
                g_acc, l_acc, H = carry
                (l, (H2, _)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb, H)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g_acc, g)
                return (g_acc, l_acc + l, H2), None

            mbs = jax.tree.map(
                lambda t: t.reshape((rcfg.grad_accum,
                                     t.shape[0] // rcfg.grad_accum)
                                    + t.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            # layer_scan: unrolled under the dry-run depth probes so the
            # microbatch loop is visible to HLO cost analysis
            (grads, loss, H), _ = layer_scan(
                micro, (g0, jnp.zeros((), jnp.float32), state.router_H), mbs)
            grads = jax.tree.map(lambda g: g / rcfg.grad_accum, grads)
            loss = loss / rcfg.grad_accum
            metrics = {"ce": loss}
        else:
            (loss, (H, metrics)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch, state.router_H)

        ef = state.ef
        if rcfg.grad_compression == "int8_ef":
            grads, ef = compress_int8_ef(grads, ef)
        elif rcfg.grad_compression == "topk_ef":
            grads, ef = compress_topk_ef(grads, ef)

        params, opt_state = opt.update(grads, state.opt, state.params)
        new = TrainState(step=state.step + 1, params=params, opt=opt_state,
                         router_H=H, ef=ef)
        out_metrics = {"loss": loss, **{k: v for k, v in metrics.items()}}
        return new, out_metrics

    return train_step


def make_prefill_step(rcfg: RunConfig):
    """Forward pass emitting last-position logits (inference prefill)."""
    api = get_model(rcfg.model)
    adt = _dtype(rcfg.activ_dtype)

    def prefill_step(params, batch, router_H):
        logits, _, _ = api.logits(params, batch, activ_dtype=adt,
                                  remat="none", router_H=router_H,
                                  last_only=True)
        return logits

    return prefill_step


def make_serve_step(rcfg: RunConfig):
    """One decode step: new token against the KV cache / recurrent state."""
    api = get_model(rcfg.model)
    adt = _dtype(rcfg.activ_dtype)

    def serve_step(params, caches, batch, router_H):
        logits, caches = api.decode_step(params, caches, batch,
                                         activ_dtype=adt, router_H=router_H)
        return logits, caches

    return serve_step
