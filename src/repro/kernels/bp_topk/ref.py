"""Pure-jnp oracle for fused backpressure top-k gating."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bp_topk_ref(scores, bias, k):
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    sel = probs - bias.astype(jnp.float32)[None, :]
    _, idx = jax.lax.top_k(sel, k)
    w = jnp.take_along_axis(probs, idx, axis=1)
    w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
    return idx.astype(jnp.int32), w
