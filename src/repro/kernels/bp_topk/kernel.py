"""Pallas TPU kernel for fused backpressure MoE gating: softmax over the
expert axis, subtract the H-queue bias (paper eq. 9), iterative top-k
selection, and renormalized combine weights — one VMEM pass per token tile.

Grid: (T // block_t,); block [block_t, E] score panels on the VPU, k static
(<= 8 in our archs), so the top-k is a k-step argmax/mask loop, unrolled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _bp_topk_kernel(s_ref, bias_ref, idx_ref, w_ref, *, k: int):
    s = s_ref[...].astype(jnp.float32)              # [bt, E]
    bias = bias_ref[...].astype(jnp.float32)        # [E]
    # row softmax
    m = s.max(axis=1, keepdims=True)
    e = jnp.exp(s - m)
    probs = e / e.sum(axis=1, keepdims=True)
    sel = probs - bias[None, :]

    wsum = jnp.zeros((s.shape[0],), jnp.float32)
    work = sel
    for j in range(k):                              # unrolled static top-k
        best = jnp.argmax(work, axis=1).astype(jnp.int32)
        pbest = jnp.take_along_axis(probs, best[:, None], axis=1)[:, 0]
        idx_ref[:, j] = best
        w_ref[:, j] = pbest
        wsum = wsum + pbest
        work = jnp.where(
            jax.nn.one_hot(best, s.shape[1], dtype=jnp.bool_), NEG, work)
    # renormalize combine weights over the selected experts
    wsum = jnp.maximum(wsum, 1e-9)
    w_ref[...] = w_ref[...] / wsum[:, None]


def bp_topk(scores: jax.Array, bias: jax.Array, k: int, *,
            block_t: int = 256, interpret: bool = True):
    """scores: [T, E] gate logits; bias: [E] (beta*H/C).  Returns
    (idx [T, k] i32, weights [T, k] f32, renormalized)."""
    T, E = scores.shape
    block_t = min(block_t, T)
    pad = (-T) % block_t
    if pad:
        scores = jnp.concatenate(
            [scores, jnp.zeros((pad, E), scores.dtype)], axis=0)
    Tp = scores.shape[0]

    idx, w = pl.pallas_call(
        functools.partial(_bp_topk_kernel, k=k),
        grid=(Tp // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, E), lambda i: (i, 0)),
            pl.BlockSpec((E,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, k), jnp.int32),
            jax.ShapeDtypeStruct((Tp, k), jnp.float32),
        ],
        interpret=interpret,
    )(scores, bias)
    return idx[:T], w[:T]
