"""Jit'd wrapper for fused backpressure gating."""
from __future__ import annotations

import functools

import jax

from .kernel import bp_topk
from .ref import bp_topk_ref


@functools.partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def bp_topk_op(scores, bias, k, *, block_t=256, interpret=True):
    return bp_topk(scores, bias, k, block_t=block_t, interpret=interpret)


__all__ = ["bp_topk_op", "bp_topk_ref"]
