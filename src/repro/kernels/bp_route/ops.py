"""Jit'd wrapper: gather endpoint backlogs and run the BP decision kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import bp_route_decide
from .ref import bp_route_ref


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def bp_route_op(Q: jax.Array, edges: jax.Array, cap: jax.Array, *,
                block_e: int = 256, interpret: bool = True):
    """Q: [N, C] per-node class backlogs; edges: [E, 2]; cap: [E]."""
    qm = Q[edges[:, 0]]
    ql = Q[edges[:, 1]]
    return bp_route_decide(qm, ql, cap, block_e=block_e, interpret=interpret)


__all__ = ["bp_route_op", "bp_route_ref", "bp_route_decide"]
