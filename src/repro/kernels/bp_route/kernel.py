"""Pallas TPU kernel for the backpressure routing decision (the paper's BP
box): for every link, scan all 3*N_C class backlogs at both endpoints, pick
the class with maximum |differential backlog| and emit (class, direction,
rate).

At fleet scale this is the control-plane hot loop: |E| links x C classes
every slot.  The kernel tiles links x classes into VMEM ([block_e, C]
endpoint-backlog panels), does the argmax reduction on the VPU in one pass,
and never re-reads HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bp_route_kernel(qm_ref, ql_ref, cap_ref, cls_ref, rate_ref, dir_ref):
    qm = qm_ref[...].astype(jnp.float32)       # [be, C]
    ql = ql_ref[...].astype(jnp.float32)
    cap = cap_ref[...].astype(jnp.float32)     # [be]
    diff = qm - ql
    adiff = jnp.abs(diff)
    best = jnp.argmax(adiff, axis=1).astype(jnp.int32)          # [be]
    dmax = jnp.take_along_axis(diff, best[:, None], axis=1)[:, 0]
    cls_ref[...] = best
    rate_ref[...] = jnp.where(jnp.abs(dmax) > 0, cap, 0.0)
    dir_ref[...] = jnp.where(dmax > 0, 1, -1).astype(jnp.int32)


def bp_route_decide(qm: jax.Array, ql: jax.Array, cap: jax.Array, *,
                    block_e: int = 256, interpret: bool = True):
    """qm/ql: [E, C] backlogs at the two endpoints of each link; cap: [E].

    Returns (best_class [E] i32, rate [E] f32, direction [E] i32 with +1 =
    m->l).  Links are padded to a block multiple.
    """
    E, C = qm.shape
    block_e = min(block_e, max(E, 1))
    pad = (-E) % block_e
    if pad:
        zf = lambda t: jnp.concatenate(
            [t, jnp.zeros((pad,) + t.shape[1:], t.dtype)], axis=0)
        qm, ql, cap = zf(qm), zf(ql), zf(cap)
    Ep = qm.shape[0]
    grid = (Ep // block_e,)

    cls, rate, dirn = pl.pallas_call(
        _bp_route_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, C), lambda i: (i, 0)),
            pl.BlockSpec((block_e, C), lambda i: (i, 0)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ep,), jnp.int32),
            jax.ShapeDtypeStruct((Ep,), jnp.float32),
            jax.ShapeDtypeStruct((Ep,), jnp.int32),
        ],
        interpret=interpret,
    )(qm, ql, cap)
    return cls[:E], rate[:E], dirn[:E]
