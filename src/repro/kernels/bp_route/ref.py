"""Pure-jnp oracle for the BP routing decision."""
from __future__ import annotations

import jax.numpy as jnp


def bp_route_ref(qm, ql, cap):
    # f32 math on f32-cast inputs — the kernel's numeric contract
    diff = qm.astype(jnp.float32) - ql.astype(jnp.float32)
    best = jnp.argmax(jnp.abs(diff), axis=1).astype(jnp.int32)
    dmax = jnp.take_along_axis(diff, best[:, None], axis=1)[:, 0]
    rate = jnp.where(jnp.abs(dmax) > 0, cap.astype(jnp.float32), 0.0)
    dirn = jnp.where(dmax > 0, 1, -1).astype(jnp.int32)
    return best, rate, dirn
