"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel subpackage has kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper) and ref.py (pure-jnp oracle).  On this CPU-only
container kernels run with interpret=True; on TPU pass interpret=False.
"""
