"""Fused Pallas kernels for the per-slot control decision (DESIGN.md §7).

Two kernels cover the paper's whole inner loop:

  * `slot_route_decide` — max-differential-backlog routing.  The grid is
    (edge blocks, class blocks); each step loads a [N, block_c] panel of
    the flattened per-node backlogs plus a [block_e] slab of edge
    endpoints, gathers the endpoint rows *in VMEM*, and folds the tile's
    best |Q_m - Q_l| into a running argmax held in the output refs — the
    [E, 3*NC] differential tensor of the XLA path is never materialized.

  * `comp_balance_decide` — the per-comp-node decision: available pairs,
    the (optionally thresholded) combine amount Z, and the masked
    join-shortest-sum-of-queues argmin, fused into one pass over NC tiles
    with a running argmin.  eps_B enters as a traced [1] operand (per-job
    data under vmap — an eps_B sweep shares one kernel).

Tie-break contract: later tiles only win on a *strictly* better value, and
the in-tile argmax/argmin take the first occurrence — so both kernels
resolve ties to the lowest flat index, exactly like `jnp.argmax`/`argmin`
(the `ref.py` oracle).  Running on CPU CI uses `interpret=True` (the same
code path, executed by the Pallas interpreter inside the jitted program);
on TPU pass `interpret=False`.  Accelerator tiling notes: DESIGN.md §7.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import balance_score, combine_amount, pair_count


def _route_kernel(q_ref, m_ref, l_ref, best_ref, dmax_ref, *, block_c: int):
    j = pl.program_id(1)
    q = q_ref[...]                                  # [N, block_c]
    qm = jnp.take(q, m_ref[...], axis=0)            # VMEM gather [be, bc]
    ql = jnp.take(q, l_ref[...], axis=0)
    diff = qm - ql
    loc = jnp.argmax(jnp.abs(diff), axis=1).astype(jnp.int32)
    dloc = jnp.take_along_axis(diff, loc[:, None], axis=1)[:, 0]
    glob = loc + j * block_c

    @pl.when(j == 0)
    def _init():
        best_ref[...] = glob
        dmax_ref[...] = dloc

    @pl.when(j > 0)
    def _fold():
        # strictly-better only: ties keep the earlier (lower) class index
        better = jnp.abs(dloc) > jnp.abs(dmax_ref[...])
        best_ref[...] = jnp.where(better, glob, best_ref[...])
        dmax_ref[...] = jnp.where(better, dloc, dmax_ref[...])


def slot_route_decide(Qf: jax.Array, m_idx: jax.Array, l_idx: jax.Array, *,
                      block_e: int = 128, block_c: int | None = None,
                      interpret: bool = True):
    """Qf: [N, C] flattened per-node class backlogs (C = 3*NC, i-major);
    m_idx/l_idx: [E] int32 endpoints.  Returns (best [E] i32 flat class
    index, dmax [E] signed differential) == `ref.slot_route_ref` bit-exact.

    Edges pad to a block multiple with (0, 0) self-loops (zero diff, never
    win); classes pad with zero columns (|0| never beats a real diff
    strictly, and an all-zero row correctly keeps flat index 0).
    """
    N, C = Qf.shape
    E = m_idx.shape[0]
    block_e = min(block_e, max(E, 1))
    block_c = C if block_c is None else min(block_c, C)

    pad_e = (-E) % block_e
    if pad_e:
        zi = jnp.zeros((pad_e,), m_idx.dtype)
        m_idx = jnp.concatenate([m_idx, zi])
        l_idx = jnp.concatenate([l_idx, zi])
    pad_c = (-C) % block_c
    if pad_c:
        Qf = jnp.concatenate(
            [Qf, jnp.zeros((N, pad_c), Qf.dtype)], axis=1)
    Ep, Cp = m_idx.shape[0], Qf.shape[1]
    grid = (Ep // block_e, Cp // block_c)

    best, dmax = pl.pallas_call(
        functools.partial(_route_kernel, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((block_e,), lambda i, j: (i,)),
            pl.BlockSpec((block_e,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_e,), lambda i, j: (i,)),
            pl.BlockSpec((block_e,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ep,), jnp.int32),
            jax.ShapeDtypeStruct((Ep,), Qf.dtype),
        ],
        interpret=interpret,
    )(Qf, m_idx, l_idx)
    return best[:E], dmax[:E]


def _comp_balance_kernel(eps_ref, q0_ref, q1_ref, q2_ref, h_ref, caps_ref,
                         mask_ref, x1_ref, x2_ref, ca1_ref, ca2_ref, cc_ref,
                         xnet_ref, z_ref, nstar_ref, smin_ref, *,
                         block_n: int, pairing: str, thresholded: bool,
                         threshold: float):
    i = pl.program_id(0)
    eps = eps_ref[0]
    mask = mask_ref[...]
    x1, x2 = x1_ref[...], x2_ref[...]
    capm = caps_ref[...] * mask
    P = pair_count(x1, x2, ca1_ref[...], ca2_ref[...], cc_ref[...],
                   xnet_ref[...], pairing)
    z_ref[...] = combine_amount(P, capm, x1 + x2, thresholded, threshold)
    score = balance_score(eps, q0_ref[...], q1_ref[...], q2_ref[...],
                          h_ref[...], mask)
    loc = jnp.argmin(score).astype(jnp.int32)
    sloc = score[loc]

    @pl.when(i == 0)
    def _init():
        nstar_ref[0] = loc + i * block_n
        smin_ref[0] = sloc

    @pl.when(i > 0)
    def _fold():
        better = sloc < smin_ref[0]                 # strict: first tile wins ties
        nstar_ref[0] = jnp.where(better, loc + i * block_n, nstar_ref[0])
        smin_ref[0] = jnp.where(better, sloc, smin_ref[0])


def comp_balance_decide(eps, q0, q1, q2, H, caps, mask, x1, x2, ca1, ca2,
                        cc, x_net, *, pairing: str = "fifo",
                        thresholded: bool = False, threshold: float = 0.0,
                        block_n: int = 128, interpret: bool = True):
    """Fused comp/balance decision over [NC] panels (ref.comp_balance_ref
    bit-exact): returns (Z [NC] f32, n_star [] i32).

    `eps` is a traced scalar (per-job data under vmap).  NC pads to a
    block multiple with mask-0 slots: their score is +inf (never win the
    strict-< fold) and their Z is 0 (sliced off anyway).
    """
    NC = q0.shape[0]
    block_n = min(block_n, max(NC, 1))
    pad = (-NC) % block_n
    panels = [q0, q1, q2, H, caps, mask, x1, x2, ca1, ca2, cc, x_net]
    if pad:
        panels = [jnp.concatenate([p, jnp.zeros((pad,), p.dtype)])
                  for p in panels]
    NCp = panels[0].shape[0]

    vec = pl.BlockSpec((block_n,), lambda i: (i,))
    one = pl.BlockSpec((1,), lambda i: (0,))
    Z, n_star, _ = pl.pallas_call(
        functools.partial(_comp_balance_kernel, block_n=block_n,
                          pairing=pairing, thresholded=thresholded,
                          threshold=threshold),
        grid=(NCp // block_n,),
        in_specs=[one] + [vec] * 12,
        out_specs=[vec, one, one],
        out_shape=[
            jax.ShapeDtypeStruct((NCp,), q0.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), q0.dtype),
        ],
        interpret=interpret,
    )(jnp.reshape(eps, (1,)), *panels)
    return Z[:NC], n_star[0]
