"""Wrappers for the fused slot-decision kernels (bp_slot).

`slot_route_decide` / `comp_balance_decide` (kernel.py) are plain traced
functions — `repro.core.policies` calls them inside the scan body when
`PolicyConfig.backend == "pallas"`.  `slot_route_op` is the standalone
jit'd entry used by benchmarks/tests: it takes the raw [N, 3, NC] queue
tensor plus edge list and emits the full per-edge decision tuple
(best_class, best_comp, direction, rate), mirroring `bp_route.ops`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import comp_balance_decide, slot_route_decide
from .ref import (balance_score, combine_amount, comp_balance_ref,
                  pair_count, slot_route_ref)


@functools.partial(jax.jit,
                   static_argnames=("block_e", "block_c", "interpret"))
def slot_route_op(Q: jax.Array, edges: jax.Array, cap: jax.Array, *,
                  block_e: int = 128, block_c: int | None = None,
                  interpret: bool = True):
    """Q: [N, 3, NC] per-node class backlogs; edges: [E, 2]; cap: [E].

    Returns (best_class [E] i32 in 0..2, best_comp [E] i32, direction [E]
    i32 with +1 = m->l, rate [E] f32) — the full routing decision of
    `repro.core.policies.bp_route_slot` without materializing [E, 3, NC].
    """
    NC = Q.shape[-1]
    Qf = Q.reshape(Q.shape[0], -1)
    best, dmax = slot_route_decide(Qf, edges[:, 0], edges[:, 1],
                                   block_e=block_e, block_c=block_c,
                                   interpret=interpret)
    rate = jnp.where(jnp.abs(dmax) > 0, cap.astype(Qf.dtype), 0.0)
    dirn = jnp.where(dmax > 0, 1, -1).astype(jnp.int32)
    return best // NC, best % NC, dirn, rate


def slot_route_op_ref(Q: jax.Array, edges: jax.Array, cap: jax.Array):
    """Pure-jnp oracle for `slot_route_op` (materializes [E, 3*NC])."""
    NC = Q.shape[-1]
    Qf = Q.reshape(Q.shape[0], -1)
    best, dmax = slot_route_ref(Qf, edges[:, 0], edges[:, 1])
    rate = jnp.where(jnp.abs(dmax) > 0, cap.astype(Qf.dtype), 0.0)
    dirn = jnp.where(dmax > 0, 1, -1).astype(jnp.int32)
    return best // NC, best % NC, dirn, rate


__all__ = [
    "slot_route_decide", "comp_balance_decide", "slot_route_op",
    "slot_route_op_ref", "slot_route_ref", "comp_balance_ref",
    "pair_count", "combine_amount", "balance_score",
]
