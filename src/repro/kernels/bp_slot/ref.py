"""Pure-jnp oracle for the fused per-slot decision (bp_slot kernel family).

This module IS the XLA backend: `repro.core.policies` calls these functions
on the `backend="xla"` path, and the Pallas kernels in `kernel.py` reuse the
small algebra helpers (`pair_count`, `combine_amount`, `balance_score`)
inside their kernel bodies — parity between the two backends is therefore
*by construction*: identical f32 expressions evaluated on identical panels,
so `slot_step(backend="pallas", interpret=True)` is bit-identical to
`backend="xla"` (asserted by tests/test_bp_slot.py).

Tie-break contract (DESIGN.md §7): both the routing argmax and the
load-balance argmin resolve ties to the *lowest flat index*, exactly like
`jnp.argmax`/`jnp.argmin` — the tiled kernels preserve this by only
accepting a strictly better candidate from a later tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Shared algebra (used verbatim inside the Pallas kernel bodies)
# ---------------------------------------------------------------------------

def pair_count(x1, x2, ca1, ca2, cc, x_net, pairing: str):
    """P_n(t): combinable same-tag pairs at each comp node (paper eq. (7) /
    FIFO counting — DESIGN.md §1), from per-node panels.

    x1/x2: X[:, 0] / X[:, 1]; ca1/ca2: cum_arr[:, 0] / cum_arr[:, 1];
    cc: cum_comb; x_net: raw packets in flight (bound pairing only, may be
    None for fifo).
    """
    if pairing == "fifo":
        P = jnp.minimum(ca1, ca2) - cc
    elif pairing == "bound":
        P = (x1 + x2 - x_net) / 2.0
    else:
        raise ValueError(f"unknown pairing model {pairing!r}")
    # Physical caps: cannot exceed either side's backlog, never negative.
    return jnp.clip(P, 0.0, jnp.minimum(x1, x2))


def combine_amount(P, caps, xsum, thresholded: bool, threshold: float):
    """Z_n(t): pairs actually combined — capped by capacity, optionally
    gated by the pi1' proof-device threshold X̄ (Lemma 1)."""
    if thresholded:
        gate = xsum >= 2.0 * caps + threshold
        return jnp.minimum(jnp.where(gate, caps, 0.0), P)
    return jnp.minimum(P, caps)


def balance_score(eps, q0, q1, q2, H, mask):
    """Join-shortest-sum-of-queues score (paper eq. (9)), +inf on masked
    (padded / failed) comp nodes so they never win the argmin."""
    score = (1.0 + eps) * q0 + q1 + q2 + H
    if mask is None:
        return score
    return jnp.where(mask > 0, score, jnp.inf)


# ---------------------------------------------------------------------------
# Full-decision oracles (the parity reference for the Pallas kernels)
# ---------------------------------------------------------------------------

def slot_route_ref(Qf: jax.Array, m_idx: jax.Array, l_idx: jax.Array):
    """BP routing decision over the flattened class axis.

    Qf: [N, 3*NC] per-node backlogs with classes flattened in (i, n) order
    (i major — `Q.reshape(N, -1)`); m_idx/l_idx: [E] endpoint indices.
    Returns (best [E] i32 flat class index, dmax [E] signed differential).
    Materializes the full [E, 3*NC] differential — the tensor the Pallas
    kernel streams in tiles instead.
    """
    diff = Qf[m_idx] - Qf[l_idx]
    best = jnp.argmax(jnp.abs(diff), axis=1).astype(jnp.int32)
    dmax = jnp.take_along_axis(diff, best[:, None], axis=1)[:, 0]
    return best, dmax


def comp_balance_ref(eps, q0, q1, q2, H, caps, mask, x1, x2, ca1, ca2, cc,
                     x_net, *, pairing: str, thresholded: bool,
                     threshold: float):
    """Fused per-comp-node decision: combinable pairs -> combine amount Z,
    plus the masked load-balance argmin n_star, from one set of panels.

    All inputs are [NC] panels except the scalar `eps`.  Returns
    (Z [NC] f32, n_star [] i32).
    """
    capm = caps * mask
    P = pair_count(x1, x2, ca1, ca2, cc, x_net, pairing)
    Z = combine_amount(P, capm, x1 + x2, thresholded, threshold)
    score = balance_score(eps, q0, q1, q2, H, mask)
    return Z, jnp.argmin(score).astype(jnp.int32)
