"""Pallas TPU flash attention (blockwise online softmax) with causal +
sliding-window masks and GQA head mapping.

Grid: (B*H, n_q_blocks, n_kv_blocks) with the kv dimension innermost
("arbitrary" semantics) revisiting the output block; running max / sum /
accumulator live in VMEM scratch.  Block shapes are MXU-aligned
(block_q x head_dim and block_k x head_dim tiles).

VMEM working set per step: q (bq x D) + k,v (bk x D each) + acc (bq x D)
+ stats (2 x bq) — for bq=bk=128, D=128 in bf16/f32 well under the ~16 MB
v5e VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, n_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_idx = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_idx = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # [bq]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)                       # guard exp(NEG_INF-m)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B, H, S, D]; k/v: [B, KH, T, D] (H % KH == 0).  Returns [B,H,S,D].

    interpret=True runs the kernel body on CPU (this container); on real TPU
    pass interpret=False.
    """
    B, H, S, D = q.shape
    KH, T = k.shape[1], k.shape[2]
    assert H % KH == 0
    G = H // KH
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    n_q, n_kv = S // block_q, T // block_k
    scale = 1.0 / np.sqrt(D)

    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * KH, T, D)
    vf = v.reshape(B * KH, T, D)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        b = bh // H
        h = bh % H
        return (b * KH + h // G, ik, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
