"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=None):
    """q [B,H,S,D], k/v [B,KH,T,D] -> [B,H,S,D] (f32 math)."""
    B, H, S, D = q.shape
    KH, T = k.shape[1], k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(D)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key -> zeros (matches kernel's l>=eps guard)
    any_valid = mask.any(axis=1)[None, None, :, None]
    out = jnp.einsum("bhst,bhtd->bhsd", p, vv.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)
