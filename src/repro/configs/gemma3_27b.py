"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144,
    window=1024, local_global=5,          # 5 local : 1 global
    rope_theta=1_000_000.0, tie_embeddings=True, logit_softcap=30.0,
)
