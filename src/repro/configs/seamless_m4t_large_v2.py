"""seamless-m4t-large-v2 [audio] — enc-dec backbone; the audio frontend is a
STUB (input_specs provides precomputed frame embeddings). "24L" = 24 encoder
+ 24 decoder layers (following the released checkpoint; see DESIGN.md §5).
[arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206,
    enc_layers=24, dec_layers=24, tie_embeddings=True,
)
