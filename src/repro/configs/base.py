"""Config system: model architectures and input shapes.

`ModelConfig` fully describes an architecture; `ShapeConfig` describes one
(seq_len, global_batch, kind) input-shape cell; `RunConfig` couples them with
distribution choices (the hillclimb knobs live here).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention details
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    window: Optional[int] = None   # sliding-window size for local layers
    local_global: int = 0          # k => pattern (k local : 1 global); 0 = all global
    norm: str = "rmsnorm"          # rmsnorm | layernorm_nonparam
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    router: str = "backpressure"   # backpressure | aux | plain
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0            # hybrid: shared attn block every k ssm layers
    # xLSTM
    slstm_every: int = 0           # 1 sLSTM per k blocks (rest mLSTM)
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # VLM
    n_patches: int = 0

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context without quadratic attention?"""
        return self.family in ("ssm", "hybrid")

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Distribution + performance knobs (hillclimb surface)."""
    model: ModelConfig
    shape: ShapeConfig
    multi_pod: bool = False
    # sharding strategy
    fsdp: bool = True              # shard params/opt over 'data' (else pure DP)
    seq_shard_decode: bool = True  # shard KV cache / state over 'data' at decode
    kv_seq_tp: str = "off"         # off | auto: cache seq over 'model' when
                                   # kv_heads don't divide the model axis
    expert_parallel: bool = True   # shard experts over 'model' (else replicate)
    # memory / remat
    remat: str = "full"            # full | dots | none
    scan_layers: bool = True
    attn_impl: str = "naive"       # naive (materialized) | chunked (online-softmax)
    ctx_par: bool = False          # context-parallel attention (q-seq over model)
    # numerics
    activ_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # optimizer
    grad_accum: int = 1
    grad_compression: str = "none" # none | int8_ef | topk_ef


def reduced(model: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    scale = dict(
        n_layers=min(model.n_layers, 2 if model.local_global == 0 else model.local_global + 1),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(model.n_kv_heads, 2) if model.n_kv_heads < model.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab=256,
    )
    if model.local_global:
        scale["n_layers"] = model.local_global + 1
        scale["window"] = 8
    if model.n_experts:
        scale["n_experts"] = 8
        scale["top_k"] = min(model.top_k, 2)
        scale["d_ff"] = 32
        scale["capacity_factor"] = 4.0   # effectively dropless at test sizes
    if model.family in ("ssm", "hybrid"):
        scale["ssm_state"] = 16
        scale["ssm_head_dim"] = 16
        scale["ssm_chunk"] = 16
    if model.attn_every:
        scale["attn_every"] = 2
        scale["n_layers"] = 4
    if model.slstm_every:
        scale["slstm_every"] = 2
        scale["n_layers"] = 4
    if model.enc_layers:
        scale["enc_layers"] = 2
        scale["dec_layers"] = 2
    if model.n_patches:
        scale["n_patches"] = 8
    scale.update(overrides)
    return dataclasses.replace(model, **scale)
