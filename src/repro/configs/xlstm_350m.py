"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (1 sLSTM per 6 blocks), d_ff=0
(blocks carry their own projections). [arXiv:2405.04517; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab=50304,
    slstm_every=6, ssm_head_dim=256,
)
