"""Config registry: `get_config(arch_id)` and ARCHS listing."""
from .base import ModelConfig, RunConfig, ShapeConfig, SHAPES, reduced

from . import (gemma3_27b, olmo_1b, qwen15_32b, qwen2_05b,
               moonshot_v1_16b_a3b, granite_moe_1b_a400m,
               seamless_m4t_large_v2, zamba2_2p7b, internvl2_1b, xlstm_350m)

_MODULES = (gemma3_27b, olmo_1b, qwen15_32b, qwen2_05b, moonshot_v1_16b_a3b,
            granite_moe_1b_a400m, seamless_m4t_large_v2, zamba2_2p7b,
            internvl2_1b, xlstm_350m)

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k only for sub-quadratic
    archs unless include_skipped (DESIGN.md §5)."""
    out = []
    for name, mc in ARCHS.items():
        for sname, sc in SHAPES.items():
            if sname == "long_500k" and not (mc.is_subquadratic or include_skipped):
                continue
            out.append((name, sname))
    return out


__all__ = ["ModelConfig", "RunConfig", "ShapeConfig", "SHAPES", "ARCHS",
           "get_config", "reduced", "cells"]
