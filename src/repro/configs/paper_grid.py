"""The paper's own experiment instance (§V): 4x4 grid, R=5, 4 computation
nodes; C in {2, 3}."""
from repro.core.graph import paper_grid_problem

def problem(C: float = 2.0):
    return paper_grid_problem(C=C)
