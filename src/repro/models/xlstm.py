"""xLSTM blocks: mLSTM (matrix memory, parallel quadratic train form,
O(1) recurrent decode) and sLSTM (scalar memory with exponential gating and
block-diagonal recurrence; sequential scan).

Pattern: one sLSTM per `slstm_every` blocks (rest mLSTM); nested scan like
the gemma local:global pattern.  d_ff = 0 in the arch spec — blocks carry
their own up/down projections (mLSTM PF=2, sLSTM post-MLP PF=4/3).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.flags import layer_scan
import numpy as np

from .common import Init, init_norm, norm


def _dims(cfg):
    d = cfg.d_model
    d_in = 2 * d                       # mLSTM projection factor 2
    nh = cfg.n_heads
    hd = d_in // nh
    return d, d_in, nh, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: jax.Array      # [B, nh, hd, hd] matrix memory
    n: jax.Array      # [B, nh, hd] normalizer
    m: jax.Array      # [B, nh] stabilizer


def init_mlstm(cfg, ini: Init) -> dict:
    d, d_in, nh, hd = _dims(cfg)
    return {
        "ln": init_norm(cfg, ini, d),
        "wup": ini.param((d, 2 * d_in), ("embed", "dinner")),
        "wq": ini.param((d_in, nh, hd), ("dinner", "ssm_heads", None)),
        "wk": ini.param((d_in, nh, hd), ("dinner", "ssm_heads", None)),
        "wv": ini.param((d_in, nh, hd), ("dinner", "ssm_heads", None)),
        "wi": ini.param((d_in, nh), ("dinner", "ssm_heads"), scale=0.02),
        "bi": ini.param((nh,), ("ssm_heads",), kind="zeros"),
        "wf": ini.param((d_in, nh), ("dinner", "ssm_heads"), scale=0.02),
        "bf": ini.param((nh,), ("ssm_heads",), kind="ones"),
        "gamma": ini.param((d_in,), ("dinner",), kind="zeros"),
        "wdown": ini.param((d_in, d), ("dinner", "embed")),
    }


def _mlstm_project(p, xin):
    dt = xin.dtype
    q = jnp.einsum("bsd,dhk->bshk", xin, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xin, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xin, p["wv"].astype(dt))
    i = jnp.einsum("bsd,dh->bsh", xin, p["wi"].astype(dt)).astype(jnp.float32) \
        + p["bi"].astype(jnp.float32)
    f = jnp.einsum("bsd,dh->bsh", xin, p["wf"].astype(dt)).astype(jnp.float32) \
        + p["bf"].astype(jnp.float32)
    return q, k, v, i, f


def _headnorm(y, gamma, B, S, d_in):
    """Per-head RMS norm then channel scale (xLSTM group norm)."""
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    yf = yf.reshape(B, S, d_in)
    return yf * (1.0 + gamma.astype(jnp.float32))


def mlstm_fwd(cfg, p: dict, x: jax.Array) -> jax.Array:
    """Parallel (train/prefill) form; x [B, S, d].

    Under context parallelism (runtime.flags.ctx_par) the query/time dim of
    the quadratic decay matrix is sharded over the model axis — with only 4
    heads the head dim cannot use it, and the [B,t,s,nh] tensors would
    otherwise be replicated 16x."""
    from repro.runtime import flags as _flags
    from repro.runtime.sharding import constrain
    B, S, d = x.shape
    _, d_in, nh, hd = _dims(cfg)
    h = norm(cfg, x, p["ln"])
    up = jnp.einsum("bsd,de->bse", h, p["wup"].astype(h.dtype))
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, i, f = _mlstm_project(p, xin)
    if _flags.ctx_par():
        q = constrain(q, ("act_batch", "act_seq_ctx", None, None))

    logsig_f = -jax.nn.softplus(-f)                       # log sigmoid(f)
    Fc = jnp.cumsum(logsig_f, axis=1)                     # [B,S,nh]
    Fc_t = constrain(Fc, ("act_batch", "act_seq_ctx", None)) \
        if _flags.ctx_par() else Fc
    D = Fc_t[:, :, None, :] - Fc[:, None, :, :] + i[:, None, :, :]
    tri = jnp.tril(jnp.ones((S, S), bool))[None, :, :, None]
    D = jnp.where(tri, D, -jnp.inf)                       # [B,t,s,nh]
    m = jnp.max(D, axis=2)                                # [B,t,nh]
    w = jnp.exp(D - m[:, :, None, :])
    scores = jnp.einsum("bthk,bshk->btsh", q, k) / np.sqrt(hd)
    scores = scores.astype(jnp.float32) * w
    denom = jnp.maximum(jnp.abs(scores.sum(axis=2)), jnp.exp(-m))   # [B,t,nh]
    y = jnp.einsum("btsh,bshk->bthk", scores.astype(v.dtype), v)
    y = y / denom[..., None].astype(v.dtype)
    if _flags.ctx_par():
        y = constrain(y, ("act_batch", "act_seq_ctx", None, None))
    y = _headnorm(y, p["gamma"], B, S, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return x + jnp.einsum("bse,ed->bsd", y, p["wdown"].astype(x.dtype))


def init_mlstm_state(cfg, batch, dtype, abstract=False) -> MLSTMState:
    _, d_in, nh, hd = _dims(cfg)
    shapes = ((batch, nh, hd, hd), (batch, nh, hd), (batch, nh))
    if abstract:
        return MLSTMState(*[jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes])
    return MLSTMState(*[jnp.zeros(s, jnp.float32) for s in shapes])


def mlstm_decode(cfg, p: dict, x: jax.Array,
                 st: MLSTMState) -> Tuple[jax.Array, MLSTMState]:
    B = x.shape[0]
    _, d_in, nh, hd = _dims(cfg)
    h = norm(cfg, x, p["ln"])
    up = jnp.einsum("bsd,de->bse", h, p["wup"].astype(h.dtype))
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, i, f = _mlstm_project(p, xin)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # [B,nh,hd]
    i, f = i[:, 0], f[:, 0]                                     # [B,nh]

    logsig_f = -jax.nn.softplus(-f)
    m_new = jnp.maximum(logsig_f + st.m, i)
    a = jnp.exp(logsig_f + st.m - m_new)[:, :, None]
    b = jnp.exp(i - m_new)[:, :, None]
    C = st.C * a[..., None] + b[..., None] * k[..., :, None] * v[..., None, :]
    n = st.n * a + b * k
    qs = q / np.sqrt(hd)
    num = jnp.einsum("bhk,bhkv->bhv", qs, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).astype(x.dtype)[:, None]         # [B,1,nh,hd]? -> reshape
    y = _headnorm(y.reshape(B, 1, nh, hd), p["gamma"], B, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", y, p["wdown"].astype(x.dtype))
    return out, MLSTMState(C=C, n=n, m=m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array      # [B, d]
    n: jax.Array      # [B, d]
    hprev: jax.Array  # [B, d]
    m: jax.Array      # [B, d]


def init_slstm(cfg, ini: Init) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ffs = int(np.ceil(4 * d / 3 / 128) * 128)
    p = {"ln": init_norm(cfg, ini, d),
         "ln_mlp": init_norm(cfg, ini, d),
         "up": ini.param((d, ffs), ("embed", "ff")),
         "down": ini.param((ffs, d), ("ff", "embed"))}
    for g in ("i", "f", "z", "o"):
        p[f"w{g}"] = ini.param((d, d), ("embed", None), scale=0.02)
        p[f"r{g}"] = ini.param((nh, hd, hd), ("ssm_heads", None, None),
                               scale=0.02)
        p[f"b{g}"] = ini.param((d,), (None,),
                               kind="ones" if g == "f" else "zeros")
    return p


def _slstm_cell(cfg, p, xt, st: SLSTMState) -> Tuple[jax.Array, SLSTMState]:
    """One timestep; xt [B, d] f32."""
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    B = xt.shape[0]
    hp = st.hprev.reshape(B, nh, hd)

    def gate(g):
        rec = jnp.einsum("bhk,hkl->bhl", hp, p[f"r{g}"].astype(jnp.float32))
        return (xt @ p[f"w{g}"].astype(jnp.float32) + rec.reshape(B, d)
                + p[f"b{g}"].astype(jnp.float32))

    i, f, z, o = gate("i"), gate("f"), gate("z"), gate("o")
    logsig_f = -jax.nn.softplus(-f)
    m_new = jnp.maximum(logsig_f + st.m, i)
    fi = jnp.exp(logsig_f + st.m - m_new)
    ii = jnp.exp(i - m_new)
    c = fi * st.c + ii * jnp.tanh(z)
    n = fi * st.n + ii
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1e-6)
    return h, SLSTMState(c=c, n=n, hprev=h, m=m_new)


def slstm_fwd(cfg, p: dict, x: jax.Array) -> jax.Array:
    """Sequential over time; x [B, S, d]."""
    B, S, d = x.shape
    h0 = norm(cfg, x, p["ln"]).astype(jnp.float32)

    def step(st, xt):
        h, st = _slstm_cell(cfg, p, xt, st)
        return st, h

    st = init_slstm_state(cfg, B, x.dtype)
    _, hs = jax.lax.scan(step, st, h0.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    x = x + y
    # post-MLP (PF 4/3)
    h = norm(cfg, x, p["ln_mlp"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["up"].astype(x.dtype)))
    return x + jnp.einsum("bsf,fd->bsd", h, p["down"].astype(x.dtype))


def init_slstm_state(cfg, batch, dtype, abstract=False) -> SLSTMState:
    d = cfg.d_model
    if abstract:
        return SLSTMState(*[jax.ShapeDtypeStruct((batch, d), jnp.float32)
                            for _ in range(4)])
    return SLSTMState(*[jnp.zeros((batch, d), jnp.float32) for _ in range(4)])


def slstm_decode(cfg, p, x, st: SLSTMState):
    B = x.shape[0]
    h0 = norm(cfg, x, p["ln"]).astype(jnp.float32)[:, 0]
    h, st = _slstm_cell(cfg, p, h0, st)
    x = x + h.astype(x.dtype)[:, None]
    hh = norm(cfg, x, p["ln_mlp"])
    hh = jax.nn.gelu(jnp.einsum("bsd,df->bsf", hh, p["up"].astype(x.dtype)))
    return x + jnp.einsum("bsf,fd->bsd", hh, p["down"].astype(x.dtype)), st


# ---------------------------------------------------------------------------
# Stack + LM wrappers
# ---------------------------------------------------------------------------

def _groups(cfg):
    k = cfg.slstm_every
    n_groups = cfg.n_layers // k
    assert n_groups * k == cfg.n_layers
    return n_groups, k - 1          # per group: (k-1) mLSTM + 1 sLSTM


def init_lm(cfg, key=None, dtype=jnp.float32, abstract=False) -> dict:
    from .common import init_embedding
    ini = Init(key=key, dtype=dtype, abstract=abstract)
    n_groups, km = _groups(cfg)
    return {
        "embed": init_embedding(cfg, ini),
        "stack": {"mlstm": init_mlstm(cfg, ini.stacked(n_groups, km)),
                  "slstm": init_slstm(cfg, ini.stacked(n_groups))},
        "ln_f": init_norm(cfg, ini, cfg.d_model),
    }


def stack_fwd(cfg, p, x, *, remat="full"):
    m_fwd = functools.partial(mlstm_fwd, cfg)
    s_fwd = functools.partial(slstm_fwd, cfg)
    if remat != "none":
        m_fwd = jax.checkpoint(m_fwd)
        s_fwd = jax.checkpoint(s_fwd)

    def group(x, xs):
        lp_m, lp_s = xs

        def inner(x, lp):
            return m_fwd(lp, x), None

        x, _ = layer_scan(inner, x, lp_m)
        return s_fwd(lp_s, x), None

    x, _ = layer_scan(group, x, (p["mlstm"], p["slstm"]))
    return x


def lm_loss(cfg, params, batch, *, activ_dtype=jnp.bfloat16, remat="full",
            router_H=None):
    from .common import cross_entropy, embed, unembed
    tokens = batch["tokens"]
    x = embed(cfg, params["embed"], tokens[:, :-1], activ_dtype)
    x = stack_fwd(cfg, params["stack"], x, remat=remat)
    x = norm(cfg, x, params["ln_f"])
    logits = unembed(cfg, params["embed"], x)
    ce = cross_entropy(logits, tokens[:, 1:])
    return ce, (router_H, {"ce": ce})


def lm_logits(cfg, params, tokens, *, activ_dtype=jnp.bfloat16, remat="full",
              router_H=None, prefix_embeds=None, last_only=False):
    from .common import embed, unembed
    x = embed(cfg, params["embed"], tokens, activ_dtype)
    x = stack_fwd(cfg, params["stack"], x, remat=remat)
    x = norm(cfg, x, params["ln_f"])
    if last_only:
        x = x[:, -1:]
    return unembed(cfg, params["embed"], x), router_H, jnp.zeros((), jnp.float32)


class XLSTMCache(NamedTuple):
    mlstm: MLSTMState      # stacked [n_groups, km]
    slstm: SLSTMState      # stacked [n_groups]


def init_decode_caches(cfg, batch, max_len, dtype, abstract=False):
    n_groups, km = _groups(cfg)

    def expand(prefix, tree):
        def one(a):
            if abstract:
                return jax.ShapeDtypeStruct(prefix + a.shape, a.dtype)
            return jnp.broadcast_to(a[(None,) * len(prefix)], prefix + a.shape)
        return jax.tree_util.tree_map(one, tree)

    return XLSTMCache(
        mlstm=expand((n_groups, km), init_mlstm_state(cfg, batch, dtype,
                                                      abstract=abstract)),
        slstm=expand((n_groups,), init_slstm_state(cfg, batch, dtype,
                                                   abstract=abstract)),
    )


def cache_axes(tree: XLSTMCache):
    def m_ax(s: MLSTMState):
        pre = ("layers",) * (s.C.ndim - 4)
        return MLSTMState(C=pre + ("cache_batch", "ssm_heads", None, None),
                          n=pre + ("cache_batch", "ssm_heads", None),
                          m=pre + ("cache_batch", "ssm_heads"))

    def s_ax(s: SLSTMState):
        pre = ("layers",) * (s.c.ndim - 2)
        a = pre + ("cache_batch", "act_embed")
        return SLSTMState(c=a, n=a, hprev=a, m=a)

    return XLSTMCache(
        mlstm=jax.tree_util.tree_map(
            m_ax, tree.mlstm, is_leaf=lambda x: isinstance(x, MLSTMState)),
        slstm=jax.tree_util.tree_map(
            s_ax, tree.slstm, is_leaf=lambda x: isinstance(x, SLSTMState)),
    )


def lm_decode_step(cfg, params, caches: XLSTMCache, tokens, *,
                   activ_dtype=jnp.bfloat16, router_H=None):
    from .common import embed, unembed
    x = embed(cfg, params["embed"], tokens[:, None], activ_dtype)

    def group(x, xs):
        lp_m, lp_s, st_m, st_s = xs

        def inner(x, xs2):
            lp, st = xs2
            x, st = mlstm_decode(cfg, lp, x, st)
            return x, st

        x, st_m = layer_scan(inner, x, (lp_m, st_m))
        x, st_s = slstm_decode(cfg, lp_s, x, st_s)
        return x, (st_m, st_s)

    x, (m_new, s_new) = layer_scan(
        group, x, (params["stack"]["mlstm"], params["stack"]["slstm"],
                   caches.mlstm, caches.slstm))
    x = norm(cfg, x, params["ln_f"])
    logits = unembed(cfg, params["embed"], x)[:, 0, :]
    return logits, XLSTMCache(mlstm=m_new, slstm=s_new)
