"""Decoder-only transformer stack (dense + MoE + local/global patterns).

Layer params are *stacked* ([L, ...] leading dims) and the stack runs under
`lax.scan`, so HLO size is O(1) in depth and per-layer remat composes with
XLA's latency-hiding scheduler.  Gemma-style k-local:1-global patterns use a
nested scan over [groups, k] stacks plus an unrolled tail.

Router virtual queues (backpressure MoE, core/router.py) are threaded
through the scan as per-layer state: H [L, E].
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.runtime.flags import layer_scan

from repro.runtime.sharding import constrain
from .attention import (KVCache, attention, decode_attention, init_attn,
                        init_cache)
from .common import (Init, cross_entropy, embed, init_embedding, init_mlp,
                     init_norm, norm, swiglu, unembed)
from .moe import init_moe, moe_ffn


class ModelState(NamedTuple):
    """Non-parameter model state: per-MoE-layer router queues H."""
    router_H: Optional[jax.Array]    # [L_moe, E] or None


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)        # "full": save only layer inputs


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def init_block(cfg, ini: Init, *, moe: bool) -> dict:
    p = {
        "ln1": init_norm(cfg, ini, cfg.d_model),
        "attn": init_attn(cfg, ini),
        "ln2": init_norm(cfg, ini, cfg.d_model),
    }
    if moe:
        p["moe"] = init_moe(cfg, ini)
    else:
        p["mlp"] = init_mlp(cfg, ini)
    p = {k: v for k, v in p.items() if v is not None}
    return p


def block_fwd(cfg, p: dict, x, positions, *, window, router_H=None,
              causal: bool = True):
    """x: [B, S, d] -> (x', router_H')."""
    h = norm(cfg, x, p.get("ln1"))
    h = attention(cfg, p["attn"], h, positions, window=window, causal=causal)
    x = x + h
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    h = norm(cfg, x, p.get("ln2"))
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        from repro.core.router import RouterState
        rs = RouterState(H=router_H, steps=jnp.zeros((), jnp.int32))
        h, rs_new, aux = moe_ffn(
            cfg, p["moe"], h, rs,
            ep_in=lambda t: constrain(
                t, ("act_group", "act_experts") + (None,) * (t.ndim - 2)),
            ep_out=lambda t: constrain(
                t, ("act_group",) + (None,) * (t.ndim - 1)))
        router_H = rs_new.H
    else:
        h = swiglu(h, p["mlp"]["gate"], p["mlp"]["up"], p["mlp"]["down"])
    x = x + h
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    return x, router_H, aux


def block_decode(cfg, p: dict, x, cache: KVCache, *, window, router_H=None):
    h = norm(cfg, x, p.get("ln1"))
    h, cache = decode_attention(cfg, p["attn"], h, cache, window=window)
    x = x + h
    h = norm(cfg, x, p.get("ln2"))
    if "moe" in p:
        from repro.core.router import RouterState
        rs = RouterState(H=router_H, steps=jnp.zeros((), jnp.int32))
        h, rs_new, _ = moe_ffn(cfg, p["moe"], h, rs, group_size=x.shape[0],
                               dropless=True)
        router_H = rs_new.H
    else:
        h = swiglu(h, p["mlp"]["gate"], p["mlp"]["up"], p["mlp"]["down"])
    return x + h, cache, router_H


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _pattern(cfg):
    """(n_groups, k_local, tail) for the k-local:1-global pattern."""
    if not cfg.local_global:
        return 0, 0, 0
    k = cfg.local_global
    n_groups = cfg.n_layers // (k + 1)
    tail = cfg.n_layers - n_groups * (k + 1)
    return n_groups, k, tail


def init_stack(cfg, ini: Init) -> dict:
    moe = cfg.family == "moe"
    if cfg.local_global:
        n_groups, k, tail = _pattern(cfg)
        p = {
            "local": init_block(cfg, ini.stacked(n_groups, k), moe=moe),
            "global": init_block(cfg, ini.stacked(n_groups), moe=moe),
        }
        if tail:
            p["tail"] = init_block(cfg, ini.stacked(tail), moe=moe)
        return p
    return {"layers": init_block(cfg, ini.stacked(cfg.n_layers), moe=moe)}


def init_model_state(cfg) -> ModelState:
    if cfg.family == "moe":
        return ModelState(router_H=jnp.zeros((cfg.n_layers, cfg.n_experts),
                                             jnp.float32))
    return ModelState(router_H=None)


def stack_fwd(cfg, p: dict, x, positions, *, remat: str = "full",
              scan_layers: bool = True, router_H=None):
    """Run all blocks; returns (x, router_H', aux_total)."""

    def scan_blocks(x, stacked, window, H_stack):
        body = _remat(
            functools.partial(block_fwd, cfg, window=window), remat)

        def f(carry, xs):
            x, aux = carry
            lp, H = xs
            x, H_new, a = body(lp, x, positions, router_H=H)
            return (x, aux + a), H_new

        (x, aux), H_out = layer_scan(f, (x, jnp.zeros((), jnp.float32)),
                                     (stacked, H_stack))
        return x, H_out, aux

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.local_global:
        n_groups, k, tail = _pattern(cfg)
        H = None  # dense archs only use this pattern here

        def group(x, xs):
            lp_local, lp_global = xs
            x, _, _ = scan_blocks(x, lp_local, cfg.window, None)
            body = _remat(functools.partial(block_fwd, cfg, window=None), remat)
            x, _, _ = body(lp_global, x, positions, router_H=None)
            return x, None

        x, _ = layer_scan(group, x, (p["local"], p["global"]))
        if "tail" in p:
            x, _, _ = scan_blocks(x, p["tail"], cfg.window, None)
        return x, router_H, aux_total

    if cfg.family == "moe":
        x, H_out, aux_total = scan_blocks(x, p["layers"], cfg.window, router_H)
        return x, H_out, aux_total
    x, _, aux_total = scan_blocks(x, p["layers"], cfg.window, None)
    return x, router_H, aux_total


# ---------------------------------------------------------------------------
# LM wrapper: init / loss / decode
# ---------------------------------------------------------------------------

def init_lm(cfg, key=None, dtype=jnp.float32, abstract: bool = False) -> dict:
    ini = Init(key=key, dtype=dtype, abstract=abstract)
    return {
        "embed": init_embedding(cfg, ini),
        "stack": init_stack(cfg, ini),
        "ln_f": init_norm(cfg, ini, cfg.d_model),
    }


def lm_logits(cfg, params, tokens, *, activ_dtype=jnp.bfloat16,
              remat="full", router_H=None, prefix_embeds=None,
              last_only=False):
    """tokens: [B, S] -> (logits [B, S(+P), V], router_H').  last_only=True
    unembeds only the final position (serving prefill)."""
    x = embed(cfg, params["embed"], tokens, activ_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(activ_dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    x, H_out, aux = stack_fwd(cfg, params["stack"], x, positions,
                              remat=remat, router_H=router_H)
    x = norm(cfg, x, params["ln_f"] if "ln_f" in params else None)
    if last_only:
        x = x[:, -1:]
    logits = unembed(cfg, params["embed"], x)
    return logits, H_out, aux


def lm_loss(cfg, params, batch, *, activ_dtype=jnp.bfloat16, remat="full",
            router_H=None):
    """batch: {tokens [B, S]} -> (scalar loss, (router_H', metrics))."""
    tokens = batch["tokens"]
    logits, H_out, aux = lm_logits(cfg, params, tokens[:, :-1],
                                   activ_dtype=activ_dtype, remat=remat,
                                   router_H=router_H)
    ce = cross_entropy(logits, tokens[:, 1:],
                       batch.get("mask", None))
    return ce + aux, (H_out, {"ce": ce, "aux": aux})


# ---- decode -----------------------------------------------------------------

def init_decode_caches(cfg, batch: int, max_len: int, dtype,
                       abstract: bool = False):
    """Stacked caches mirroring the stack structure."""
    mk = functools.partial(init_cache, cfg, batch, max_len, dtype,
                           abstract=abstract)

    def stacked(prefix, window=None):
        c = mk(window=window)
        def expand(a):
            if abstract:
                return jax.ShapeDtypeStruct(prefix + a.shape, a.dtype)
            return jnp.broadcast_to(a[(None,) * len(prefix)], prefix + a.shape)
        return jax.tree_util.tree_map(expand, c)

    if cfg.local_global:
        n_groups, k, tail = _pattern(cfg)
        caches = {
            "local": stacked((n_groups, k), window=cfg.window),
            "global": stacked((n_groups,)),
        }
        if tail:
            caches["tail"] = stacked((tail,), window=cfg.window)
        return caches
    return {"layers": stacked((cfg.n_layers,), window=cfg.window)}


def cache_axes(tree):
    """Logical axes for a (possibly stacked) cache tree."""
    def one(c: KVCache):
        pre = ("layers",) * (c.k.ndim - 4)
        kv = pre + ("cache_batch", "cache_seq", "act_kv_heads", None)
        return KVCache(k=kv, v=kv, kpos=pre + ("cache_seq",), pos=pre)
    return jax.tree_util.tree_map(one, tree,
                                  is_leaf=lambda x: isinstance(x, KVCache))


def lm_decode_step(cfg, params, caches, tokens, *, activ_dtype=jnp.bfloat16,
                   router_H=None, prefix_embeds=None):
    """tokens: [B] int32 -> (logits [B, V], new caches)."""
    x = embed(cfg, params["embed"], tokens[:, None], activ_dtype)
    stack = params["stack"]

    def scan_dec(x, stacked, caches, window, H_stack=None):
        if H_stack is None:
            def f(x, xs):
                lp, c = xs
                x, c, _ = block_decode(cfg, lp, x, c, window=window)
                return x, c
            return layer_scan(f, x, (stacked, caches))

        def f(x, xs):
            lp, c, H = xs
            x, c, _ = block_decode(cfg, lp, x, c, window=window, router_H=H)
            return x, c
        return layer_scan(f, x, (stacked, caches, H_stack))

    if cfg.local_global:
        def group(x, xs):
            lp_l, lp_g, c_l, c_g = xs
            x, c_l = scan_dec(x, lp_l, c_l, cfg.window)
            x, c_g, _ = block_decode(cfg, lp_g, x, c_g, window=None)
            return x, (c_l, c_g)
        x, (c_local, c_global) = layer_scan(
            group, x, (stack["local"], stack["global"],
                       caches["local"], caches["global"]))
        new_caches = {"local": c_local, "global": c_global}
        if "tail" in stack:
            x, c_tail = scan_dec(x, stack["tail"], caches["tail"], cfg.window)
            new_caches["tail"] = c_tail
    elif cfg.family == "moe":
        x, new_layers = scan_dec(x, stack["layers"], caches["layers"],
                                 cfg.window, H_stack=router_H)
        new_caches = {"layers": new_layers}
    else:
        x, new_layers = scan_dec(x, stack["layers"], caches["layers"],
                                 cfg.window)
        new_caches = {"layers": new_layers}

    x = norm(cfg, x, params.get("ln_f"))
    logits = unembed(cfg, params["embed"], x)[:, 0, :]
    return logits, new_caches
