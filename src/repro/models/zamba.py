"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every `attn_every` layers (shared weights, separate KV caches per
application).  54 = 9 groups x 6 mamba layers here.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.runtime.flags import layer_scan

from .attention import init_cache, KVCache
from .common import Init, init_norm, norm
from .mamba import (MambaState, init_mamba, init_mamba_state, mamba_decode,
                    mamba_fwd, mamba_state_axes)
from . import transformer as tfm


def _groups(cfg):
    k = cfg.attn_every
    n_groups = cfg.n_layers // k
    assert n_groups * k == cfg.n_layers, (cfg.n_layers, k)
    return n_groups, k


def init_stack(cfg, ini: Init) -> dict:
    n_groups, k = _groups(cfg)
    return {
        "mamba": {"m": init_mamba(cfg, ini.stacked(n_groups, k)),
                  "ln": init_norm(cfg, ini.stacked(n_groups, k), cfg.d_model)},
        "shared": tfm.init_block(cfg, ini, moe=False),   # one shared attn block
    }


def init_lm(cfg, key=None, dtype=jnp.float32, abstract: bool = False) -> dict:
    from .common import init_embedding
    ini = Init(key=key, dtype=dtype, abstract=abstract)
    return {
        "embed": init_embedding(cfg, ini),
        "stack": init_stack(cfg, ini),
        "ln_f": init_norm(cfg, ini, cfg.d_model),
    }


def _mamba_layer(cfg, lp, x, remat):
    def body(lp, x):
        h = norm(cfg, x, lp["ln"])
        return x + mamba_fwd(cfg, lp["m"], h)
    if remat != "none":
        body = jax.checkpoint(body)
    return body(lp, x)


def stack_fwd(cfg, p, x, positions, *, remat="full"):
    n_groups, k = _groups(cfg)
    shared = p["shared"]

    def group(x, lp_group):
        def inner(x, lp):
            return _mamba_layer(cfg, lp, x, remat), None
        x, _ = layer_scan(inner, x, lp_group)
        x, _, _ = tfm.block_fwd(cfg, shared, x, positions, window=None)
        return x, None

    x, _ = layer_scan(group, x, p["mamba"])
    return x


def lm_loss(cfg, params, batch, *, activ_dtype=jnp.bfloat16, remat="full",
            router_H=None):
    from .common import cross_entropy, embed, unembed
    tokens = batch["tokens"]
    x = embed(cfg, params["embed"], tokens[:, :-1], activ_dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = stack_fwd(cfg, params["stack"], x, positions, remat=remat)
    x = norm(cfg, x, params["ln_f"])
    logits = unembed(cfg, params["embed"], x)
    ce = cross_entropy(logits, tokens[:, 1:])
    return ce, (router_H, {"ce": ce})


def lm_logits(cfg, params, tokens, *, activ_dtype=jnp.bfloat16, remat="full",
              router_H=None, prefix_embeds=None, last_only=False):
    from .common import embed, unembed
    x = embed(cfg, params["embed"], tokens, activ_dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = stack_fwd(cfg, params["stack"], x, positions, remat=remat)
    x = norm(cfg, x, params["ln_f"])
    if last_only:
        x = x[:, -1:]
    return unembed(cfg, params["embed"], x), router_H, jnp.zeros((), jnp.float32)


class ZambaCache(NamedTuple):
    ssm: MambaState          # stacked [n_groups, k]
    attn: KVCache            # stacked [n_groups]


def init_decode_caches(cfg, batch, max_len, dtype, abstract=False):
    n_groups, k = _groups(cfg)

    def expand(prefix, tree):
        def one(a):
            if abstract:
                return jax.ShapeDtypeStruct(prefix + a.shape, a.dtype)
            return jnp.broadcast_to(a[(None,) * len(prefix)], prefix + a.shape)
        return jax.tree_util.tree_map(one, tree)

    return ZambaCache(
        ssm=expand((n_groups, k), init_mamba_state(cfg, batch, dtype,
                                                   abstract=abstract)),
        attn=expand((n_groups,), init_cache(cfg, batch, max_len, dtype,
                                            abstract=abstract)),
    )


def cache_axes(tree: ZambaCache):
    return ZambaCache(ssm=mamba_state_axes(tree.ssm),
                      attn=tfm.cache_axes(tree.attn))


def lm_decode_step(cfg, params, caches: ZambaCache, tokens, *,
                   activ_dtype=jnp.bfloat16, router_H=None):
    from .common import embed, unembed
    x = embed(cfg, params["embed"], tokens[:, None], activ_dtype)
    shared = params["stack"]["shared"]

    def group(x, xs):
        lp_group, ssm_group, attn_cache = xs

        def inner(x, xs2):
            lp, st = xs2
            h = norm(cfg, x, lp["ln"])
            h, st = mamba_decode(cfg, lp["m"], h, st)
            return x + h, st

        x, ssm_group = layer_scan(inner, x, (lp_group, ssm_group))
        x, attn_cache, _ = tfm.block_decode(cfg, shared, x, attn_cache,
                                            window=None)
        return x, (ssm_group, attn_cache)

    x, (ssm_new, attn_new) = layer_scan(
        group, x, (params["stack"]["mamba"], caches.ssm, caches.attn))
    x = norm(cfg, x, params["ln_f"])
    logits = unembed(cfg, params["embed"], x)[:, 0, :]
    return logits, ZambaCache(ssm=ssm_new, attn=attn_new)
