"""Uniform model API across families — the single entry point used by the
launcher, dry-run, serving engine and tests.

  api = get_model(cfg)
  params~ = api.init(key, dtype, abstract)          # Annotated tree
  loss, (H', metrics) = api.loss(params, batch, ...)
  logits, ... = api.logits(params, batch, ...)      # prefill forward
  caches = api.init_decode(batch, max_len, dtype, abstract)
  logits, caches = api.decode_step(params, caches, batch, ...)
  specs, axes = api.batch_specs(shape)              # ShapeDtypeStruct inputs
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec, transformer, vlm, xlstm, zamba


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    mod: Any

    # ---- params / state
    def init(self, key=None, dtype=jnp.float32, abstract: bool = False):
        return self.mod.init_lm(self.cfg, key=key, dtype=dtype,
                                abstract=abstract)

    def init_state(self):
        if self.cfg.family == "moe":
            return transformer.init_model_state(self.cfg)
        return transformer.ModelState(router_H=None)

    # ---- training loss
    def loss(self, params, batch, *, activ_dtype=jnp.bfloat16, remat="full",
             router_H=None):
        return self.mod.lm_loss(self.cfg, params, batch,
                                activ_dtype=activ_dtype, remat=remat,
                                router_H=router_H)

    # ---- prefill forward
    def logits(self, params, batch, *, activ_dtype=jnp.bfloat16,
               remat="none", router_H=None, last_only=False):
        if self.cfg.family in ("encdec", "vlm"):
            return self.mod.lm_logits(self.cfg, params, batch,
                                      activ_dtype=activ_dtype, remat=remat,
                                      router_H=router_H, last_only=last_only)
        return self.mod.lm_logits(self.cfg, params, batch["tokens"],
                                  activ_dtype=activ_dtype, remat=remat,
                                  router_H=router_H, last_only=last_only)

    # ---- decode
    def init_decode(self, batch: int, max_len: int, dtype,
                    abstract: bool = False):
        return self.mod.init_decode_caches(self.cfg, batch, max_len, dtype,
                                           abstract=abstract)

    def cache_axes(self, tree):
        return self.mod.cache_axes(tree)

    def decode_step(self, params, caches, batch, *,
                    activ_dtype=jnp.bfloat16, router_H=None):
        return self.mod.lm_decode_step(self.cfg, params, caches,
                                       batch["tokens"],
                                       activ_dtype=activ_dtype,
                                       router_H=router_H)

    # ---- abstract input specs (dry-run; ShapeDtypeStruct, no allocation)
    def batch_specs(self, shape: ShapeConfig, activ_dtype=jnp.bfloat16):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
        emb = lambda *sh: jax.ShapeDtypeStruct(sh, activ_dtype)
        if shape.kind == "decode":
            specs = {"tokens": tok(B)}
            axes = {"tokens": ("act_batch",)}
            if cfg.family == "encdec":
                pass   # cross memory lives in the cache
            return specs, axes
        if cfg.family == "encdec":
            specs = {"frames": emb(B, S, cfg.d_model), "tokens": tok(B, S)}
            axes = {"frames": ("act_batch", "act_seq", "act_embed"),
                    "tokens": ("act_batch", "act_seq")}
        elif cfg.family == "vlm":
            s_text = S - cfg.n_patches
            specs = {"patch_embeds": emb(B, cfg.n_patches, cfg.d_model),
                     "tokens": tok(B, s_text)}
            axes = {"patch_embeds": ("act_batch", None, "act_embed"),
                    "tokens": ("act_batch", "act_seq")}
        else:
            specs = {"tokens": tok(B, S)}
            axes = {"tokens": ("act_batch", "act_seq")}
        return specs, axes


_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "hybrid": zamba,
    "ssm": xlstm,
    "encdec": encdec,
    "vlm": vlm,
}


def get_model(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(cfg=cfg, mod=_FAMILY[cfg.family])
