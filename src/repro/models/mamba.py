"""Mamba2 (SSD) block — TPU-idiomatic chunked scan.

The GPU reference implementation leans on fused CUDA scans; here the paper's
(Mamba2) recurrence is restructured for the MXU: a sequential `lax.scan`
over chunks whose per-chunk work is dense matmuls (intra-chunk lower-
triangular attention-like products and inter-chunk state updates), exactly
the SSD block-decomposition.  Decode is the O(1) state recurrence.

Shapes: d_in = expand*d_model inner channels, nh = d_in/hd heads (state
shared across head dims like Mamba2's multi-value form), ns = ssm_state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import Init


class MambaState(NamedTuple):
    S: jax.Array        # [B, nh, hd, ns] state matrices
    conv: jax.Array     # [B, kw-1, conv_dim] causal-conv tail buffer


KW = 4  # depthwise conv width


def dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_state, cfg.ssm_head_dim


def init_mamba(cfg, ini: Init) -> dict:
    d = cfg.d_model
    d_in, nh, ns, hd = dims(cfg)
    conv_dim = d_in + 2 * ns
    return {
        "wz": ini.param((d, d_in), ("embed", "dinner")),
        "wx": ini.param((d, d_in), ("embed", "dinner")),
        "wB": ini.param((d, ns), ("embed", "state")),
        "wC": ini.param((d, ns), ("embed", "state")),
        "wdt": ini.param((d, nh), ("embed", "ssm_heads")),
        "dt_bias": ini.param((nh,), ("ssm_heads",), kind="zeros"),
        "A_log": ini.param((nh,), ("ssm_heads",), kind="zeros"),
        "Dskip": ini.param((nh,), ("ssm_heads",), kind="ones"),
        "conv_w": ini.param((KW, conv_dim), ("conv", "dinner"), scale=0.5),
        "conv_b": ini.param((conv_dim,), ("dinner",), kind="zeros"),
        "gamma": ini.param((d_in,), ("dinner",), kind="zeros"),
        "wo": ini.param((d_in, d), ("dinner", "embed")),
    }


def _project(cfg, p, u):
    dt_ = u.dtype
    z = jnp.einsum("bsd,de->bse", u, p["wz"].astype(dt_))
    x = jnp.einsum("bsd,de->bse", u, p["wx"].astype(dt_))
    Bm = jnp.einsum("bsd,dn->bsn", u, p["wB"].astype(dt_))
    Cm = jnp.einsum("bsd,dn->bsn", u, p["wC"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", u, p["wdt"].astype(dt_))
    return z, x, Bm, Cm, dt


def _gated_out(cfg, p, y, z, B, S, d_in):
    dt_ = z.dtype                 # residual/activation dtype (y may be f32)
    y = y.reshape(B, S, d_in).astype(dt_) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    y = (yf * (1.0 + p["gamma"].astype(jnp.float32))).astype(dt_)
    return jnp.einsum("bse,ed->bsd", y, p["wo"].astype(dt_))


def mamba_fwd(cfg, p: dict, u: jax.Array) -> jax.Array:
    """Train/prefill: u [B, S, d] -> [B, S, d] via chunked SSD scan."""
    B, S0, d = u.shape
    pad = (-S0) % min(cfg.ssm_chunk, S0)
    if pad:
        u = jnp.concatenate(
            [u, jnp.zeros((B, pad, d), u.dtype)], axis=1)
    S = u.shape[1]
    d_in, nh, ns, hd = dims(cfg)
    Lc = min(cfg.ssm_chunk, S)
    nC = S // Lc

    z, x, Bm, Cm, dt = _project(cfg, p, u)

    # causal depthwise conv over (x, B, C)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    zpad = jnp.zeros((B, KW - 1, xbc.shape[-1]), xbc.dtype)
    xp = jnp.concatenate([zpad, xbc], axis=1)
    w = p["conv_w"].astype(xbc.dtype)
    conv = sum(xp[:, i:i + S] * w[i][None, None, :] for i in range(KW))
    xbc = jax.nn.silu(conv + p["conv_b"].astype(xbc.dtype))
    x, Bm, Cm = jnp.split(xbc, [d_in, d_in + ns], axis=-1)

    x = x.reshape(B, S, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # [B,S,nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # [nh] (<0)
    loga = dt * A[None, None, :]                                # log decay
    xbar = x * dt.astype(x.dtype)[..., None]                    # dt-scaled input

    # chunk views
    def chunk(t):
        return t.reshape(B, nC, Lc, *t.shape[2:])
    xc, Bc, Cc, lc = map(chunk, (xbar, Bm, Cm, loga))
    cum = jnp.cumsum(lc, axis=2)                                # [B,nC,Lc,nh]

    # intra-chunk (lower-triangular "attention" with decay weights)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)              # [B,nC,Lc,Lc]
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # [B,nC,Lc,Lc,nh]
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    wgt = jnp.where(tri[None, None, :, :, None], jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bcls,bclsh,bcshp->bclhp", scores, wgt, xc)

    # inter-chunk state carry: S' = e^{sum l} S + sum_s e^{cum_L - cum_s} xbar_s B_s
    # Linear recurrence -> associative parallel prefix (TPU-idiomatic: log-depth
    # instead of a sequential while loop, and fully visible to HLO cost analysis).
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                      # [B,nC,Lc,nh]
    chunk_in = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, seg,
                          xc).astype(jnp.float32)               # [B,nC,nh,hd,ns]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # [B,nC,nh]

    def combine(a, b):
        (da, sa), (db, sb) = a, b
        return da * db, sb + sa * db[..., None, None]

    dec_all, S_all = jax.lax.associative_scan(
        combine, (chunk_decay, chunk_in), axis=1)
    # S_all[c] = state AFTER chunk c; state entering chunk c is S_all[c-1]
    S_in = jnp.concatenate(
        [jnp.zeros((B, 1, nh, hd, ns), jnp.float32), S_all[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         Cc, jnp.exp(cum).astype(Cc.dtype), S_in.astype(Cc.dtype))
    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    y = y + x * p["Dskip"].astype(x.dtype)[None, None, :, None]
    out = _gated_out(cfg, p, y, z[:, :S], B, S, d_in)
    return out[:, :S0] if pad else out


def init_mamba_state(cfg, batch: int, dtype, abstract: bool = False) -> MambaState:
    d_in, nh, ns, hd = dims(cfg)
    conv_dim = d_in + 2 * ns
    s_shape = (batch, nh, hd, ns)
    c_shape = (batch, KW - 1, conv_dim)
    if abstract:
        return MambaState(jax.ShapeDtypeStruct(s_shape, jnp.float32),
                          jax.ShapeDtypeStruct(c_shape, dtype))
    return MambaState(jnp.zeros(s_shape, jnp.float32), jnp.zeros(c_shape, dtype))


def mamba_state_axes(tree):
    def one(s: MambaState):
        pre = ("layers",) * (s.S.ndim - 4)
        return MambaState(S=pre + ("cache_batch", "ssm_heads", None, None),
                          conv=pre + ("cache_batch", None, "act_dinner"))
    return jax.tree_util.tree_map(one, tree,
                                  is_leaf=lambda x: isinstance(x, MambaState))


def mamba_decode(cfg, p: dict, u: jax.Array,
                 state: MambaState) -> Tuple[jax.Array, MambaState]:
    """u: [B, 1, d]; O(1) state update."""
    B = u.shape[0]
    d_in, nh, ns, hd = dims(cfg)
    z, x, Bm, Cm, dt = _project(cfg, p, u)

    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)                 # [B,1,conv_dim]
    hist = jnp.concatenate([state.conv, xbc], axis=1)           # [B,KW,conv_dim]
    w = p["conv_w"].astype(xbc.dtype)
    conv = jnp.einsum("bkc,kc->bc", hist, w)[:, None, :]
    xbc_c = jax.nn.silu(conv + p["conv_b"].astype(xbc.dtype))
    x, Bm, Cm = jnp.split(xbc_c, [d_in, d_in + ns], axis=-1)

    x = x.reshape(B, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]   # [B,nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, :])                                # [B,nh]
    xbar = x.astype(jnp.float32) * dt[..., None]

    S1 = state.S * a[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xbar, Bm[:, 0].astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), S1)
    y = y.astype(u.dtype) + x * p["Dskip"].astype(x.dtype)[None, :, None]
    out = _gated_out(cfg, p, y[:, None], z, B, 1, d_in)
    return out, MambaState(S=S1, conv=hist[:, 1:])
