"""GQA attention with RoPE, sliding windows, KV caches and a flash-decode
style sharded-KV path.

The einsum implementation here is the *reference* path (used on CPU and as
the oracle).  On TPU the Pallas `flash_attention` kernel (kernels/) replaces
the quadratic materialization for train/prefill; the dry-run lowers the
reference path, whose HLO cost model upper-bounds the kernel's.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import Init, apply_rope


class KVCache(NamedTuple):
    """Ring-buffer KV cache with explicit absolute positions.

    For full-attention layers T_cache = max_len; for sliding-window layers
    T_cache = window (the ring wraps), which keeps long-context decode memory
    proportional to the window — `kpos` records each slot's absolute position
    so masking is uniform across both cases.
    """
    k: jax.Array      # [B, T_cache, KH, D]
    v: jax.Array      # [B, T_cache, KH, D]
    kpos: jax.Array   # [T_cache] int32 absolute positions (-1 = empty)
    pos: jax.Array    # [] int32 — next absolute position to write


def init_attn(cfg, ini: Init, *, kv_heads: int | None = None) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    KH = kv_heads or cfg.n_kv_heads
    Dh = cfg.head_dim
    p = {
        "wq": ini.param((d, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ini.param((d, KH, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ini.param((d, KH, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ini.param((H, Dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ini.param((H, Dh), ("heads", "head_dim"), kind="zeros")
        p["bk"] = ini.param((KH, Dh), ("kv_heads", "head_dim"), kind="zeros")
        p["bv"] = ini.param((KH, Dh), ("kv_heads", "head_dim"), kind="zeros")
    return p


def _project_qkv(cfg, p, x, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """[..., S, T] boolean validity mask from absolute positions."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        m &= q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def sdpa(q, k, v, mask) -> jax.Array:
    """q [B,S,H,D], k/v [B,T,KH,D], mask [B,S,T] -> [B,S,H,D]."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qh = q.reshape(B, S, KH, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qh, k) / np.sqrt(D)
    scores = jnp.where(mask[:, None, None, :, :], scores.astype(jnp.float32),
                       -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, D)


def _pad_to(x, n, axis, value=0):
    pad = (-x.shape[axis]) % n
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def sdpa_chunked(q, k, v, q_pos, k_pos, *, causal: bool,
                 window: Optional[int], chunk_q: int = 2048,
                 chunk_k: int = 2048) -> jax.Array:
    """Online-softmax (flash) attention in pure XLA: nested `layer_scan`s
    over query and key chunks so no [S, T] score tensor ever materializes —
    peak activation memory drops from O(S*T) to O(chunk_q*chunk_k) per
    head.  This is the XLA counterpart of kernels/flash_attention (the
    Pallas kernel is the TPU fast path; this path is lowerable everywhere
    and is what the dry-run measures).

    q [B,S,H,D]; k/v [B,T,KH,D]; q_pos [B,S]; k_pos [B,T] (-1 = invalid).
    """
    from repro.runtime.flags import layer_scan
    B, S, H, D = q.shape
    KH, T = k.shape[2], k.shape[1]
    G = H // KH
    cq, ck = min(chunk_q, S), min(chunk_k, T)
    qp = _pad_to(q, cq, 1)
    qpos = _pad_to(q_pos, cq, 1, value=-(10 ** 9))
    kp = _pad_to(k, ck, 1)
    vp = _pad_to(v, ck, 1)
    kpos = _pad_to(k_pos, ck, 1, value=-1)
    Sq, Tk = qp.shape[1], kp.shape[1]
    nq, nk = Sq // cq, Tk // ck

    qh = qp.reshape(B, nq, cq, KH, G, D).transpose(1, 0, 2, 3, 4, 5)
    qpos_c = qpos.reshape(B, nq, cq).transpose(1, 0, 2)
    kh = kp.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    vh = vp.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    kpos_c = kpos.reshape(B, nk, ck).transpose(1, 0, 2)
    scale = 1.0 / np.sqrt(D)

    def q_block(_, xs):
        qc, qpc = xs                               # [B,cq,KH,G,D], [B,cq]

        def kv_block(carry, xs2):
            m, l, acc = carry
            kc, vc, kpc = xs2                      # [B,ck,KH,D], [B,ck]
            s = jnp.einsum("bskgd,btkd->bkgst", qc, kc) * scale
            s = s.astype(jnp.float32)
            valid = (kpc[:, None, :] >= 0) & \
                (qpc[:, :, None] >= 0)             # [B,cq,ck]
            if causal:
                valid &= qpc[:, :, None] >= kpc[:, None, :]
            if window is not None:
                valid &= kpc[:, None, :] > qpc[:, :, None] - window
            s = jnp.where(valid[:, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(valid[:, None, None], p, 0.0)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(vc.dtype), vc)
            return (m_new, l_new, acc_new.astype(acc.dtype)), None

        m0 = jnp.full((B, KH, G, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KH, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, cq, D), jnp.float32)
        (m, l, acc), _ = layer_scan(kv_block, (m0, l0, a0),
                                    (kh, vh, kpos_c))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)           # [B,KH,G,cq,D]

    _, outs = layer_scan(q_block, None, (qh, qpos_c))
    # outs: [nq, B, KH, G, cq, D] -> [B, Sq, H, D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, KH * G, D)
    return out[:, :S]




def sdpa_banded(q, k, v, q_pos, k_pos, *, window: int) -> jax.Array:
    """Sliding-window attention in O(S*window): each query block attends to
    exactly (previous block + own block) of keys, with block size = window.
    Scan-free (fully visible to HLO cost analysis) and sharding-friendly
    (the block dim is the sequence dim).  Causality + the window mask are
    enforced via absolute positions.

    q [B,S,H,D]; k/v [B,T,KH,D] with S == T (self-attention only).
    """
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    cb = window
    qp = _pad_to(q, cb, 1)
    kp = _pad_to(k, cb, 1)
    vp = _pad_to(v, cb, 1)
    qpos = _pad_to(q_pos, cb, 1, value=-(10 ** 9))
    kpos = _pad_to(k_pos, cb, 1, value=-1)
    Sp = qp.shape[1]
    nb = Sp // cb

    qb = qp.reshape(B, nb, cb, KH, G, D)
    qpb = qpos.reshape(B, nb, cb)

    def banded(t, fill=0):  # [B, Sp, ...] -> [B, nb, 2cb, ...]
        tb = t.reshape(B, nb, cb, *t.shape[2:])
        prev = jnp.concatenate(
            [jnp.full_like(tb[:, :1], fill), tb[:, :-1]], axis=1)
        return jnp.concatenate([prev, tb], axis=2)

    kb = banded(kp)
    vb = banded(vp)
    # block 0's shifted-in band must carry INVALID positions, not pos 0
    kpb = banded(jnp.where(kpos < 0, -(10 ** 9), kpos)[..., None],
                 fill=-(10 ** 9))[..., 0]

    s = jnp.einsum("bnskgd,bntkd->bkgnst", qb, kb) / np.sqrt(D)
    valid = (kpb[:, :, None, :] >= 0) & (qpb[:, :, :, None] >= 0)
    valid &= qpb[:, :, :, None] >= kpb[:, :, None, :]          # causal
    valid &= kpb[:, :, None, :] > qpb[:, :, :, None] - window  # window
    # s: [B,KH,G,nb,cq,ckb]; valid: [B,nb,cq,ckb] -> broadcast over KH,G
    s = jnp.where(valid[:, None, None], s.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(s, axis=-1)
    any_valid = valid.any(axis=-1)                              # [B,nb,cb]
    w = jnp.where(any_valid[:, None, None, :, :, None], w, 0.0)
    out = jnp.einsum("bkgnst,bntkd->bnskgd", w.astype(vb.dtype), vb)
    out = out.reshape(B, Sp, KH * G, D)
    return out[:, :S]


def attention(cfg, p: dict, x: jax.Array, positions: jax.Array, *,
              window: Optional[int] = None, causal: bool = True) -> jax.Array:
    """Full (train/prefill) self-attention; impl chosen by
    runtime.flags.attention_impl (naive materialized vs chunked
    online-softmax)."""
    from repro.runtime import flags
    from repro.runtime.sharding import constrain
    q, k, v = _project_qkv(cfg, p, x, positions)
    pos = positions if positions.ndim == 2 else positions[None, :]
    pos = jnp.broadcast_to(pos, x.shape[:2])
    if flags.ctx_par():
        # context parallelism: q-sequence sharded over the model axis for
        # the O(S*T) part; K/V replicated (gathered) on that axis.
        q = constrain(q, ("act_batch", "act_seq_ctx", None, None))
        k = constrain(k, ("act_batch", None, None, None))
        v = constrain(v, ("act_batch", None, None, None))
    if flags.attn_impl() == "chunked" and window is not None and causal:
        # sliding-window layers: banded O(S*window) form (scan-free)
        out = sdpa_banded(q, k, v, pos, pos, window=window)
    elif flags.attn_impl() == "chunked":
        # under context parallelism the q-seq dim is sharded over 'model';
        # a scan over q chunks would destroy that sharding, so chunk only
        # the KV axis (q = one block, locally full).
        cq = 10 ** 9 if flags.ctx_par() else 2048
        out = sdpa_chunked(q, k, v, pos, pos, causal=causal, window=window,
                           chunk_q=cq)
    else:
        m = _mask(pos, pos, causal=causal, window=window)
        out = sdpa(q, k, v, m)
    if flags.ctx_par():
        out = constrain(out, ("act_batch", "act_seq_ctx", None, None))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def cross_attention(cfg, p: dict, x: jax.Array, memory_kv, mem_mask=None):
    """Decoder cross-attention against precomputed encoder K/V."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    k, v = memory_kv
    B, S = x.shape[:2]
    T = k.shape[1]
    m = jnp.ones((B, S, T), bool) if mem_mask is None else mem_mask
    out = sdpa(q, k, v, m)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def encode_kv(cfg, p: dict, mem: jax.Array):
    dt = mem.dtype
    k = jnp.einsum("btd,dhk->bthk", mem, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", mem, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v


# ---------------------------------------------------------------------------
# Decode path (one token against a cache)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype,
               kv_heads: int | None = None, window: Optional[int] = None,
               abstract: bool = False) -> KVCache:
    KH = kv_heads or cfg.n_kv_heads
    T_cache = min(window, max_len) if window else max_len
    shape = (batch, T_cache, KH, cfg.head_dim)
    if abstract:
        return KVCache(jax.ShapeDtypeStruct(shape, dtype),
                       jax.ShapeDtypeStruct(shape, dtype),
                       jax.ShapeDtypeStruct((T_cache,), jnp.int32),
                       jax.ShapeDtypeStruct((), jnp.int32))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.full((T_cache,), -1, jnp.int32),
                   jnp.zeros((), jnp.int32))


def prefill_cache(cache: KVCache, k: jax.Array, v: jax.Array) -> KVCache:
    """Load a full prefix (no wrap) into a fresh cache; k/v: [B, S, KH, D]."""
    S = k.shape[1]
    kc = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, 0, 0, 0))
    kpos = jax.lax.dynamic_update_slice(cache.kpos, jnp.arange(S, dtype=jnp.int32),
                                        (0,))
    return KVCache(kc, vc, kpos, jnp.asarray(S, jnp.int32))


def decode_attention(cfg, p: dict, x: jax.Array, cache: KVCache, *,
                     window: Optional[int] = None):
    """x: [B, 1, d]; writes at pos % T_cache, attends over valid slots."""
    B = x.shape[0]
    T_cache = cache.k.shape[1]
    positions = jnp.broadcast_to(cache.pos[None, None], (B, 1))
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    wslot = cache.pos % T_cache
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, wslot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, wslot, 0, 0))
    kpos = jax.lax.dynamic_update_slice(
        cache.kpos, cache.pos[None].astype(jnp.int32), (wslot,))
    valid = (kpos >= 0) & (kpos <= cache.pos)
    if window is not None:
        valid &= kpos > cache.pos - window
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, T_cache))
    out = sdpa(q, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, KVCache(k, v, kpos, cache.pos + 1)
