"""Shared model building blocks: annotated params, norms, RoPE, embeddings.

Params are plain pytrees (nested dicts of arrays).  During init every leaf is
an `Annotated(value, axes)` carrying *logical* axis names ("vocab", "embed",
"heads", "ff", "experts", ...); `split_tree` separates the value tree from
the axes tree, and `runtime.sharding` maps logical axes -> mesh axes with
divisibility checks.  Abstract init (ShapeDtypeStruct leaves) supports the
no-allocation dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class Annotated(NamedTuple):
    value: Any                      # jax.Array | jax.ShapeDtypeStruct
    axes: tuple                     # logical axis names, len == value.ndim


def is_annotated(x) -> bool:
    return isinstance(x, Annotated)


@dataclasses.dataclass
class Init:
    """Parameter factory: concrete (PRNG) or abstract (ShapeDtypeStruct).

    `prefix` prepends stacked-layer dims (logical axis "layers") to every
    param — used to build scan-over-layers weight stacks in one shot.
    """
    key: jax.Array | None
    dtype: Any = jnp.float32
    abstract: bool = False
    prefix: tuple = ()

    def _next(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def stacked(self, *ns: int) -> "Init":
        return dataclasses.replace(self, prefix=self.prefix + tuple(ns))

    def param(self, shape: Sequence[int], axes: Sequence[str | None],
              scale: float | None = None, kind: str = "normal") -> Annotated:
        shape = tuple(int(s) for s in shape)
        assert len(axes) == len(shape), (shape, axes)
        if scale is None:
            fan_in = shape[0] if len(shape) >= 1 else 1
            scale = 1.0 / np.sqrt(max(fan_in, 1))
        full_shape = tuple(self.prefix) + shape
        full_axes = ("layers",) * len(self.prefix) + tuple(axes)
        if self.abstract:
            return Annotated(jax.ShapeDtypeStruct(full_shape, self.dtype),
                             full_axes)
        if kind == "zeros":
            v = jnp.zeros(full_shape, self.dtype)
        elif kind == "ones":
            v = jnp.ones(full_shape, self.dtype)
        else:
            v = (jax.random.truncated_normal(self._next(), -2.0, 2.0, full_shape,
                                             jnp.float32) * scale).astype(self.dtype)
        return Annotated(v, full_axes)


def split_tree(tree):
    """(annotated tree) -> (value tree, axes tree)."""
    values = jax.tree_util.tree_map(lambda a: a.value, tree, is_leaf=is_annotated)
    axes = jax.tree_util.tree_map(lambda a: a.axes, tree, is_leaf=is_annotated)
    return values, axes


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if gamma is not None:
        x = x * (1.0 + gamma.astype(jnp.float32))
    return x.astype(dt)


def layernorm_nonparam(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm (no gain/bias)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm(cfg, x: jax.Array, gamma: jax.Array | None) -> jax.Array:
    if cfg.norm == "layernorm_nonparam":
        return layernorm_nonparam(x)
    return rmsnorm(x, gamma)


def init_norm(cfg, ini: Init, d: int) -> Annotated | None:
    if cfg.norm == "layernorm_nonparam":
        return None
    return ini.param((d,), ("embed",), kind="zeros")   # gamma stored as (1+g)


# ---------------------------------------------------------------------------
# Rotary position embeddings (GPT-NeoX half-rotation)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (or [S]) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(cfg, ini: Init) -> dict:
    p = {"table": ini.param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                            scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = ini.param((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return p


def embed(cfg, p: dict, tokens: jax.Array, dtype) -> jax.Array:
    x = p["table"].astype(dtype)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def unembed(cfg, p: dict, x: jax.Array) -> jax.Array:
    """Logits in the activation dtype (CE upcasts; avoids f32 [B,S,V])."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["head"].astype(x.dtype))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = (jnp.tanh(logits.astype(jnp.float32) / c) * c).astype(x.dtype)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token CE; f32 math on any-dtype logits, labels int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u,
                      w_down.astype(x.dtype))


def init_mlp(cfg, ini: Init, d: int | None = None, ff: int | None = None) -> dict:
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    return {
        "gate": ini.param((d, ff), ("embed", "ff")),
        "up": ini.param((d, ff), ("embed", "ff")),
        "down": ini.param((ff, d), ("ff", "embed")),
    }
