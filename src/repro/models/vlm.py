"""Vision-prefix VLM (internvl2): InternViT frontend is a STUB — the
assignment supplies precomputed patch embeddings via input_specs(); a
learned 2-layer projector maps them into the LM embedding space, then the
qwen2-shaped LM backbone runs with the image prefix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Init, cross_entropy, init_norm
from . import transformer as tfm


def init_lm(cfg, key=None, dtype=jnp.float32, abstract=False) -> dict:
    ini = Init(key=key, dtype=dtype, abstract=abstract)
    p = tfm.init_lm(cfg, key=ini._next() if not abstract else None,
                    dtype=dtype, abstract=abstract)
    p["projector"] = {
        "ln": init_norm(cfg, ini, cfg.d_model),
        "w1": ini.param((cfg.d_model, cfg.d_model), ("embed", "ff")),
        "w2": ini.param((cfg.d_model, cfg.d_model), ("ff", "embed")),
    }
    return p


def _project(cfg, p, patches, dtype):
    from .common import norm
    x = patches.astype(dtype)
    x = norm(cfg, x, p["projector"]["ln"])
    x = jax.nn.gelu(jnp.einsum("bpd,de->bpe", x,
                               p["projector"]["w1"].astype(dtype)))
    return jnp.einsum("bpe,ed->bpd", x, p["projector"]["w2"].astype(dtype))


def lm_loss(cfg, params, batch, *, activ_dtype=jnp.bfloat16, remat="full",
            router_H=None):
    """batch: {patch_embeds [B, P, d], tokens [B, S_text]}."""
    prefix = _project(cfg, params, batch["patch_embeds"], activ_dtype)
    tokens = batch["tokens"]
    logits, H_out, aux = tfm.lm_logits(
        cfg, params, tokens[:, :-1], activ_dtype=activ_dtype, remat=remat,
        router_H=router_H, prefix_embeds=prefix)
    P = prefix.shape[1]
    ce = cross_entropy(logits[:, P:], tokens[:, 1:])   # loss on text only
    return ce, (H_out, {"ce": ce})


def lm_logits(cfg, params, batch, *, activ_dtype=jnp.bfloat16, remat="full",
              router_H=None, last_only=False):
    prefix = _project(cfg, params, batch["patch_embeds"], activ_dtype)
    return tfm.lm_logits(cfg, params, batch["tokens"],
                         activ_dtype=activ_dtype, remat=remat,
                         router_H=router_H, prefix_embeds=prefix,
                         last_only=last_only)


init_decode_caches = tfm.init_decode_caches
cache_axes = tfm.cache_axes
lm_decode_step = tfm.lm_decode_step      # decode: prefix already in cache
