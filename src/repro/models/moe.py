"""Mixture-of-Experts FFN with backpressure (paper eq. 9/10) routing.

Dispatch is sort-based with static expert capacity: tokens are ranked within
their chosen expert via an argsort over expert ids and scattered into
[E, Cap, d] buffers (overflow -> dropped, like the paper's finite computation
capacity C_n).  This costs O(T·k) index ops + the expert matmuls only — no
one-hot dispatch einsum (whose FLOPs would dwarf the expert FFN itself).

Sharding: token groups G -> ("pod","data"), expert buffers E -> "model"
(expert parallelism; XLA inserts the dispatch/combine all-to-alls at the
boundary).  The router's virtual queues H follow the paper's
H_e <- [H_e + assigned_e - C_e]^+ with selection bias beta·H/C (router.py).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.router import RouterState
from .common import Init


def init_moe(cfg, ini: Init) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ini.param((d, E), ("embed", "experts"), scale=0.02),
        "gate": ini.param((E, d, ff), ("experts", "embed", "expert_ff")),
        "up": ini.param((E, d, ff), ("experts", "embed", "expert_ff")),
        "down": ini.param((E, ff, d), ("experts", "expert_ff", "embed")),
    }


def _route(cfg, p, x_flat, router_state: RouterState, *,
           use_kernel: bool = False):
    """Select k experts/token.  x_flat: [G, Tg, d].

    use_kernel=True runs the fused Pallas bp_topk kernel (softmax + H-bias
    + top-k + renorm in one VMEM pass) — the TPU fast path for inference
    routing; gradients flow through the einsum path, so training keeps the
    jnp formulation."""
    G, Tg, _ = x_flat.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("gtd,de->gte", x_flat, p["router"].astype(x_flat.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    cap_step = jnp.asarray(G * Tg * k / E, jnp.float32)     # C_e per step
    if cfg.router == "backpressure":
        bias = jax.lax.stop_gradient(
            router_state.H / jnp.maximum(cap_step, 1.0))
    else:
        bias = jnp.zeros((E,), jnp.float32)
    if use_kernel:
        from repro.kernels.bp_topk.ops import bp_topk_op
        idx2, w2 = bp_topk_op(logits.reshape(G * Tg, E), bias, k)
        idx = idx2.reshape(G, Tg, k)
        w = w2.reshape(G, Tg, k)
    else:
        sel = probs - bias[None, None, :]
        _, idx = jax.lax.top_k(sel, k)                      # [G, Tg, k]
        w = jnp.take_along_axis(probs, idx, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    H_new = jnp.maximum(router_state.H + jax.lax.stop_gradient(counts)
                        - cap_step, 0.0)
    new_state = RouterState(H=H_new, steps=router_state.steps + 1)

    if cfg.router == "aux":
        f = counts / jnp.maximum(counts.sum(), 1.0)
        pbar = probs.mean(axis=(0, 1))
        aux = 0.01 * E * jnp.sum(jax.lax.stop_gradient(f) * pbar)
    else:
        aux = jnp.zeros((), jnp.float32)
    return idx, w.astype(x_flat.dtype), new_state, aux, counts


def moe_ffn(cfg, p: dict, x: jax.Array, router_state: RouterState,
            *, group_size: int | None = None, dropless: bool = False,
            ep_in=None, ep_out=None) -> Tuple[jax.Array, RouterState, jax.Array]:
    """x: [B, S, d] -> (y, new_router_state, aux_loss).

    Groups default to one-per-sequence (G=B, Tg=S) — always divisible and
    sharded over the DP axes.  dropless=True sizes expert buffers to the
    worst case (decode-time behaviour: batches are tiny, so capacity = all
    tokens)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    if group_size is None:
        G, Tg = B, S
    else:
        Tg = min(group_size, T)
        G = T // Tg
    assert G * Tg == T, (T, Tg)
    xf = x.reshape(G, Tg, d)

    idx, w, new_state, aux, _ = _route(cfg, p, xf, router_state)

    if dropless:
        cap = Tg
    else:
        cap = max(int(math.ceil(Tg * k / E * cfg.capacity_factor)), 1)
    tk = Tg * k
    e_flat = idx.reshape(G, tk)
    t_flat = jnp.broadcast_to(jnp.arange(Tg)[:, None], (Tg, k)).reshape(tk)
    w_flat = w.reshape(G, tk)

    order = jnp.argsort(e_flat, axis=-1, stable=True)       # [G, tk]
    e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
    t_sorted = t_flat[order]                                # [G, tk]
    w_sorted = jnp.take_along_axis(w_flat, order, axis=-1)

    # rank within expert: arange - start offset of that expert
    starts = jax.vmap(lambda es: jnp.searchsorted(es, jnp.arange(E),
                                                  side="left"))(e_sorted)
    pos = jnp.arange(tk)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=-1)                          # [G, tk]
    slot = jnp.where(pos < cap, e_sorted * cap + pos, E * cap)  # overflow sink

    # dispatch: scatter tokens into [G, E*cap(+1), d].  All gathers/scatters
    # are vmapped per-group row ops — index arrays stay [tk]-shaped, so the
    # SPMD partitioner keeps everything sharded on G (take_along_axis-style
    # d-broadcast u32[G,tk,d] indices would force full replication).
    xg = jax.vmap(lambda xrow, t: xrow[t])(xf, t_sorted)    # [G, tk, d]
    if ep_out is not None:
        xg = ep_out(xg)
    # scatter stays LOCAL per data shard (G sharded, E replicated) ...
    buf = jax.vmap(
        lambda s, xr: jnp.zeros((E * cap + 1, d), x.dtype).at[s].set(xr)
    )(slot, xg)
    if ep_out is not None:
        buf = ep_out(buf)
    X = buf[:, : E * cap].reshape(G, E, cap, d)
    if ep_in is not None:
        X = ep_in(X)        # ... then reshard E -> 'model' (the dispatch a2a)

    dt = x.dtype
    g = jnp.einsum("gecd,edf->gecf", X, p["gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", X, p["up"].astype(dt))
    Y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, p["down"].astype(dt))
    if ep_out is not None:
        Y = ep_out(Y)       # reshard back (combine a2a) so gathers are local

    # combine: gather back per assignment, weight, scatter-add per token
    Yflat = jnp.concatenate(
        [Y.reshape(G, E * cap, d), jnp.zeros((G, 1, d), dt)], axis=1)
    gathered = jax.vmap(lambda yrow, s: yrow[s])(Yflat, slot)   # [G, tk, d]
    vals = gathered * w_sorted[..., None]
    if ep_out is not None:
        vals = ep_out(vals)
    out = jax.vmap(
        lambda t, vr: jnp.zeros((Tg, d), dt).at[t].add(vr))(t_sorted, vals)
    if ep_out is not None:
        out = ep_out(out)
    return out.reshape(B, S, d), new_state, aux
