"""Encoder-decoder backbone (seamless-m4t): bidirectional encoder over
precomputed frame embeddings (modality frontend is a stub per the
assignment), causal decoder with cross-attention.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.runtime.flags import layer_scan

from .attention import (KVCache, attention, cross_attention, decode_attention,
                        encode_kv, init_attn, init_cache)
from .common import (Init, cross_entropy, embed, init_embedding, init_mlp,
                     init_norm, norm, swiglu, unembed)
from . import transformer as tfm


def init_dec_block(cfg, ini: Init) -> dict:
    return {
        "ln1": init_norm(cfg, ini, cfg.d_model),
        "attn": init_attn(cfg, ini),
        "lnx": init_norm(cfg, ini, cfg.d_model),
        "xattn": init_attn(cfg, ini),
        "ln2": init_norm(cfg, ini, cfg.d_model),
        "mlp": init_mlp(cfg, ini),
    }


def init_lm(cfg, key=None, dtype=jnp.float32, abstract=False) -> dict:
    ini = Init(key=key, dtype=dtype, abstract=abstract)
    return {
        "embed": init_embedding(cfg, ini),
        "encoder": tfm.init_block(cfg, ini.stacked(cfg.enc_layers), moe=False),
        "ln_enc": init_norm(cfg, ini, cfg.d_model),
        "decoder": init_dec_block(cfg, ini.stacked(cfg.dec_layers)),
        "ln_f": init_norm(cfg, ini, cfg.d_model),
    }


def encode(cfg, params, frames, *, remat="full"):
    """frames: [B, S_src, d] (precomputed embeddings) -> memory [B, S_src, d]."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    body = functools.partial(tfm.block_fwd, cfg, window=None, causal=False)
    if remat != "none":
        body = jax.checkpoint(body)

    def f(x, lp):
        x, _, _ = body(lp, x, positions)
        return x, None

    x, _ = layer_scan(f, frames, params["encoder"])
    return norm(cfg, x, params["ln_enc"])


def dec_block_fwd(cfg, p, x, positions, memory):
    h = norm(cfg, x, p["ln1"])
    h = attention(cfg, p["attn"], h, positions, window=None, causal=True)
    x = x + h
    h = norm(cfg, x, p["lnx"])
    mem_kv = encode_kv(cfg, p["xattn"], memory)
    h = cross_attention(cfg, p["xattn"], h, mem_kv)
    x = x + h
    h = norm(cfg, x, p["ln2"])
    return x + swiglu(h, p["mlp"]["gate"], p["mlp"]["up"], p["mlp"]["down"])


def decode_fwd(cfg, params, tokens, memory, *, activ_dtype, remat="full",
               last_only=False):
    x = embed(cfg, params["embed"], tokens, activ_dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    body = functools.partial(dec_block_fwd, cfg)
    if remat != "none":
        body = jax.checkpoint(body)

    def f(x, lp):
        return body(lp, x, positions, memory), None

    x, _ = layer_scan(f, x, params["decoder"])
    x = norm(cfg, x, params["ln_f"])
    if last_only:
        x = x[:, -1:]
    return unembed(cfg, params["embed"], x)


def lm_loss(cfg, params, batch, *, activ_dtype=jnp.bfloat16, remat="full",
            router_H=None):
    """batch: {frames [B, S_src, d], tokens [B, S_tgt]}."""
    memory = encode(cfg, params, batch["frames"].astype(activ_dtype),
                    remat=remat)
    logits = decode_fwd(cfg, params, batch["tokens"][:, :-1], memory,
                        activ_dtype=activ_dtype, remat=remat)
    ce = cross_entropy(logits, batch["tokens"][:, 1:])
    return ce, (router_H, {"ce": ce})


def lm_logits(cfg, params, batch, *, activ_dtype=jnp.bfloat16, remat="full",
              router_H=None, last_only=False):
    """Prefill = encode + full decoder forward over the target prefix."""
    memory = encode(cfg, params, batch["frames"].astype(activ_dtype),
                    remat=remat)
    logits = decode_fwd(cfg, params, batch["tokens"], memory,
                        activ_dtype=activ_dtype, remat=remat,
                        last_only=last_only)
    return logits, router_H, jnp.zeros((), jnp.float32)


class EncDecCache(NamedTuple):
    self_kv: KVCache       # stacked [dec_layers], decoder self-attention
    cross_k: jax.Array     # [dec_layers, B, S_src, KH, Dh]
    cross_v: jax.Array


def init_decode_caches(cfg, batch, max_len, dtype, abstract=False):
    L = cfg.dec_layers
    KH, Dh = cfg.n_kv_heads, cfg.head_dim

    def expand(prefix, tree):
        def one(a):
            if abstract:
                return jax.ShapeDtypeStruct(prefix + a.shape, a.dtype)
            return jnp.broadcast_to(a[(None,) * len(prefix)], prefix + a.shape)
        return jax.tree_util.tree_map(one, tree)

    xshape = (L, batch, max_len, KH, Dh)
    if abstract:
        ck = jax.ShapeDtypeStruct(xshape, dtype)
        cv = jax.ShapeDtypeStruct(xshape, dtype)
    else:
        ck = jnp.zeros(xshape, dtype)
        cv = jnp.zeros(xshape, dtype)
    return EncDecCache(
        self_kv=expand((L,), init_cache(cfg, batch, max_len, dtype,
                                        abstract=abstract)),
        cross_k=ck, cross_v=cv)


def cache_axes(tree: EncDecCache):
    xkv = ("layers", "cache_batch", "cache_seq", "act_kv_heads", None)
    return EncDecCache(self_kv=tfm.cache_axes(tree.self_kv),
                       cross_k=xkv, cross_v=xkv)


def build_cross_cache(cfg, params, memory, max_len, dtype,
                      self_cache=None) -> EncDecCache:
    """Precompute per-decoder-layer cross K/V from encoder output (the
    serving-engine prefill step for enc-dec models)."""
    def kv_one(lp):
        return encode_kv(cfg, lp["xattn"], memory)
    ck, cv = jax.lax.map(lambda lp: kv_one(lp), params["decoder"])
    if self_cache is None:
        B = memory.shape[0]
        self_cache = init_decode_caches(
            cfg, B, max_len, dtype).self_kv
    return EncDecCache(self_kv=self_cache, cross_k=ck.astype(dtype),
                       cross_v=cv.astype(dtype))


def lm_decode_step(cfg, params, caches: EncDecCache, tokens, *,
                   activ_dtype=jnp.bfloat16, router_H=None):
    """One decoder token against self cache + precomputed cross K/V."""
    x = embed(cfg, params["embed"], tokens[:, None], activ_dtype)

    def f(x, xs):
        lp, c, ck, cv = xs
        h = norm(cfg, x, lp["ln1"])
        h, c = decode_attention(cfg, lp["attn"], h, c, window=None)
        x = x + h
        h = norm(cfg, x, lp["lnx"])
        h = cross_attention(cfg, lp["xattn"], h,
                            (ck.astype(x.dtype), cv.astype(x.dtype)))
        x = x + h
        h = norm(cfg, x, lp["ln2"])
        x = x + swiglu(h, lp["mlp"]["gate"], lp["mlp"]["up"], lp["mlp"]["down"])
        return x, c

    x, self_new = layer_scan(
        f, x, (params["decoder"], caches.self_kv, caches.cross_k,
               caches.cross_v))
    x = norm(cfg, x, params["ln_f"])
    logits = unembed(cfg, params["embed"], x)[:, 0, :]
    return logits, EncDecCache(self_kv=self_new, cross_k=caches.cross_k,
                               cross_v=caches.cross_v)
