from .api import ModelAPI, get_model
from .common import Annotated, Init, split_tree

__all__ = ["ModelAPI", "get_model", "Annotated", "Init", "split_tree"]
